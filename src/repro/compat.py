"""JAX version-compatibility shims.

The codebase targets the modern jax API surface (top-level
``jax.shard_map`` with ``check_vma``, ``jax.sharding.AxisType`` meshes);
the baked-in toolchain may ship an older jax (0.4.x) where ``shard_map``
lives in ``jax.experimental.shard_map`` (with ``check_rep``) and
``jax.make_mesh`` has no ``axis_types``.  Every shard_map/mesh call site
goes through these helpers so the repo runs unmodified on both.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

try:                                       # jax >= 0.5
    from jax.sharding import AxisType as _AxisType
except ImportError:                        # jax 0.4.x
    _AxisType = None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices: Optional[Sequence] = None):
    """``jax.make_mesh`` with Auto axis types where supported."""
    kw = {"devices": devices} if devices is not None else {}
    if _AxisType is not None:
        kw["axis_types"] = (_AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def pltpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params: ``pltpu.CompilerParams`` (new) /
    ``pltpu.TPUCompilerParams`` (0.4.x) — same kwargs."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


if hasattr(jax, "shard_map"):              # jax >= 0.6

    def shard_map(f, *, mesh, in_specs, out_specs):
        """``jax.shard_map`` with replication checking off (both APIs)."""
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        """``jax.shard_map`` with replication checking off (both APIs)."""
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
