"""Deterministic, shardable, resumable synthetic LM data pipeline.

Batches are a pure function of (seed, step, host_index) — resumability after
restart or elastic re-meshing is by construction (no iterator state to
checkpoint), and every host materializes only its own shard.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    seq_len: int = 512
    global_batch: int = 8
    n_hosts: int = 1
    host_index: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def batch_at(cfg: ModelConfig, dc: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Markov-chain synthetic tokens (stationary bigram structure so the loss
    actually decreases during training, unlike iid noise)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([dc.seed, step, dc.host_index])
    )
    b, s = dc.host_batch, dc.seq_len
    v = cfg.vocab_size
    # bigram transition: next = (3 * cur + noise) mod v, small noise
    start = rng.integers(0, v, size=(b, 1))
    noise = rng.integers(0, 7, size=(b, s))
    toks = np.zeros((b, s), np.int64)
    toks[:, 0] = start[:, 0]
    for i in range(1, s):
        toks[:, i] = (3 * toks[:, i - 1] + noise[:, i]) % v
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    batch = {"labels": labels}
    if cfg.frontend == "tokens":
        batch["tokens"] = tokens
    else:
        # frontend stub: embeddings are a FIXED random codebook lookup of the
        # token stream, so labels stay predictable from the inputs
        d = cfg.d_model
        code_rng = np.random.default_rng(np.random.SeedSequence([dc.seed, 999]))
        codebook = code_rng.standard_normal((cfg.vocab_size, d)).astype(np.float32) * 0.05
        batch["embeds"] = codebook[tokens]
    return batch


def data_iterator(cfg: ModelConfig, dc: DataConfig, start_step: int = 0) -> Iterator:
    step = start_step
    while True:
        yield batch_at(cfg, dc, step)
        step += 1
