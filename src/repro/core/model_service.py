"""Batched model-step service: the authoritative model-step queue as a
first-class, continuously-batched resource.

Paper anchor: B-PASTE's core invariant is that speculation may only spend
*slack* — it must never tax the latency-critical authoritative path (§5–6,
Eq. 5's ``min(R_slack, B)`` admission limit).  On an accel=1 edge box the
authoritative path IS the model-step queue: with c concurrent episodes, c
reasoning steps contend for one accelerator slot and every scheduler
converges on the serial model-step floor (PR 3/4's ``serving/thor_c8``
rows) — there is no slack for any tool-level mechanism to exploit.  The
only lever left is the model side itself: coalescing concurrent episodes'
reasoning steps into one batched model invocation (the same sublinearity
SPORK and Speculative Actions exploit for inference) compresses the queue,
and the reclaimed accelerator time becomes exactly the slack speculation
needs.

Mechanism (continuous-batching semantics over the discrete-event sim):

* ``submit`` enqueues a :class:`ModelStepRequest` instead of spawning a
  solo simulator job (``runtime._start_model_step`` is the only producer).
* Requests coalesce into micro-batches: a batch DISPATCHES when it reaches
  ``max_batch`` members, or when the ``linger`` admission window — opened
  by the batch's first member — expires (a zero-demand timer job; expiry
  with a single member dispatches a singleton batch).
* A dispatched batch runs as ONE simulator job on ONE accelerator slot
  with latency ``interference.batched_step_latency(works, marginal)`` =
  ``max(w) + marginal·(Σw − max(w))`` — sublinear but not free — and
  completes every member's continuation callback at once.
* ``max_batch=1`` (the pinned baseline) bypasses the queue entirely: the
  request dispatches synchronously with its legacy job name, demand, and
  work, so the pre-service runtime is reproduced bit-identically and every
  equivalence/regression test keeps pinning today's behavior.

Scheduling feedback: :meth:`expected_unlock_delay` exposes the wait a model
step landing NOW would see (remaining linger of the forming batch, or a
fresh window).  The runtime threads it into the EU unlock term ΔU
(``scoring.static_gain_terms(model_delay=...)``): a speculative branch
whose payoff is unlocking the next reasoning step early is worth less when
that step would sit in an already-forming batch window anyway.

Upstream: runtime.py (sole producer, Phase-less — batches are
authoritative jobs, protected by Phase 2 like any other).  Downstream:
simulator.py (batch + linger-timer jobs), interference.py (latency curve),
runtime.Metrics (occupancy / queue-delay / batched-vs-solo accounting,
per-tenant attribution).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.core.events import RESOURCE_DIMS
from repro.core.interference import batched_step_latency
from repro.core.simulator import SimJob, Simulator


@dataclass
class ModelStepRequest:
    """One episode's pending reasoning step.

    ``name`` is the legacy solo-job name (``model[e{eid}.{step}]``) so the
    ``max_batch=1`` fast path reproduces the pre-service simulator log
    verbatim; ``on_done`` is the episode-continuation callback the runtime
    would have hung on the solo job.  ``batchable`` carries the workload's
    per-step metadata (``Step.batchable``): a non-batchable step (e.g. a
    latency-critical final answer) always dispatches solo."""
    eid: int
    name: str
    work: float
    on_done: Callable[[Simulator, SimJob], None]
    enqueued_at: float = 0.0
    batchable: bool = True


@dataclass
class SpecStepTicket:
    """One speculative reasoning step riding an idle slot of a forming batch.

    Passengers are strictly lower priority than authoritative fill: they
    never open an admission window, never trigger dispatch, never extend
    linger, and the lowest-EU passenger is EVICTED (``on_evict``) — never
    the batch delayed — when an authoritative request needs the slot.  A
    dispatched passenger rides FREE: batch duration is computed from the
    authoritative members' works only, so authoritative timing is
    bit-identical to a run without passengers (zero marginal latency up to
    ``max_batch``).  ``on_done`` fires after the authoritative members'
    continuations when the batch completes; the runtime validates the
    speculated outcome against authoritative history on arrival."""
    eid: int
    work: float
    eu: float
    on_done: Callable[[Simulator, SimJob], None]
    on_evict: Callable[[], None]
    dispatched: Optional[SimJob] = None


class ModelStepService:
    """Owns the model-step queue for one runtime.

    Parameters
    ----------
    sim : the runtime's simulator (batches become jobs on it).
    rho : demand vector of ONE model invocation — a batch occupies one
        accelerator slot regardless of occupancy; that compression is the
        entire point.
    max_batch : micro-batch size cap.  1 = pinned pre-service baseline
        (synchronous solo dispatch, bit-identical).
    linger : admission window (sim seconds) a forming batch holds open for
        more members, counted from its FIRST member.  Batching across
        asynchronously-arriving episodes needs linger > 0; the window is a
        latency tax on the first member, which is why it must be short and
        why ``expected_unlock_delay`` reports it to admission scoring.
    marginal : per-extra-member cost fraction of
        ``interference.batched_step_latency``.
    metrics : runtime ``Metrics`` object to book occupancy / queue-delay /
        batched-vs-solo counts into (optional — the service works bare).
    """

    def __init__(self, sim: Simulator, rho: np.ndarray, *,
                 max_batch: int = 1, linger: float = 1.0,
                 marginal: float = 0.3, metrics=None,
                 adaptive: bool = False):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if linger < 0:
            raise ValueError(f"linger must be >= 0, got {linger}")
        self.sim = sim
        self.rho = np.asarray(rho, float)
        self.max_batch = int(max_batch)
        self.linger = float(linger)
        self.marginal = float(marginal)
        self.metrics = metrics
        self.adaptive = bool(adaptive)
        self._forming: List[ModelStepRequest] = []
        self._spec_forming: List[SpecStepTicket] = []
        self._linger_job: Optional[SimJob] = None
        self._linger_deadline: float = 0.0
        self._batch_seq = 0
        # adaptive-linger load signal: EMA of batchable-submit inter-arrival
        # gaps (only maintained when ``adaptive`` — the fixed-linger path
        # stays untouched)
        self._last_arrival: Optional[float] = None
        self._ema_gap: Optional[float] = None

    # ------------------------------------------------------------------
    def submit(self, req: ModelStepRequest) -> None:
        """Enqueue one reasoning step.  Solo fast path (``max_batch=1``,
        non-batchable steps, or a zero linger window that can never coalesce
        asynchronous arrivals) dispatches synchronously — same job name,
        demand, and work as the pre-service runtime.  Otherwise the request
        joins the forming batch: dispatch fires on fill (cancelling the
        linger timer) or on linger expiry."""
        req.enqueued_at = self.sim.now
        if self.max_batch == 1 or not req.batchable or self.linger <= 0.0:
            self._dispatch([req])
            return
        if self.adaptive:
            if self._last_arrival is not None:
                gap = max(self.sim.now - self._last_arrival, 0.0)
                self._ema_gap = gap if self._ema_gap is None else (
                    0.7 * self._ema_gap + 0.3 * gap)
            self._last_arrival = self.sim.now
        self._forming.append(req)
        # authoritative fill always wins: when the new member would overflow
        # the batch past speculative passengers, the lowest-EU passenger is
        # evicted — the batch is never delayed and never dispatched over-full
        while (self._spec_forming
               and len(self._forming) + len(self._spec_forming) > self.max_batch):
            victim = min(self._spec_forming, key=lambda t: t.eu)
            self._spec_forming.remove(victim)
            victim.on_evict()
        if len(self._forming) >= self.max_batch:
            if self._linger_job is not None:
                self.sim.cancel(self._linger_job.jid)
                self._linger_job = None
            self._dispatch_forming()
            return
        if self._linger_job is None:
            self._open_window()

    # ------------------------------------------------------------------
    # speculative slot-fill (strictly lower priority than authoritative)
    def submit_speculative(self, ticket: SpecStepTicket) -> bool:
        """Offer a speculative reasoning step an idle slot of the CURRENTLY
        forming batch.  Returns False (nothing enqueued) unless a window is
        open with a free slot — passengers never open windows, never trigger
        dispatch, and never extend linger."""
        if self.max_batch == 1 or self.linger <= 0.0:
            return False
        if self._linger_job is None:
            return False
        if len(self._forming) + len(self._spec_forming) >= self.max_batch:
            return False
        self._spec_forming.append(ticket)
        return True

    def withdraw_spec(self, ticket: SpecStepTicket) -> bool:
        """Remove a still-forming passenger (squash before dispatch).  False
        if it already dispatched or was evicted."""
        if ticket in self._spec_forming:
            self._spec_forming.remove(ticket)
            return True
        return False

    def promote_spec(self, ticket: SpecStepTicket,
                     req: ModelStepRequest) -> None:
        """A still-forming passenger validated by the authoritative arrival:
        it becomes a regular member of the same forming batch (normal
        ``submit`` path — may fill-trigger dispatch)."""
        self.withdraw_spec(ticket)
        self.submit(req)

    @property
    def spec_slot_free(self) -> bool:
        """True iff a speculative step submitted NOW would ride free: a
        window is open with an idle slot.  Admission threads this into the
        slot-marginal-cost term (a hypothesis whose MODEL step lands in a
        forming under-full batch carries near-zero model-step cost in ΔI)."""
        return (self.max_batch > 1 and self.linger > 0.0
                and self._linger_job is not None
                and len(self._forming) + len(self._spec_forming) < self.max_batch)

    def _open_window(self) -> None:
        """Zero-demand timer job holding the admission window open.  Zero
        demand ⇒ no interference and no QoS-sample pollution (the ``timer``
        meta flag excludes it from slowdown attribution, like the arrival
        timer); the event-driven sim would otherwise never wake at the
        deadline when nothing else completes in the window."""
        win = self._window_len()
        self._linger_deadline = self.sim.now + win

        def fire(sim: Simulator, job: SimJob):
            self._linger_job = None
            self._dispatch_forming()

        self._linger_job = self.sim.new_job(
            "model_batch_linger", np.zeros(RESOURCE_DIMS),
            max(win, 1e-9), speculative=False, on_complete=fire,
            meta={"timer": True},
        )
        self.sim.start(self._linger_job)

    def _window_len(self) -> float:
        """Admission-window length for the batch being opened NOW.  Fixed
        ``linger`` unless ``adaptive``, which is load-aware in three
        regimes keyed on the EMA inter-arrival gap of batchable submits:

        * dense (gap ≤ linger): arrivals land inside the fixed window —
          keep it (restoration under burst fill falls out of the EMA
          pulling back down).
        * moderate (linger < gap ≤ 2·linger): the expected next arrival
          lands just PAST the fixed window — every batch would dispatch
          solo having paid the full linger tax for nothing.  Stretch to
          1.25× the expected gap (capped at 2·linger) so the window
          actually catches the next tenant: this is what buys batch
          occupancy at low open-loop rates.
        * trickle (gap > 2·linger): coalescing is a lost cause — shrink
          proportionally and stop paying the admission tax."""
        if not self.adaptive or not self._ema_gap or self._ema_gap <= 0.0:
            return self.linger
        g = self._ema_gap
        if g <= self.linger:
            return self.linger
        if g <= 2.0 * self.linger:
            return min(1.25 * g, 2.0 * self.linger)
        return max(self.linger * (self.linger / g), 1e-9)

    def _dispatch_forming(self) -> None:
        batch, self._forming = self._forming, []
        spec, self._spec_forming = self._spec_forming, []
        if batch:
            self._dispatch(batch, queued=True, spec=spec)
        else:
            # a window is only ever opened by an authoritative member, so
            # passenger-only expiry is unreachable today; evict defensively
            # rather than dispatch a batch speculation would have to pay for
            for t in spec:
                t.on_evict()

    def _dispatch(self, batch: List[ModelStepRequest],
                  queued: bool = False,
                  spec: Optional[List[SpecStepTicket]] = None) -> None:
        """Run one micro-batch as a single simulator job.  Batch demand is
        ONE model invocation's ρ (one accelerator slot — occupancy rides
        inside the job, not on the resource vector); duration follows the
        ``base + marginal·(b−1)`` curve.  Completion fires every member's
        continuation in submission order — the same order solo completions
        at one instant would have fired.  Speculative passengers ride FREE:
        duration is computed from the authoritative works only, ``eids``
        stays authoritative-only (QoS attribution fans over it), and
        passengers' ``on_done`` fire after every authoritative member's."""
        b = len(batch)
        works = [r.work for r in batch]
        dur = batched_step_latency(works, self.marginal)
        name = batch[0].name if b == 1 else (
            f"model_batch[b{self._batch_seq}x{b}]")
        batch_id = self._batch_seq
        self._batch_seq += 1
        self._book_dispatch(batch, queued)
        spec = spec or []

        def done(sim: Simulator, job: SimJob):
            for r in batch:
                r.on_done(sim, job)
            for t in spec:
                t.on_done(sim, job)

        meta = {"eid": batch[0].eid, "eids": [r.eid for r in batch],
                "batch_size": b, "batch": batch_id}
        if spec:
            meta["spec_eids"] = [t.eid for t in spec]
        job = self.sim.new_job(
            name, self.rho, dur, speculative=False, on_complete=done,
            meta=meta,
        )
        for t in spec:
            t.dispatched = job
        if spec and self.metrics is not None:
            self.metrics.spec_slot_fill_samples.append(len(spec))
        self.sim.start(job)

    def _book_dispatch(self, batch: List[ModelStepRequest],
                       queued: bool) -> None:
        m = self.metrics
        if m is None:
            return
        b = len(batch)
        m.model_batches += 1
        m.model_batch_occupancy_samples.append(b)
        if b == 1:
            m.model_solo_steps += 1
        else:
            m.model_batched_steps += b
        for r in batch:
            wait = max(self.sim.now - r.enqueued_at, 0.0)
            if queued:
                # every member that went THROUGH the admission window gets a
                # delay sample — including the fill-triggering member's 0.0
                # — so mean_model_queue_delay is a true per-queued-step mean
                # (solo fast-path dispatches never entered the window and
                # book nothing)
                m.model_queue_delay_samples.append(wait)
            # queue delay is attributed to the tenant that WAITED — the
            # member that opened the window pays the linger, late joiners
            # pay less; per-batch pooling would smear one tenant's latency
            # tax across the whole batch
            if wait > 0.0:
                m.model_queue_delay_seconds += wait
                m.tenant_model_queue_delay[r.eid] = (
                    m.tenant_model_queue_delay.get(r.eid, 0.0) + wait)

    # ------------------------------------------------------------------
    def expected_unlock_delay(self) -> float:
        """Expected wait a model step landing NOW would see before its batch
        even starts: the remaining linger of the forming batch it would join
        (a full window if none is open and batching is on; 0 under the
        ``max_batch=1`` baseline — keeping baseline EU scoring bit-identical).
        Admission threads this into ΔU: unlocking the next reasoning step
        early is worth at most the part of the unlock the batch window does
        not swallow (DESIGN.md, model-step-service section)."""
        if self.max_batch == 1 or self.linger <= 0.0:
            return 0.0
        if self._linger_job is not None:
            # a live window is always joinable: submit() dispatches and
            # clears the forming batch the instant it reaches max_batch, so
            # a full-but-undispatched window state cannot exist
            return max(self._linger_deadline - self.sim.now, 0.0)
        return self._window_len()

    @property
    def forming_size(self) -> int:
        """Members currently waiting in the open admission window."""
        return len(self._forming)
