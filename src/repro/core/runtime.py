"""B-PASTE runtime: Algorithm 1 (beam-aware opportunistic speculative
scheduling) over the discrete-event simulator.

Per tick (any job start/finish/preempt):
  Phase 1  Confirm/Promote — match arriving authoritative invocations
           against speculative branch nodes: completed → reuse result (+
           commit the branch's state snapshot up to that node); running →
           promote to authoritative (non-preemptible); completed prefix →
           reuse prefix state and continue from the boundary.
  Phase 2  Protect — if authoritative demand exceeds capacity, preempt
           speculative jobs in ascending admission-EU order.
  Phase 3  Run authoritative jobs (primary FIFO policy, untouched).
  Phase 4  Opportunistic branch scheduling — refresh each active episode's
           beam, pool the idle candidates from ALL episodes into one shared
           cross-episode beam, score EU (Eq. 3) with per-tenant fairness
           weights, and greedily admit the highest-value branch *prefixes*
           under min(R_slack, B) in ONE fused pass per tick (per-episode
           passes each saw slack that ignored demand a sibling episode had
           just admitted but not launched — cross-tenant double-booking);
           admitted prefixes run as preemptible speculative jobs inside CoW
           sandboxes.

Modes:
  "bpaste"   — full system (beam of branch hypotheses, EU objective)
  "paste"    — single-invocation speculation, expected-saved-latency rank
               (the PASTE baseline per [1])
  "parallel" — naive concurrency: admit everything that fits, probability
               order, no EU/no preemption priority (the strawman §9 argues
               against)
  "serial"   — no speculation

Paper anchor: Algorithm 1 (the phase loop), §5–6 (slack-only speculation,
authoritative protection), Eq. 5 admission limit.
Upstream: workload.py (episodes), patterns/hypothesis (beam supply),
scoring/admission (EU + admitted set), safety.py (eligibility policy).
Downstream: simulator.py (every job), sandbox/executor (state effects),
memo.py (cache-served commits), model_service.py (the authoritative
model-step queue — ``_start_model_step`` enqueues there; batches are
authoritative jobs protected by Phase 2 like any other).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

import time

from repro.core.admission import (
    _fit_limit,
    admission_signature,
    bucket_k,
    fused_admit,
    greedy_admit,
)
from repro.core.analysis import AnalysisError, RuntimeSanitizer, analyze_static
from repro.core.scoring import tenant_fairness_weights
from repro.core.events import (
    DEFAULT_TOOLS, RESOURCE_DIMS, Event, ResourceVector, SafetyLevel, ToolSpec,
    signature,
)
from repro.core.executor import StateFacade, execute_tool
from repro.core.hypothesis import (
    COLD_TOOLS, BranchHypothesis, HypothesisBuilder, Node, NodeKind,
)
from repro.core.interference import Machine
from repro.core.memo import MemoEntry, ResultStore, memo_key
from repro.core.model_service import (
    ModelStepRequest, ModelStepService, SpecStepTicket,
)
from repro.core.patterns import PatternEngine
from repro.core.safety import EligibilityPolicy, FULL_POLICY
from repro.core.sandbox import AgentState, Sandbox
from repro.core.scoring import PackedBeam, Scorer, pack_beam, prefix_rho
from repro.core.simulator import SimJob, Simulator
from repro.core.workload import Episode


@dataclass
class NodeRun:
    node: Node
    resolved_args: Dict[str, Any]
    status: str = "pending"       # pending|running|done|reused|promoted
    job: Optional[SimJob] = None
    result: Any = None
    run_tool: str = ""            # actual (possibly transformed) tool
    transformed: bool = False
    snapshot: Optional[Dict[str, Dict[str, Any]]] = None  # cumulative overlay
    waiting: bool = False         # subscribed to an in-flight twin in the
                                  # result store (launch deduped)
    served: bool = False          # result came from the store at zero cost
                                  # (no job, no burn — not "invested" work)
    args_epoch: int = -1          # EpisodeState.epoch the cached resolution
    args_cache: Optional[Dict[str, Any]] = None  # below was computed at
    mkey_epoch: int = -1          # same guard for the canonical memo key
    mkey_cache: Any = None
    # memo-mask servability verdict cache (_memo_terms pass 1).  A verdict
    # can only change if the episode's epoch moved (args / sandbox / node
    # state), the node's tool saw a NEW publish (store.tool_pubs — the only
    # way an unservable key becomes servable), or — for a positive verdict
    # — any invalidation fired (the only way a servable entry retracts
    # without a republish).
    serv_epoch: int = -1
    serv_pubs: int = -1
    serv_inval: int = -1
    serv_ok: bool = False


@dataclass
class HypRun:
    hyp: BranchHypothesis
    eid: int
    sandbox: Sandbox
    node_runs: List[NodeRun]
    eu: float
    parents: Dict[int, Tuple[int, ...]] = field(default_factory=dict)
    base_len: int = 0             # len(history) the hypothesis was built on:
                                  # late bindings resolve against THIS prefix
                                  # (mined offsets are relative to the build
                                  # context, not whatever history grew into)
    status: str = "active"        # active|done|squashed
    used: bool = False            # any node reused/promoted (waste metric)

    def path_to(self, i: int) -> List[int]:
        """Root-to-node index path, via the cached parent map."""
        return self.hyp.path_to(i, self.parents)


@dataclass
class EpisodeState:
    ep: Episode
    state: AgentState
    history: List[Event] = field(default_factory=list)
    step_idx: int = 0
    phase: str = "init"           # init|reasoning|acting|executing|done
    t_start: float = 0.0
    t_end: float = 0.0
    pending_action: Optional[Tuple[str, Dict[str, Any]]] = None
    inflight: Optional[Tuple[str, Dict[str, Any]]] = None
    matched_hr: Optional["HypRun"] = None
    last_writes: set = field(default_factory=set)
    hyp_runs: List[HypRun] = field(default_factory=list)
    auth_queue: List[SimJob] = field(default_factory=list)
    # env_warmup effect horizon, PER TENANT: warmth lives in the episode's
    # own environment, so one tenant's env_warmup must not discount another
    # tenant's cold tools (a global scalar did exactly that under
    # concurrency > 1)
    warm_until: float = -1.0
    idx: int = -1                 # position in BPasteRuntime.episodes — the
                                  # key the event scheduler's dirty-sets and
                                  # per-episode caches are indexed by
    epoch: int = 0                # bumped on every dirtying event; guards the
                                  # per-NodeRun resolved-args cache (the
                                  # pseudo-history inputs — history prefix,
                                  # inflight, path node results — only change
                                  # through events that mark the episode dirty)


@dataclass
class SpecStep:
    """Lifecycle record of one speculative reasoning step (tentpole of the
    two-segment speculation path).

    ``assumed`` is the ENCODED authoritative history the prediction requires
    at the reasoning boundary it targets: the branch's build context plus a
    materialized prefix of the spine's TOOL results.  The agent reasons
    after EVERY action, so every done-prefix of the spine is a valid
    boundary to draft — a branch may hold several outstanding drafts at
    successive boundaries (``full`` marks the one at the MODEL join itself,
    which is what unlocks segment 2).  Validation on arrival compares
    ``assumed`` against the encoded live history (``_consume_spec_steps``):
    equality is a hit, a strict extension keeps the draft alive (the agent
    has not reached that boundary yet), anything else is a dead prediction
    and squashes.  Exactly one terminal ``outcome`` per submission —
    accepted | squashed | evicted — with waste booked so that every
    ``wasted_solo_seconds`` increment has a matching ``spec_solo_seconds``
    contribution (wasted_frac <= 1 stays an invariant).  A passenger whose
    branch resubmits after eviction counts as a NEW submission."""
    es: "EpisodeState"
    hr: HypRun
    i: int                        # MODEL node index in hr.node_runs
    assumed: List[Tuple]
    work: float                   # speculative model-step solo work
    eu: float                     # branch admission EU (eviction order)
    full: bool = False            # boundary == the MODEL join (whole spine)
    ticket: Optional[SpecStepTicket] = None
    landed: bool = False          # batch completed; predicted outcome exists
    outcome: str = ""             # terminal: accepted|squashed|evicted
    pending_auth: bool = False    # authoritative step matched mid-flight:
                                  # batch completion IS the reasoning step
    pending_saved: float = 0.0    # latency credit to book on that completion

    @property
    def stage(self) -> str:
        if self.landed:
            return "done"
        t = self.ticket
        return "inflight" if (t is not None and t.dispatched is not None) \
            else "forming"


@dataclass
class RuntimeConfig:
    mode: str = "bpaste"
    admission: str = "fused"      # "fused" (one-dispatch admit_beam kernel)
                                  # | "reference" (per-iteration greedy oracle)
    scheduler: str = "event"      # "event" (dirty-set tick loop: an episode
                                  # is re-examined only when one of its
                                  # wakeup triggers fired — O(dirty) per
                                  # tick) | "dense" (the PR-5 reference
                                  # loop: every phase scans every episode
                                  # every tick — O(c); decision-identical,
                                  # kept as the equivalence oracle)
    record_log: bool = True       # simulator event log (start/finish/...)
                                  # — an unbounded list; benches at c=1024
                                  # turn it off (trace= is the opt-in full
                                  # recorder)
    trace: Any = None             # optional trace.GanttRecorder (or any
                                  # recorder(sim, kind, job) callable)
                                  # attached to the simulator for
                                  # per-episode timeline dumps
    assembly: str = "tree"        # "tree" (branching subgraphs, multi-root
                                  # fill) | "chain" (pre-tree linear baseline)
    beam_k: int = 12              # multi-root fill needs slots: makespan,
                                  # reuse rate, and occupancy all improve up
                                  # to ~12 slots on the default workload,
                                  # then saturate (benchmarks/bench_beam.py)
    max_nodes: int = 12
    lam: float = 0.5
    mu: float = 1.0
    budget: ResourceVector = ResourceVector(cpu=8, mem_bw=60, io=400, accel=1)
    idle_window: float = 8.0
    max_concurrent_episodes: int = 1
    seed: int = 0
    warm_discount: float = 0.65   # prep-node payoff on cold tools (§4.1)
    warm_ttl: float = 120.0
    fairness_alpha: float = 1.0   # shared-beam fairness: tenants already
                                  # holding speculative capacity get their
                                  # candidates' EU discounted by
                                  # 1/(1+alpha*share); 0 disables
    memo: bool = True             # runtime-global result store: validated
                                  # speculative/authoritative results are
                                  # SERVED to later identical invocations
                                  # (any tenant) instead of re-executed;
                                  # inert in mode="serial"
    # batched model-step service (model_service.py): coalesce concurrent
    # episodes' reasoning steps into micro-batched model invocations.
    # max_batch=1 is the PINNED baseline — the service dispatches solo jobs
    # synchronously and the runtime is bit-identical to the pre-service
    # code, which every equivalence/regression test relies on.  Batching is
    # the model-side lever for the accel-bound edge regime where the
    # model-step queue (not tool work) is the bottleneck.
    host_admit_max: int = 512     # pools at/below this take the host-side
                                  # numpy admit kernel; above it, the one-
                                  # dispatch XLA while_loop.  On CPU a
                                  # single XLA dispatch costs ~1 ms — and
                                  # every fresh bucketed pool shape costs
                                  # an in-run compile — which dwarfs the
                                  # numpy arithmetic up to mid-hundreds of
                                  # candidates (the two kernels are
                                  # decision-identical — the fused-
                                  # admission equivalence suite and the
                                  # pinned end-to-end metrics both gate
                                  # this routing)
    warm_admit: bool = True       # verified admission warm-start: when this
                                  # tick's post-filter admission inputs (hid
                                  # tuple, slack/budget/demand vectors,
                                  # fairness weights, memo terms, model
                                  # delay) are byte-identical to last
                                  # tick's, replay last tick's admitted set
                                  # instead of re-running the greedy/fused
                                  # kernel.  The signature pins EVERY input
                                  # the admission decision is a function of,
                                  # so the replayed decisions are
                                  # bit-identical by construction; any
                                  # deviation falls back to the full pass.
                                  # Guarded by staticcheck C1 + the runtime
                                  # sanitizer + event≡dense equivalence.
    model_max_batch: int = 1
    model_batch_linger: float = 1.5   # admission window (sim s) a forming
                                      # batch stays open from its first
                                      # member; the window is a latency tax
                                      # on that member, so keep it short
    model_batch_marginal: float = 0.3  # per-extra-member cost fraction of
                                       # interference.batched_step_latency
    spec_model_steps: bool = False    # speculative reasoning steps: two-
                                      # segment hypothesis trees continue
                                      # past the MODEL join with the mined
                                      # table's top continuation, and the
                                      # predicted step rides an idle slot of
                                      # a forming under-full batch (strictly
                                      # lower priority than authoritative
                                      # fill; validate-on-arrival, mismatch
                                      # squashes).  Default off = the whole
                                      # path is inert and every decision is
                                      # bit-identical to the flag's absence.
                                      # Needs model_max_batch > 1 (passengers
                                      # only exist where idle slots do).
    adaptive_linger: bool = False     # load-aware batch admission window:
                                      # when batchable submits are trickling
                                      # (EMA inter-arrival gap > linger) the
                                      # window shrinks proportionally, and in
                                      # the moderate regime (gap just past
                                      # the window) it stretches toward the
                                      # expected next arrival — the linger
                                      # tax is paid exactly where coalescing
                                      # is likely.  Default off = fixed-
                                      # linger path untouched.
    shed_alpha: float = 0.0           # load-shedding admission (open-loop
                                      # overload ladder): every candidate's
                                      # ΔO is taxed shed_alpha × the arrival
                                      # backlog (arrived-but-unlaunched
                                      # tenants), so the lowest-EU
                                      # speculation sheds first and at deep
                                      # overload the beam prices itself out
                                      # entirely — idle slack is left for
                                      # the queued authoritative work.  The
                                      # scalar threads through every
                                      # admission path at the same point as
                                      # model_delay/spec_costs and enters
                                      # the warm-start signature.  0 = off
                                      # (bit-identical, closed-loop pins).
    # ---- speculation-safety analysis (core/analysis.py) ----------------
    analysis: str = "warn"        # construction-time static pass (R1-R3)
                                  # over (policy, tool table, patterns):
                                  # "off" skips it, "warn" warnings.warn on
                                  # error findings, "strict" raises
                                  # AnalysisError.  The report is kept at
                                  # ``BPasteRuntime.analysis_report`` either
                                  # way.  Pure — no RNG, no builder ids —
                                  # so decisions are untouched.
    sanitize: bool = False        # runtime sanitizer: every sanitize_every
                                  # ticks, cross-check the event scheduler's
                                  # caches (epoch args/memo-key/servability,
                                  # dirty-set frontiers, counter-group
                                  # demand/slack, store indices) against
                                  # fresh recomputation, plus tracked
                                  # executor footprints vs declared specs on
                                  # every execution.  Read-only: findings
                                  # land in ``BPasteRuntime.sanitizer`` and
                                  # Metrics.sanitize_findings, decisions are
                                  # bit-identical to sanitize=False.
    sanitize_every: int = 7       # sampled tick schedule for the sanitizer
                                  # sweep (footprint checks always run when
                                  # sanitize is on); prime, so the sample
                                  # doesn't alias phase-periodic tick shapes
    race_mask: bool = False       # thread R3's write-conflict detection into
                                  # shared admission as a mask: when two
                                  # co-admitted branches' frontier tools
                                  # declare the same EXACT write key with
                                  # different tools, the lower-EU branch is
                                  # de-admitted this pass (report-only
                                  # detection runs under sanitize without
                                  # masking)


@dataclass
class Metrics:
    makespan: float = 0.0
    episode_latencies: List[float] = field(default_factory=list)
    serial_reference: float = 0.0
    promotions: int = 0
    reuses: int = 0
    prefix_reuses: int = 0
    mis_speculations: int = 0
    wasted_solo_seconds: float = 0.0
    spec_solo_seconds: float = 0.0
    qos_violations: int = 0
    auth_slowdown_samples: List[float] = field(default_factory=list)
    auth_actions: int = 0
    # simulation stopped on max_time/max_steps with work outstanding —
    # makespan/latency figures are lower bounds, not results
    truncated: bool = False
    # per-tenant breakdowns (tenant == episode): service latency (launch ->
    # done), sojourn (ARRIVAL -> done, i.e. queueing delay included — the
    # honest serving metric under staggered arrivals, where a tenant can
    # wait far longer for a slot than it spends in service), the
    # speculation-attributable slowdown samples of the tenant's own
    # authoritative jobs, and its QoS violations — fairness is judged on
    # the WORST tenant, which the pooled means above can hide
    tenant_latency: Dict[int, float] = field(default_factory=dict)
    tenant_sojourn: Dict[int, float] = field(default_factory=dict)
    tenant_slowdown_samples: Dict[int, List[float]] = field(default_factory=dict)
    tenant_qos_violations: Dict[int, int] = field(default_factory=dict)
    # cross-episode result store (memo.py): authoritative actions served
    # from the cache at zero execution cost, speculative launches served
    # into sandboxes, duplicate in-flight launches deduped, entries killed
    # by footprint-intersection invalidation, and the per-tenant latency the
    # serves bought (a tenant at saturation gets hits from a sibling's warm
    # speculation — this is the number that shows it)
    memo_serves: int = 0
    memo_hits: int = 0
    memo_dedups: int = 0
    memo_invalidations: int = 0
    memo_entries: int = 0
    memo_saved_seconds: float = 0.0
    tenant_memo_saved: Dict[int, float] = field(default_factory=dict)
    # batched model-step service (model_service.py): dispatched batch jobs,
    # steps served in size>=2 batches vs solo dispatches, per-batch
    # occupancy at dispatch, and the admission-window queue delay each
    # request actually waited — attributed to the tenant that waited, so
    # the linger tax can never hide inside a pooled mean (the batching
    # analogue of per-tenant QoS attribution)
    model_batches: int = 0
    model_batched_steps: int = 0
    model_solo_steps: int = 0
    model_batch_occupancy_samples: List[int] = field(default_factory=list)
    model_queue_delay_samples: List[float] = field(default_factory=list)
    model_queue_delay_seconds: float = 0.0
    tenant_model_queue_delay: Dict[int, float] = field(default_factory=dict)
    # speculative reasoning steps (RuntimeConfig.spec_model_steps): every
    # submitted passenger terminates in exactly one of accepted / squashed /
    # evicted (submitted == accepted + squashed + evicted at run end — the
    # lifecycle property test pins this); saved-seconds is the authoritative
    # step latency the accepted hits bought, slot-fill is passengers per
    # dispatched batch that carried any
    spec_steps_submitted: int = 0
    spec_steps_accepted: int = 0
    spec_steps_squashed: int = 0
    spec_steps_evicted: int = 0
    spec_step_saved_seconds: float = 0.0
    spec_slot_fill_samples: List[int] = field(default_factory=list)
    # occupied beam slots (active hypotheses, launchable or mid-flight,
    # summed over all active episodes) at each shared admission pass —
    # beam fullness against the per-episode beam_k slot cap, NOT the
    # per-pass candidate count (candidates drain as nodes launch)
    beam_occupancy_samples: List[int] = field(default_factory=list)
    # scheduler self-overhead: wall time burned inside admission per tick
    sched_admit_calls: int = 0
    sched_admit_seconds: float = 0.0
    sched_pack_hits: int = 0
    sched_pack_misses: int = 0
    # admission warm-start (RuntimeConfig.warm_admit): passes replayed from
    # last tick's verified signature vs full kernel passes.  Deliberately
    # NOT in summary(): summaries must stay bit-identical warm on/off.
    sched_warm_hits: int = 0
    sched_warm_misses: int = 0
    # whole-tick scheduler overhead (phases 1-4 + QoS accounting): the
    # number the event-driven refactor is judged on —
    # benchmarks/bench_scheduler.py reports it as us/tick/episode
    sched_ticks: int = 0
    sched_tick_seconds: float = 0.0
    # speculation-safety sanitizer (RuntimeConfig.sanitize): findings
    # recorded by the per-tick cross-checks + footprint contract, and
    # branches de-admitted by the write-race conflict mask
    # (RuntimeConfig.race_mask).  Both stay 0 with the knobs off, so the
    # event≡dense and pinned-metric comparisons are unaffected.
    sanitize_findings: int = 0
    race_masked: int = 0
    # load-shedding admission (RuntimeConfig.shed_alpha): admission passes
    # that ran with a nonzero shed tax, the worst arrival backlog behind
    # one, and the candidates priced out while it was active — the ladder's
    # "speculation sheds first" evidence (all 0 with the knob off, so the
    # closed-loop pinned comparisons are unaffected)
    shed_passes: int = 0
    shed_peak_backlog: int = 0
    shed_rejections: int = 0

    def summary(self) -> Dict[str, float]:
        lat = np.array(self.episode_latencies) if self.episode_latencies else np.zeros(1)
        total_spec = max(self.spec_solo_seconds, 1e-9)
        return {
            "makespan": self.makespan,
            "mean_latency": float(lat.mean()),
            "p95_latency": float(np.percentile(lat, 95)),
            "promotions": self.promotions,
            "reuses": self.reuses,
            "prefix_reuses": self.prefix_reuses,
            "mis_speculations": self.mis_speculations,
            "wasted_frac": self.wasted_solo_seconds / total_spec,
            "spec_solo_seconds": self.spec_solo_seconds,
            "qos_violations": self.qos_violations,
            "reuse_rate": self.reuses / max(self.auth_actions, 1),
            "beam_occupancy": (
                float(np.mean(self.beam_occupancy_samples))
                if self.beam_occupancy_samples else 0.0
            ),
            "mean_auth_slowdown": float(np.mean(self.auth_slowdown_samples))
            if self.auth_slowdown_samples else 1.0,
            "sched_admit_calls": self.sched_admit_calls,
            "sched_us_per_admit": (
                self.sched_admit_seconds * 1e6 / self.sched_admit_calls
                if self.sched_admit_calls else 0.0
            ),
            "sched_pack_hit_rate": (
                self.sched_pack_hits
                / max(self.sched_pack_hits + self.sched_pack_misses, 1)
            ),
            "sched_ticks": self.sched_ticks,
            "sched_us_per_tick": (
                self.sched_tick_seconds * 1e6 / self.sched_ticks
                if self.sched_ticks else 0.0
            ),
            "truncated": float(self.truncated),
            "worst_tenant_latency": (
                max(self.tenant_latency.values()) if self.tenant_latency else 0.0
            ),
            "p95_sojourn": (
                float(np.percentile(list(self.tenant_sojourn.values()), 95))
                if self.tenant_sojourn else 0.0
            ),
            "worst_tenant_sojourn": (
                max(self.tenant_sojourn.values()) if self.tenant_sojourn else 0.0
            ),
            "worst_tenant_slowdown": (
                max(float(np.mean(s)) for s in self.tenant_slowdown_samples.values())
                if self.tenant_slowdown_samples else 1.0
            ),
            "memo_serves": self.memo_serves,
            "memo_hits": self.memo_hits,
            "memo_dedups": self.memo_dedups,
            "memo_invalidations": self.memo_invalidations,
            "memo_saved_seconds": self.memo_saved_seconds,
            "memo_serve_rate": self.memo_serves / max(self.auth_actions, 1),
            "model_batches": self.model_batches,
            "model_batched_steps": self.model_batched_steps,
            "model_solo_steps": self.model_solo_steps,
            "model_batch_occupancy": (
                float(np.mean(self.model_batch_occupancy_samples))
                if self.model_batch_occupancy_samples else 0.0
            ),
            "model_queue_delay_seconds": self.model_queue_delay_seconds,
            "mean_model_queue_delay": (
                float(np.mean(self.model_queue_delay_samples))
                if self.model_queue_delay_samples else 0.0
            ),
            "spec_steps_submitted": self.spec_steps_submitted,
            "spec_steps_accepted": self.spec_steps_accepted,
            "spec_steps_squashed": self.spec_steps_squashed,
            "spec_steps_evicted": self.spec_steps_evicted,
            "spec_step_saved_seconds": self.spec_step_saved_seconds,
            "spec_squash_rate": (
                self.spec_steps_squashed / max(self.spec_steps_submitted, 1)
            ),
            "spec_slot_fill": (
                float(np.mean(self.spec_slot_fill_samples))
                if self.spec_slot_fill_samples else 0.0
            ),
            "sanitize_findings": self.sanitize_findings,
            "race_masked": self.race_masked,
            "shed_passes": self.shed_passes,
            "shed_peak_backlog": self.shed_peak_backlog,
            "shed_rejections": self.shed_rejections,
        }

    def per_tenant(self) -> Dict[int, Dict[str, float]]:
        """Per-tenant serving breakdown: service latency, arrival-inclusive
        sojourn, mean slowdown of the tenant's own authoritative jobs, and
        its QoS violations."""
        eids = (set(self.tenant_latency) | set(self.tenant_slowdown_samples)
                | set(self.tenant_qos_violations))
        return {
            eid: {
                "latency": self.tenant_latency.get(eid, 0.0),
                "sojourn": self.tenant_sojourn.get(eid, 0.0),
                "mean_auth_slowdown": (
                    float(np.mean(self.tenant_slowdown_samples[eid]))
                    if self.tenant_slowdown_samples.get(eid) else 1.0
                ),
                "qos_violations": float(self.tenant_qos_violations.get(eid, 0)),
                "memo_saved": self.tenant_memo_saved.get(eid, 0.0),
                "model_queue_delay": self.tenant_model_queue_delay.get(eid, 0.0),
            }
            for eid in sorted(eids)
        }


class BPasteRuntime:
    def __init__(
        self,
        episodes: List[Episode],
        engine: PatternEngine,
        machine: Optional[Machine] = None,
        policy: EligibilityPolicy = FULL_POLICY,
        rcfg: Optional[RuntimeConfig] = None,
        tools: Dict[str, ToolSpec] = DEFAULT_TOOLS,
        episode_source: Optional[Iterator[Episode]] = None,
    ):
        if machine is None:
            machine = Machine()
        if rcfg is None:
            rcfg = RuntimeConfig()
        if rcfg.admission not in ("fused", "reference"):
            raise ValueError(
                f"RuntimeConfig.admission must be 'fused' or 'reference', "
                f"got {rcfg.admission!r}")
        if rcfg.scheduler not in ("event", "dense"):
            raise ValueError(
                f"RuntimeConfig.scheduler must be 'event' or 'dense', "
                f"got {rcfg.scheduler!r}")
        if rcfg.analysis not in ("off", "warn", "strict"):
            raise ValueError(
                f"RuntimeConfig.analysis must be 'off', 'warn' or 'strict', "
                f"got {rcfg.analysis!r}")
        self.machine = machine
        self.policy = policy
        self.rcfg = rcfg
        self.tools = tools
        self.rng = np.random.default_rng(rcfg.seed)
        self.engine = engine
        # speculative reasoning steps only exist where idle batch slots do:
        # batching must be on, and serial mode is the no-system baseline
        self._spec_on = (rcfg.spec_model_steps and rcfg.mode != "serial"
                         and rcfg.model_max_batch > 1)
        # tree assembly gets the full packed-table budget (rcfg.max_nodes
        # minus the MODEL join; two-segment assembly also reserves the
        # post-MODEL continuation's up-to-3 nodes): siblings must not eat
        # the spine's depth, and total nodes must stay inside the scorer's
        # packed N.  The chain baseline keeps the builder's historical bound.
        if rcfg.assembly == "tree":
            builder_nodes = (max(rcfg.max_nodes - 4, 1) if self._spec_on
                             else rcfg.max_nodes - 1)
        else:
            builder_nodes = HypothesisBuilder.max_nodes
        self.builder = HypothesisBuilder(
            engine, tools=tools, assembly=rcfg.assembly,
            max_nodes=builder_nodes,
            spec_steps=self._spec_on and rcfg.assembly == "tree")
        self.scorer = Scorer(machine, lam=rcfg.lam, mu=rcfg.mu,
                             k_max=rcfg.beam_k, n_max=rcfg.max_nodes)
        self.metrics = Metrics()
        self.episodes = [EpisodeState(ep, AgentState()) for ep in episodes]
        for i, es in enumerate(self.episodes):
            es.idx = i
        self._eid2idx = {es.ep.eid: es.idx for es in self.episodes}
        # ---- event-driven tick state (scheduler="event") -------------
        # Dirty-sets index EPISODES (by es.idx); marks are recorded
        # unconditionally in both modes (set adds are cheap, and an extra
        # mark is always safe — the bug class to defend against is a
        # MISSING mark, which would leave an episode's cached beam /
        # frontier stale while the dense loop would have rebuilt it).
        self._event = rcfg.scheduler == "event"
        self._dirty: set = set()       # beam/frontier caches need rebuild
        self._acting: set = set()      # pending authoritative action to match
        self._auth_idx: set = set()    # non-empty auth_queue
        self._n_serving = 0            # episodes in phases other than
                                       # init/done (replaces the O(c)
                                       # _launch_wave scan)
        # per-episode phase-4 caches, rebuilt only for dirty episodes:
        # _frontiers[i] = [(hr, frontier_indices)] over ALL active branches
        # with a non-empty launch frontier; _contrib[i] = the idle subset
        # formatted as shared-pool entries; _nact[i] = active-branch count
        # (the beam-occupancy contribution)
        self._frontiers: Dict[int, List[Tuple[HypRun, List[int]]]] = {}
        self._contrib: Dict[int, List[Tuple[EpisodeState, HypRun, List[int]]]] = {}
        self._nact: Dict[int, int] = {}
        self._n_active_tot = 0
        self._spec_idx: set = set()    # episodes with cached frontiers
        self._pool_idx: set = set()    # episodes contributing pool candidates
        # runtime-GLOBAL result store: one cache spans every episode/tenant,
        # so a tenant at saturation is served from a sibling's warm
        # speculation (speculative value decoupled from speculative
        # execution).  Inert in serial mode — serial is the no-system
        # baseline, caching is part of the speculation machinery.
        self.store = ResultStore()
        self._memo_on = rcfg.memo and rcfg.mode != "serial"
        self._rho_cache: Dict[int, np.ndarray] = {}   # hid -> static prefix_rho
        self._pack_rows: Dict[int, tuple] = {}        # hid -> pack_beam row set
        # (hid, frozenset(excl)) -> memo-excluded prefix_rho: a pure function
        # of the immutable hypothesis and the exclusion set, so entries never
        # go stale — the memo pass otherwise re-runs the prefix DP for every
        # partially-memoized candidate every tick (top profile entry at c≫1)
        self._rho_excl_cache: Dict[tuple, np.ndarray] = {}
        self._cap = machine.cap_array()               # Machine is frozen
        self._wave_ptr = 0
        # shared-beam incremental packing: ONE PackedBeam cache for the
        # pooled cross-episode candidate beam (hids are globally unique —
        # a single builder numbers every episode's hypotheses)
        self._packed_beam: Optional[PackedBeam] = None
        self._packed_sig: Optional[Tuple] = None
        # admission warm-start (rcfg.warm_admit): the last full pass's
        # decision signature + admitted {hid: eu}.  No explicit
        # invalidation needed — the signature re-verifies every decision
        # input on each pass, so staleness can only produce a miss.
        self._warm_sig: Optional[Tuple] = None
        self._warm_admitted: Optional[Dict[int, float]] = None
        # per-hid static-gain-term cache for the host admission path (the
        # warm-start's sub-signature level: raw terms are hypothesis-
        # intrinsic, so they survive pool-membership churn that misses the
        # full signature).  Values never go stale — hids are unique and
        # hypotheses immutable — so like _pack_rows it is only size-bounded.
        self._static_rows: Dict[int, Tuple] = {}
        self._arrival_timer: Optional[SimJob] = None
        # open-loop episode source: a lazy iterator of Episodes with
        # nondecreasing arrivals (workload.open_loop_source) drained into
        # the roster mid-run — the runtime admits tenants as they ARRIVE
        # instead of from a frozen list.  None (the default) is the frozen
        # closed-loop roster, bit-identical to the pre-source code.
        self._source: Optional[Iterator[Episode]] = (
            iter(episode_source) if episode_source is not None else None)
        self.sim = Simulator(machine, self._tick,
                             record_log=rcfg.record_log,
                             recorder=rcfg.trace)
        self.sim.drain_probe = self._drain_pending
        # batched model-step service: owns the model-step queue (the sole
        # authoritative path on an accel-bound edge box).  max_batch=1 is a
        # synchronous pass-through, bit-identical to spawning solo jobs here.
        self.model_service = ModelStepService(
            self.sim, tools["model_step"].rho.as_array(),
            max_batch=rcfg.model_max_batch, linger=rcfg.model_batch_linger,
            marginal=rcfg.model_batch_marginal, metrics=self.metrics,
            adaptive=rcfg.adaptive_linger,
        )
        # live speculative reasoning steps, keyed by tenant eid — settled
        # (removed) exactly once each via _settle_spec_step
        self._spec_steps: Dict[int, List[SpecStep]] = {}
        # construction-time static safety pass (core/analysis.py R1-R3):
        # pure — dry-runs on throwaway state, no RNG, no hypothesis ids —
        # so it cannot perturb a single scheduling decision.  R4 (barrier
        # placement) needs assembled beams and runs via the CLI instead.
        if rcfg.analysis != "off":
            self.analysis_report = analyze_static(policy, engine)
            errs = self.analysis_report.errors()
            if errs:
                if rcfg.analysis == "strict":
                    raise AnalysisError(self.analysis_report)
                import warnings
                warnings.warn(
                    f"speculation-safety analysis found {len(errs)} error "
                    f"finding(s):\n" + "\n".join(f"  {f}" for f in errs),
                    RuntimeWarning, stacklevel=2)
        else:
            self.analysis_report = None
        self.sanitizer = (RuntimeSanitizer(self, every=rcfg.sanitize_every)
                          if rcfg.sanitize else None)

    # ==================================================================
    def run(self) -> Metrics:
        self._launch_wave()
        self.sim.run()
        self.metrics.truncated = self.sim.truncated is not None
        self.metrics.makespan = self.sim.now
        self.metrics.serial_reference = sum(
            es.ep.serial_latency(self.tools) for es in self.episodes
        )
        # settle branches still alive at simulation end: _squash_one books
        # their burn into spec/wasted exactly once (same path as mid-run
        # squashes), so wasted_frac stays <= 1 by construction
        for es in self.episodes:
            self._squash_all(es)
        self.metrics.memo_invalidations = self.store.invalidations
        self.metrics.memo_entries = len(self.store)
        return self.metrics

    def _mark_dirty(self, es: EpisodeState):
        """Wake an episode for the next phase-4 beam/frontier rebuild.
        Called unconditionally (both schedulers): a stray mark costs one
        set-add, a missing one leaves a stale cache.  Also advances the
        episode's epoch, invalidating every cached arg resolution — a spare
        bump costs one re-resolve, a missing one serves stale args."""
        es.epoch += 1
        if es.idx >= 0:
            self._dirty.add(es.idx)

    def _mark_dirty_eid(self, eid):
        i = self._eid2idx.get(eid)
        if i is not None:
            self._mark_dirty(self.episodes[i])

    def _pump_source(self):
        """Drain the open-loop episode source of every episode that has
        ARRIVED, plus one future head for the arrival timer to park on.
        Arrivals are nondecreasing, so the newest materialized episode
        having a future arrival means every still-lazy one does too — the
        roster then holds the complete arrived-but-unlaunched backlog
        (the load-shedding signal) at all times."""
        while self._source is not None:
            if (self._wave_ptr < len(self.episodes)
                    and self.episodes[-1].ep.arrival > self.sim.now + 1e-9):
                break
            ep = next(self._source, None)
            if ep is None:
                self._source = None
                break
            es = EpisodeState(ep, AgentState())
            es.idx = len(self.episodes)
            self.episodes.append(es)
            self._eid2idx[ep.eid] = es.idx

    def _arrival_backlog(self) -> int:
        """Arrived-but-unlaunched tenants — the overload pressure the
        shedding ladder keys on.  Episodes are in arrival order, so the
        count is a prefix scan from the wave pointer."""
        n = 0
        for i in range(self._wave_ptr, len(self.episodes)):
            if self.episodes[i].ep.arrival > self.sim.now + 1e-9:
                break
            n += 1
        return n

    def _launch_wave(self):
        self._pump_source()
        # incremental serving count (clamped: unit tests drive episodes
        # through _finish_action without ever launching them here)
        active = max(self._n_serving, 0)
        while (active < self.rcfg.max_concurrent_episodes
               and self._wave_ptr < len(self.episodes)):
            es = self.episodes[self._wave_ptr]
            arrival = getattr(es.ep, "arrival", 0.0)
            if arrival > self.sim.now + 1e-9:
                # staggered tenant hasn't arrived yet: park the wave and wake
                # at its arrival time (episodes are in arrival order)
                self._schedule_arrival(arrival)
                break
            self._wave_ptr += 1
            es.t_start = self.sim.now
            es.phase = "reasoning"
            self._n_serving += 1
            self._mark_dirty(es)
            self._start_model_step(es)
            active += 1
        else:
            # open-loop serving at capacity: keep the arrival timer armed on
            # the next FUTURE arrival anyway, so the source keeps
            # materializing (and the backlog signal stays fresh) while every
            # slot is busy.  Closed-loop rosters (no source) take the legacy
            # quiet path — no extra timer jobs, bit-identical schedules.
            if self._source is not None:
                for i in range(self._wave_ptr, len(self.episodes)):
                    arrival = self.episodes[i].ep.arrival
                    if arrival > self.sim.now + 1e-9:
                        self._schedule_arrival(arrival)
                        break

    def _schedule_arrival(self, t: float):
        """Zero-demand wake-up timer for the next pending tenant arrival —
        the event-driven sim would otherwise go quiescent (or never see the
        arrival) whenever no job completion lands between now and ``t``.
        Zero demand means no interference and no QoS sample pollution (the
        ``timer`` meta flag excludes it from slowdown attribution)."""
        if (self._arrival_timer is not None
                and self._arrival_timer.jid in self.sim.running):
            return                        # a timer for this arrival is live
        def fire(sim: Simulator, job: SimJob):
            self._arrival_timer = None
            self._launch_wave()
        self._arrival_timer = self.sim.new_job(
            "arrival_timer", np.zeros(RESOURCE_DIMS),
            max(t - self.sim.now, 1e-9), speculative=False,
            on_complete=fire, meta={"timer": True},
        )
        self.sim.start(self._arrival_timer)

    # ==================================================================
    # episode driving (authoritative path)
    # ==================================================================
    def _start_model_step(self, es: EpisodeState):
        """Enqueue the episode's next reasoning step into the model-step
        service.  Under ``model_max_batch=1`` the service dispatches a solo
        job synchronously (same name/demand/work as the pre-service code);
        with batching on, the step may coalesce with other tenants' steps
        into one micro-batched model invocation.

        Speculative reasoning steps validate ON ARRIVAL here: a live
        speculative step whose assumed history matches the authoritative one
        replaces this submit entirely (its batch already computed — or is
        computing, or will compute — the very step the agent is asking
        for); divergent predictions squash before anything dispatches."""
        step = es.ep.steps[es.step_idx]
        if self._spec_on and self._consume_spec_steps(es, step):
            return

        def done(sim: Simulator, job: SimJob):
            self._on_reasoning_done(es)

        self.model_service.submit(ModelStepRequest(
            eid=es.ep.eid, name=f"model[e{es.ep.eid}.{es.step_idx}]",
            work=step.model_work, on_done=done,
            batchable=step.batchable,
        ))

    def _on_reasoning_done(self, es: EpisodeState):
        step = es.ep.steps[es.step_idx]
        es.pending_action = (step.tool, dict(step.args))
        es.phase = "acting"
        if es.idx >= 0:
            self._acting.add(es.idx)
        self._mark_dirty(es)
        # Phase 1 happens inside the tick that follows this completion.

    # ==================================================================
    # speculative reasoning steps (RuntimeConfig.spec_model_steps)
    # ==================================================================
    @staticmethod
    def _enc_call(tool: str, result) -> Tuple:
        """Canonical encoding of one authoritative tool invocation for
        validate-on-arrival comparison.  Deliberately (tool, result) and
        NOT args: authoritative events carry the step's full argument dict
        while spine nodes resolve only the binding subset the pattern
        mined, so arg equality is unobtainable even on a perfectly
        followed spine.  Tool results embed the arguments that shaped
        them (and on the reuse path the event's result IS the node's
        result object), so (tool, repr(result)) is the discriminating
        fingerprint; a false accept can only mis-credit latency — the
        reasoning outcome itself is read from the authoritative script."""
        return (tool, repr(result))

    def _enc(self, e: Event) -> Tuple:
        return self._enc_call(e.tool, e.result)

    def _submit_spec_step(self, es: EpisodeState, hr: HypRun, i: int) -> bool:
        """Offer a branch's next reasoning boundary to an idle slot of the
        forming batch.

        The agent reasons after EVERY action, so the draft targets the
        deepest boundary the branch can currently vouch for: build context
        plus the longest materialized prefix of the spine (``full`` when
        that prefix is the whole spine — the MODEL join itself, whose
        landing unlocks segment 2).  Fires only when the service reports a
        free slot — passengers never open windows or delay dispatch.  The
        ``assumed`` history is frozen at submit time; everything after is
        validate-on-arrival."""
        nr = hr.node_runs[i]
        if (not self._spec_on or nr.status != "pending"
                or i != hr.hyp.model_idx):
            return False
        if len(es.history) < hr.base_len:
            return False          # build-context action still in flight
        if not self.model_service.spec_slot_free:
            return False
        assumed = [self._enc(e) for e in es.history[:hr.base_len]]
        full = True
        for j in hr.path_to(i)[:-1]:
            p = hr.node_runs[j]
            if p.node.kind != NodeKind.TOOL:
                continue
            if p.status not in ("done", "reused", "promoted"):
                full = False      # prefix ends: result not materialized
                break             # (missing args or a still-running node)
            assumed.append(self._enc_call(p.run_tool, p.result))
        actual = [self._enc(e) for e in es.history]
        n = len(actual)
        if not (len(assumed) > n and assumed[:n] == actual):
            return False          # no unconsumed boundary (or divergent)
        live = self._spec_steps.get(es.ep.eid, ())
        if any(ss.assumed == assumed for ss in live):
            return False          # this boundary is already drafted
        work = self.tools["model_step"].base_latency
        ss = SpecStep(es=es, hr=hr, i=i, assumed=assumed, work=work,
                      eu=hr.eu, full=full)

        def spec_done(sim: Simulator, job: SimJob, ss=ss):
            if ss.outcome:
                return            # settled mid-flight (squash/prune)
            ss.landed = True
            es2 = ss.es
            nr2 = ss.hr.node_runs[ss.i]
            if ss.pending_auth:
                # the authoritative step validated against this passenger
                # while its batch was mid-flight: this completion IS the
                # reasoning step — credit the remaining-work saving
                self._settle_spec_step(ss, "accepted",
                                       saved=ss.pending_saved)
                if ss.full and nr2.status == "pending":
                    nr2.status = "reused"
                self._mark_dirty(es2)
                self._on_reasoning_done(es2)
                return
            if ss.hr.status != "active":
                self._settle_spec_step(ss, "squashed")
                return
            if ss.full and nr2.status == "pending":
                # the MODEL join's own reasoning outcome materialized: the
                # post-MODEL segment becomes launchable (frontier ready on
                # done|reused).  Partial-boundary drafts stay live for
                # validation but never open segment 2 — their context is
                # not the join's.
                nr2.status = "done"
                self._mark_dirty(es2)

        def on_evict(ss=ss):
            self._settle_spec_step(ss, "evicted")

        ticket = SpecStepTicket(eid=es.ep.eid, work=work, eu=hr.eu,
                                on_done=spec_done, on_evict=on_evict)
        ss.ticket = ticket
        if not self.model_service.submit_speculative(ticket):
            return False
        self._spec_steps.setdefault(es.ep.eid, []).append(ss)
        self.metrics.spec_steps_submitted += 1
        # nr.status stays "pending": the node is a reusable drafting handle
        # — deeper boundaries are drafted as more of the spine materializes
        # (the per-boundary dedup above prevents duplicates).
        return True

    def _consume_spec_steps(self, es: EpisodeState, step) -> bool:
        """Validate-on-arrival against the live speculative steps when the
        agent reaches a reasoning step.  Dead predictions (assumed history
        neither equal to nor a strict extension of the authoritative one)
        squash immediately; the best hit — completed beats mid-flight beats
        still-forming — replaces the authoritative submit.  Returns True
        iff the submit was replaced."""
        live = self._spec_steps.get(es.ep.eid)
        if not live:
            return False
        actual = [self._enc(e) for e in es.history]
        n = len(actual)
        rank = {"done": 0, "inflight": 1, "forming": 2}
        hit: Optional[SpecStep] = None
        for ss in list(live):
            if ss.assumed == actual:
                if hit is None or rank[ss.stage] < rank[hit.stage]:
                    hit = ss
            elif not (len(ss.assumed) > n and ss.assumed[:n] == actual):
                self._settle_spec_step(ss, "squashed")
        if hit is None:
            return False
        nr = hit.hr.node_runs[hit.i]
        if hit.stage == "done":
            # the predicted step already computed: zero-latency reuse
            self._settle_spec_step(hit, "accepted", saved=step.model_work)
            if hit.full and nr.status in ("pending", "done"):
                nr.status = "reused"
                self._mark_dirty(es)
            self._on_reasoning_done(es)
            return True
        if hit.stage == "inflight":
            job = hit.ticket.dispatched
            remaining = max(self.sim.settled_remaining(job), 0.0)
            if remaining >= step.model_work:
                # waiting out the batch would cost more than dispatching
                # fresh: not a win — leave the passenger to settle on its
                # own (it goes dead once this step's action lands)
                return False
            hit.pending_auth = True
            hit.pending_saved = step.model_work - remaining
            return True
        # still forming: the passenger becomes a regular member of the SAME
        # forming batch (authoritative submit path — may fill-trigger);
        # nothing was saved, but nothing was wasted either

        def done(sim: Simulator, job: SimJob):
            self._on_reasoning_done(es)

        ticket = hit.ticket
        self._settle_spec_step(hit, "accepted", saved=0.0)
        if hit.full and nr.status == "pending":
            nr.status = "reused"
            self._mark_dirty(es)
        self.model_service.promote_spec(ticket, ModelStepRequest(
            eid=es.ep.eid, name=f"model[e{es.ep.eid}.{es.step_idx}]",
            work=step.model_work, on_done=done,
            batchable=step.batchable,
        ))
        return True

    def _settle_spec_step(self, ss: SpecStep, outcome: str,
                          saved: float = 0.0) -> None:
        """Book one speculative step's terminal outcome exactly once.

        Waste invariant: a dispatched passenger's work enters
        ``spec_solo_seconds`` whatever its fate (it was executed);
        squash-after-dispatch adds the SAME work to ``wasted_solo_seconds``
        — so wasted_frac <= 1 holds by construction.  Forming-stage
        terminals (evicted, or squashed before dispatch) book nothing:
        no cycles were burned."""
        if ss.outcome:
            return
        stage = ss.stage          # capture before mutating
        ss.outcome = outcome
        live = self._spec_steps.get(ss.es.ep.eid)
        if live is not None and ss in live:
            live.remove(ss)
        dispatched = (ss.ticket is not None
                      and ss.ticket.dispatched is not None)
        if outcome == "accepted":
            self.metrics.spec_steps_accepted += 1
            self.metrics.spec_step_saved_seconds += saved
            if dispatched:
                self.metrics.spec_solo_seconds += ss.work
            return
        if outcome == "squashed":
            self.metrics.spec_steps_squashed += 1
            if stage == "forming" and ss.ticket is not None:
                self.model_service.withdraw_spec(ss.ticket)
            elif dispatched:
                self.metrics.spec_solo_seconds += ss.work
                self.metrics.wasted_solo_seconds += ss.work
        else:                     # evicted (service already dropped ticket)
            self.metrics.spec_steps_evicted += 1
        # non-accepted terminal: nothing to revert — the MODEL node stayed
        # "pending" while drafting, so a still-active branch resubmits on
        # the next frontier pass (counted as a new submission).

    def _finish_action(self, es: EpisodeState, result: Any, t_start: float):
        """``t_start`` is the action's WALL start time (``job.started_at``) —
        ``now - solo_work`` understated the start under co-run interference
        (stretched jobs span more wall time than their solo work) and was
        plain wrong for promoted jobs, which started before the agent asked."""
        self._mark_dirty(es)
        step = es.ep.steps[es.step_idx]
        ev = Event("tool", step.tool, dict(step.args), result,
                   t_start, self.sim.now, es.ep.eid)
        es.history.append(ev)
        es.state.history.append(ev)
        es.pending_action = None
        es.inflight = None
        self.metrics.auth_actions += 1
        keep = es.matched_hr
        es.matched_hr = None
        writes = getattr(es, "last_writes", set()) or set()
        self._prune_beam(es, es.history, keep=keep, writes=writes)
        es.last_writes = set()
        es.step_idx += 1
        if es.step_idx >= len(es.ep.steps):
            es.phase = "done"
            self._n_serving = max(0, self._n_serving - 1)
            es.t_end = self.sim.now
            self.metrics.episode_latencies.append(es.t_end - es.t_start)
            self.metrics.tenant_latency[es.ep.eid] = es.t_end - es.t_start
            # sojourn counts from ARRIVAL: a tenant that queued for a slot
            # waited that long too, and the service-only latency above would
            # hide it (dominant under staggered multi-tenant load)
            self.metrics.tenant_sojourn[es.ep.eid] = (
                es.t_end - getattr(es.ep, "arrival", 0.0))
            self._squash_all(es)
            self._launch_wave()
        else:
            es.phase = "reasoning"
            self._start_model_step(es)

    # shared with the builder so PREP insertion and the warm-up discount
    # can never disagree on what counts as a cold tool
    COLD_TOOLS = COLD_TOOLS

    def _start_auth_tool(self, es: EpisodeState, tool: str, args: Dict[str, Any]):
        spec = self.tools[tool]
        es.inflight = (tool, dict(args))
        dur = spec.det_latency(args)
        if tool in self.COLD_TOOLS and self.sim.now <= es.warm_until:
            dur *= self.rcfg.warm_discount    # preparation-node payoff

        def done(sim: Simulator, job: SimJob):
            fac = StateFacade(es.state)
            result = execute_tool(tool, args, fac)
            if self.sanitizer is not None:
                self.sanitizer.check_footprint(tool, fac, f"auth e{es.ep.eid}")
            es.last_writes = set(fac.writes)
            if spec.level >= SafetyLevel.STAGED_WRITE:
                es.state.bump()
            self._publish_result(fac, tool, args, result, es.ep.eid)
            self._finish_action(es, result, job.started_at or 0.0)

        job = self.sim.new_job(
            f"{tool}[e{es.ep.eid}.{es.step_idx}]", spec.rho.as_array(), dur,
            speculative=False, on_complete=done, meta={"eid": es.ep.eid},
        )
        es.auth_queue.append(job)
        if es.idx >= 0:
            self._auth_idx.add(es.idx)
        self._mark_dirty(es)

    # ==================================================================
    # Phase 1: confirm / promote
    # ==================================================================
    def _pseudo_history(self, es: EpisodeState, hr: HypRun, upto: int) -> List[Event]:
        """The build-time history prefix extended with the branch's executed
        TOOL results along the root-to-node path before node index `upto` —
        the view against which late bindings resolve.  Path-based, not
        list-prefix: sibling subtrees are alternative futures and must not
        leak into this node's event stream.  Truncating to ``base_len``
        keeps mined source offsets aligned for carried-over branches (an
        in-flight event at build time lands inside the prefix, so its real
        result materializes; later events must not shift the tail)."""
        hist = list(es.history[:hr.base_len])
        if len(hist) < hr.base_len and es.inflight is not None:
            # the hypothesis was built across an in-flight action that has
            # not landed yet: restore the build-time placeholder so mined
            # offsets stay aligned — bindings that target it resolve None
            # (lazily, post-landing) instead of hitting the wrong event
            t, a = es.inflight
            hist.append(Event("tool", t, dict(a), None))
        for j in hr.path_to(upto)[:-1]:
            p = hr.node_runs[j]
            if p.node.kind == NodeKind.TOOL and p.status in ("done", "reused", "promoted")                     and p.result is not None:
                hist.append(Event("tool", p.run_tool, dict(p.resolved_args), p.result))
        return hist

    def _resolve_node_args(self, es: EpisodeState, hr: HypRun, i: int) -> Dict[str, Any]:
        nr = hr.node_runs[i]
        hist = self._pseudo_history(es, hr, i)
        args = {b.arg_name: b.resolve(hist) for b in nr.node.bindings}
        return {k: v for k, v in args.items() if v is not None}

    def _cached_node_args(self, es: EpisodeState, hr: HypRun, i: int) -> Dict[str, Any]:
        """Epoch-guarded arg resolution: between dirtying events of an
        episode every `_pseudo_history` input is frozen (history prefix is
        append-only under ``base_len``, inflight and path node results only
        change through handlers that ``_mark_dirty``), so the resolution is
        a pure function of (hr, i, es.epoch).  The admission memo pass
        re-resolves every frontier binding every tick — under c≫1 tenants
        this cache turns that from the top profile entry into a dict hit.
        Callers that PERSIST the dict must copy it (the cache owns this one)."""
        nr = hr.node_runs[i]
        if nr.args_epoch == es.epoch and nr.args_cache is not None:
            return nr.args_cache
        args = self._resolve_node_args(es, hr, i)
        nr.args_epoch = es.epoch
        nr.args_cache = args
        return args

    # Phase-1 match preference: a completed speculative result beats a
    # running one beats an unstarted node.  With a wide beam several
    # branches can contain the same tool; first-in-list order would let an
    # early pending match shadow a finished result in a later branch.
    _MATCH_RANK = {"done": 0, "running": 1, "pending": 2}

    def _match_action(self, es: EpisodeState, tool: str, args: Dict[str, Any]):
        best = None
        for hr in es.hyp_runs:
            if hr.status != "active":
                continue
            for i, nr in enumerate(hr.node_runs):
                if nr.node.kind != NodeKind.TOOL or nr.run_tool != tool:
                    continue
                if nr.transformed:
                    continue                      # transformed results aren't a full match
                if nr.status not in self._MATCH_RANK:
                    continue
                prior_done = all(
                    hr.node_runs[j].status in ("done", "reused")
                    for j in hr.path_to(i)[:-1]
                    if hr.node_runs[j].node.kind == NodeKind.TOOL
                )
                if nr.status == "pending":
                    if not prior_done:
                        continue
                    cand_args = self._cached_node_args(es, hr, i)
                    if any(cand_args.get(k) != v for k, v in args.items() if k in cand_args):
                        continue              # resolved args contradict
                elif nr.resolved_args != args:
                    continue
                rank = self._MATCH_RANK[nr.status]
                if best is None or rank < best[0]:
                    best = (rank, hr, i, nr)
                if rank == 0:
                    return hr, i, nr
        if best is None:
            return None
        return best[1], best[2], best[3]

    def _drain_pending(self) -> bool:
        """``Simulator.run`` drain probe: True while some episode holds a
        pending authoritative action that only the next tick's phase 1 can
        dispatch.  A completion cascade can strand one with an EMPTY event
        queue — an instant store-serve chains into a validate-on-arrival
        spec-step acceptance, whose reasoning completes at the same
        timestamp without ever creating a sim job — so quiescence must be
        judged against this parked work, not just the queue."""
        if self._event:
            return bool(self._acting)
        return any(es.phase == "acting" and es.pending_action is not None
                   for es in self.episodes)

    def _phase1(self):
        """Confirm / promote (Algorithm 1 phase 1): match each episode's
        pending authoritative action against its speculative beam.  A DONE
        node is consumed at zero latency (commit the matched path, reuse the
        result); a RUNNING node is promoted to authoritative — unless a
        store entry can serve instantly, in which case the redundant run is
        preempted; a ready PENDING node reuses its prefix state and is
        served or executed from the boundary; a MISS settles its
        consequences (contradiction squash, mis-speculation accounting),
        then serves from the cross-episode store or re-executes.

        Event scheduler: only episodes whose reasoning step completed since
        the last tick (the ``_acting`` wakeup set) are examined — an episode
        can only have a pending action if ``_on_reasoning_done`` fired for
        it, and that is exactly where the set is fed.  Dense mode scans all
        episodes; both orders are ascending episode index, so the match /
        commit / store-serve sequence is identical."""
        if self._event:
            woken = sorted(self._acting)
            self._acting.clear()
            targets = [self.episodes[i] for i in woken]
        else:
            targets = self.episodes
        for es in targets:
            if es.phase != "acting" or es.pending_action is None:
                continue
            self._mark_dirty(es)
            tool, args = es.pending_action
            m = self._match_action(es, tool, args)
            if m is None:
                # beam miss: settle the miss consequences first (contradicted
                # branches squash, mis-speculation accounting, chain-mode
                # beam wipe) — they depend on the ACTION, not on how it gets
                # satisfied — then try the cross-episode result store: a
                # valid entry (any tenant's warm speculation or past
                # authoritative run) serves the action at zero execution
                # cost, else re-execute authoritatively
                self._note_misses(es, tool, args)
                entry = self._try_serve(es, tool, args)
                es.pending_action = None
                es.phase = "executing"
                if entry is not None:
                    self._finish_action(es, entry.result, self.sim.now)
                else:
                    self._start_auth_tool(es, tool, args)
                continue
            hr, i, nr = m
            hr.used = True
            es.matched_hr = hr
            if nr.status == "done":
                # reuse: commit state along the matched path, zero extra latency
                self._commit_path(es, hr, i)
                self.metrics.reuses += 1
                if i > 0:
                    self.metrics.prefix_reuses += 1
                es.phase = "executing"
                es.pending_action = None
                self._finish_action(es, nr.result, self.sim.now)
            elif nr.status == "running" and nr.job is not None:
                # the prefix state is valid either way (promotion would
                # commit it at completion; replay is idempotent) — commit it
                # FIRST so the serve validates against the post-prefix live
                # state its read footprint may depend on.  The honest
                # counterfactual here is PROMOTION, which would only have
                # cost the job's REMAINING solo work — not the full latency
                self._commit_path(es, hr, i, inclusive=False)
                # lazy settlement: the raw ``remaining`` field of a running
                # job is only current as of its last rate change
                entry = self._try_serve(
                    es, tool, args,
                    saved=max(self.sim.settled_remaining(nr.job), 0.0))
                if entry is not None:
                    # a sibling's entry landed while our copy was mid-flight:
                    # serving is instant, so the run is redundant — preempt
                    # it (partial burn settles as waste, same as a squash)
                    # and consume the node coherently
                    job = nr.job
                    self.sim.preempt(job.jid)
                    self.store.abort(job.meta.get("memo_key"), job.jid)
                    self.metrics.spec_solo_seconds += job.executed_solo_seconds
                    self.metrics.wasted_solo_seconds += job.executed_solo_seconds
                    nr.job = None
                    nr.result = entry.result
                    # consumed by the authoritative path: counts as invested
                    # work in carry-over (the prediction was VALIDATED — the
                    # served flag marks unconsumed sandbox serves only)
                    nr.status = "reused"
                    es.phase = "executing"
                    es.pending_action = None
                    self._finish_action(es, entry.result, self.sim.now)
                    continue
                # promote: job becomes authoritative, non-preemptible (via
                # the simulator API so the incremental auth/spec demand
                # split stays coherent)
                self.sim.set_speculative(nr.job, False)
                nr.status = "promoted"
                self.metrics.promotions += 1
                es.phase = "executing"
                es.pending_action = None
                hr_ref, i_ref = hr, i

                def on_promoted(sim: Simulator, job: SimJob, es=es, hr=hr_ref, i=i_ref):
                    nr2 = hr.node_runs[i]
                    self._snapshot(hr, nr2)
                    self._commit_path(es, hr, i)
                    self._finish_action(es, nr2.result, job.started_at or 0.0)

                nr.job.meta["promoted_for"] = es.ep.eid
                # chain our completion behind the existing callback
                orig = nr.job.on_complete

                def chained(sim, job, orig=orig, hook=on_promoted):
                    if orig:
                        orig(sim, job)
                    hook(sim, job)

                nr.job.on_complete = chained
            else:
                # valid path prefix done, node not started: reuse its state
                # and continue authoritatively from the boundary — served
                # from the store when a valid entry exists (the node was
                # predicted but never launched, e.g. at saturation there is
                # no slack to launch with; the entry consumes it coherently
                # so descendants keep their pseudo-history), else executed
                self._commit_path(es, hr, i, inclusive=False)
                self.metrics.prefix_reuses += 1
                es.phase = "executing"
                es.pending_action = None
                entry = self._try_serve(es, tool, args)
                if entry is not None:
                    nr.result = entry.result
                    # consumed: invested for carry-over purposes (validated
                    # prediction), unlike unconsumed sandbox serves
                    nr.status = "reused"
                    self._finish_action(es, entry.result, self.sim.now)
                else:
                    self._start_auth_tool(es, tool, args)

    def _try_serve(self, es: EpisodeState, tool: str, args: Dict[str, Any],
                   saved: Optional[float] = None) -> Optional[MemoEntry]:
        """Cache-serve path: satisfy an authoritative action from a valid
        result-store entry at zero execution cost.  A finished branch match
        always wins over the store (it commits richer path state); a miss
        settles its consequences (``_note_misses``) before serving; a
        matched running/pending node commits its prefix first, then prefers
        the instant serve over promotion / authoritative re-execution —
        at saturation nothing launches, so predicted nodes sit pending and
        the store is the only mechanism that can still satisfy them.

        Safety gating lives in the policy (``EligibilityPolicy.servable``):
        PREP/READ_ONLY entries serve directly; STAGED_WRITE entries serve by
        replaying the stored write overlay through the commit barrier onto
        the live state — version bump, conflict-prune write-set, and
        footprint invalidation exactly as execution would have produced.
        Validation is by VALUE over the entry's read footprint against THIS
        tenant's live state (entries are produced by any tenant; per-key
        value equality is what makes cross-episode serving exact)."""
        if not self._memo_on:
            return None
        how = self.policy.servable(tool)
        if how is None:
            return None
        entry = self.store.peek(tool, args)
        if entry is None:
            return None
        if not self.store.validate(entry, es.state, eid=es.ep.eid):
            return None
        wrote = self.store.apply_writes(entry, es.state)
        spec = self.tools[tool]
        if wrote or spec.level >= SafetyLevel.STAGED_WRITE:
            # served base mutations advance the version like executed ones
            es.state.bump()
        es.last_writes = set(getattr(es, "last_writes", set())) | wrote
        if wrote:
            self.store.note_writes(entry.writes)
        entry.serves += 1
        if saved is None:
            # counterfactual cost of executing this action authoritatively
            # (callers with a cheaper counterfactual — e.g. promotion of a
            # mid-flight run — pass their own ``saved``)
            saved = spec.det_latency(args)
            if tool in self.COLD_TOOLS and self.sim.now <= es.warm_until:
                saved *= self.rcfg.warm_discount
        self.metrics.memo_serves += 1
        self.metrics.memo_saved_seconds += saved
        self.metrics.tenant_memo_saved[es.ep.eid] = (
            self.metrics.tenant_memo_saved.get(es.ep.eid, 0.0) + saved)
        return entry

    def _publish_result(self, fac: StateFacade, run_tool: str,
                        args: Dict[str, Any], result: Any, eid: int,
                        note: bool = True) -> bool:
        """Store bookkeeping after one tool execution: footprint-intersection
        invalidation FIRST (live executions only — sandbox writes are not
        authoritative and must not invalidate anything), then a level-gated
        publish so the fresh entry carries the post-write store version.
        Returns whether an entry was published (pending-entry owners abort
        on False so subscribed twins can re-arm)."""
        if not self._memo_on:
            return False
        spec = self.tools[run_tool]
        if note:
            self.store.note_writes(fac.write_values)
        if result is None or spec.level >= SafetyLevel.NON_SPECULATIVE:
            return False
        self.store.publish(run_tool, dict(args), result,
                           reads=fac.reads, writes=fac.write_values,
                           level=spec.level,
                           solo_work=spec.det_latency(args), eid=eid)
        return True

    def _note_misses(self, es: EpisodeState, tool: str, args):
        if self.builder.assembly == "chain":
            # pre-tree baseline semantics: any miss wipes the whole beam
            # (rebuilt from scratch in Phase 4)
            for hr in es.hyp_runs:
                if hr.status == "active" and not hr.used and any(
                    nr.status in ("done", "running") and not nr.served
                    for nr in hr.node_runs
                ):
                    self.metrics.mis_speculations += 1
            self._squash_all(es)
            return
        # selective pruning: the context moved somewhere unpredicted, but a
        # branch still speculating toward a top prediction for the post-miss
        # context keeps its work (write-set invalidation happens in
        # _finish_action once the authoritative action lands its writes)
        hist = list(es.history) + [Event("tool", tool, dict(args))]
        self._prune_beam(es, hist, missed=(tool, dict(args)),
                         count_misses=True)

    def _prune_beam(self, es: EpisodeState, hist: List[Event],
                    keep: Optional[HypRun] = None, writes: set = frozenset(),
                    missed: Optional[Tuple[str, Dict[str, Any]]] = None,
                    count_misses: bool = False):
        """Shared keep-or-squash policy after the context advances (either an
        authoritative action finished, or a miss is about to start one).

        A branch is squashed when (a) authoritative ``writes`` intersect its
        base read-set (state safety), (b) it executed the ``missed`` tool
        with different args — it speculated this very action wrongly, so its
        invested work is proven garbage — or (c) it is neither built for the
        current context nor still speculating toward a top prediction
        (carry-over horizon matches what the builder would seed: merged
        backoff up to beam_k under tree assembly)."""
        # context tails at every backoff length the builder/engine can key
        # on — 1..engine.context_len, NOT a hard-coded 2: with a different
        # mining context length the builder stamps longer/shorter
        # context_keys, and comparing them against a 2-suffix misclassified
        # every carried-over branch (wrongly squashed or wrongly kept)
        self._mark_dirty(es)
        cl = max(self.engine.context_len, 1)
        tail = tuple(signature(e) for e in hist[-cl:])
        tails = {tail[-n:] for n in range(1, len(tail) + 1)} or {()}
        if self.builder.assembly == "tree":
            pred_pairs = self.engine.predict(hist, top=self.rcfg.beam_k,
                                             backoff="merge")
        else:
            pred_pairs = self.engine.predict(hist,
                                             top=self.builder.branch_factor)
        preds = {pt.tool for pt, _ in pred_pairs}
        for hr in list(es.hyp_runs):
            if hr.status != "active" or hr is keep:
                continue
            conflicted = bool(writes) and bool(hr.sandbox.base_read_set & writes)
            contradicted = missed is not None and any(
                nr.node.kind == NodeKind.TOOL and nr.run_tool == missed[0]
                and nr.status in ("done", "running")
                and nr.resolved_args != missed[1]
                for nr in hr.node_runs
            )
            if not (conflicted or contradicted):
                if hr.hyp.context_key in tails:
                    continue                  # built for this context
                if self._still_predicted(hr, preds):
                    continue
            if count_misses and not hr.used and any(
                nr.status in ("done", "running") and not nr.served
                for nr in hr.node_runs
            ):
                self.metrics.mis_speculations += 1
            self._squash_one(es, hr)
        es.hyp_runs = [hr for hr in es.hyp_runs if hr.status == "active"]

    def _still_predicted(self, hr: HypRun, preds: set) -> bool:
        """Carry-over test: does this branch still speculate toward a
        predicted tool?  Chains check their single next pending tool (the
        pre-tree baseline rule); trees check every un-finished tool node —
        but only branches with *executed* work (done/running/reused nodes)
        are worth a beam slot: a pristine stale branch would crowd out the
        fresh current-context tree that covers the same predictions."""
        pend = [nr for nr in hr.node_runs if nr.node.kind == NodeKind.TOOL
                and nr.status in ("pending", "running")]
        if not pend:
            return False
        if self.builder.assembly == "chain":
            return pend[0].run_tool in preds
        # store-served nodes are NOT investment: they cost nothing, and
        # counting them let pristine stale branches masquerade as invested,
        # crowding fresh current-context hypotheses out of the beam
        invested = any(nr.status in ("done", "running", "reused", "promoted")
                       and not nr.served for nr in hr.node_runs)
        return invested and any(nr.run_tool in preds for nr in pend)

    def _snapshot(self, hr: HypRun, nr: NodeRun):
        nr.snapshot = {
            "M": dict(hr.sandbox.M._overlay),
            "F": dict(hr.sandbox.F._overlay),
            "E": dict(hr.sandbox.E._overlay),
        }

    def _commit_path(self, es: EpisodeState, hr: HypRun, i: int,
                     inclusive: bool = True) -> None:
        """Promotion commit via *replay*: re-derive the executed results and
        staged effects along the matched root-to-node path against the LIVE
        state at zero latency (``inclusive=False`` stops at node i's parent).

        Path-based, not list-prefix: committing a branch must not replay
        sibling subtrees — those are alternative futures the agent did NOT
        take.  Tools are Level-1 replayable or Level-2 deterministic staged
        writes, so replay is exact; it also revalidates results when the
        base state advanced after the speculative run (sandbox.is_stale) —
        the paper's "replayable prefix" reuse semantics without
        stale-snapshot risk."""
        self._mark_dirty(es)          # node statuses flip to reused below
        fac = StateFacade(es.state)
        path = hr.path_to(i)
        if not inclusive:
            path = path[:-1]
        for j in path:
            nr = hr.node_runs[j]
            if nr.node.kind != NodeKind.TOOL or nr.status not in ("done", "promoted", "reused"):
                continue
            fac.begin_call()              # per-node footprint for the store
            try:
                nr.result = execute_tool(nr.run_tool, nr.resolved_args, fac)
            except KeyError:
                pass
            else:
                if self.sanitizer is not None:
                    self.sanitizer.check_footprint(
                        nr.run_tool, fac, f"commit e{es.ep.eid} h{hr.hyp.hid}")
                # the replay just validated this result against the LIVE
                # state — publish it for every tenant
                self._publish_result(fac, nr.run_tool, nr.resolved_args,
                                     nr.result, es.ep.eid)
            # a committed node is consumed by the authoritative path either
            # way; leaving promotions as "promoted" would strand their
            # descendants (the ready/prior-done tests require done|reused)
            if nr.status in ("done", "promoted"):
                nr.status = "reused"
        es.last_writes = set(getattr(es, "last_writes", set())) | set(fac.writes)
        es.state.bump()
        hr.sandbox.base_version = es.state.version

    def _squash_one(self, es: EpisodeState, hr: HypRun):
        """Squash a branch and settle its speculative-work accounting.

        Waste is NODE-granular: a node whose result was consumed by the
        authoritative path carries status reused/promoted; a node still
        "done" (or running) at squash time was executed and never consumed —
        that is wasted work even when a sibling subtree of the same branch
        was followed (tree hypotheses hedge, so branch-level `used` would
        hide the un-taken subtrees' cost).

        Invariant: every wasted_solo_seconds increment has a matching (>=)
        spec_solo_seconds contribution, so wasted_frac <= 1 by construction:
          * done nodes booked job.work into spec_solo at completion; waste
            books the same job.work here;
          * running nodes book their partial burn into BOTH here — their
            completion callback will never fire (accounting happens before
            any status mutation; the old code flipped running->pending first
            and left mid-flight burn out of spec_solo entirely)."""
        self._mark_dirty(es)
        hr.status = "squashed"
        hr.sandbox.squash()
        for nr in hr.node_runs:
            job = nr.job
            if job is None:
                continue
            if nr.status == "running":
                self.sim.preempt(job.jid)
                # the in-flight computation dies with the job: release the
                # store's pending entry so subscribed twins can re-arm
                self.store.abort(job.meta.get("memo_key"), job.jid)
                self.metrics.spec_solo_seconds += job.executed_solo_seconds
                self.metrics.wasted_solo_seconds += job.executed_solo_seconds
                nr.status = "pending"
            elif nr.status == "done":
                self.metrics.wasted_solo_seconds += job.work
            nr.job = None
        # live speculative reasoning steps die with their branch: forming
        # passengers withdraw from the service, dispatched ones settle their
        # burn as waste (their batch completion sees the terminal outcome
        # and ignores them)
        for ss in list(self._spec_steps.get(es.ep.eid, ())):
            if ss.hr is hr:
                self._settle_spec_step(ss, "squashed")

    def _squash_all(self, es: EpisodeState):
        # the compaction below rewrites hyp_runs even when nothing was
        # active to squash, so mark unconditionally (a spare mark costs one
        # set-add + epoch bump; every cached value recomputes identically)
        self._mark_dirty(es)
        for hr in es.hyp_runs:
            if hr.status == "active":
                self._squash_one(es, hr)
        es.hyp_runs = [hr for hr in es.hyp_runs if hr.status == "active"]

    # ==================================================================
    # Phase 2: protect authoritative jobs
    # ==================================================================
    def _phase2(self):
        """Preempt speculative work (ascending EU) on every resource dim that
        is oversubscribed AND where speculation actually contributes — a dim
        the authoritative set alone oversubscribes cannot be relieved by
        preemption, so it never justifies one.

        Event scheduler: queued authoritative jobs can only exist in
        episodes ``_start_auth_tool`` touched (the ``_auth_idx`` wakeup
        set), so the gather is O(|queued|) instead of O(c); index order
        matches the dense scan, so ``need`` sums in the same order."""
        if self._event:
            auth_pending = [j for i in sorted(self._auth_idx)
                            for j in self.episodes[i].auth_queue]
        else:
            auth_pending = [j for es in self.episodes for j in es.auth_queue]
        if not auth_pending:
            return
        need = np.sum([j.demand for j in auth_pending], axis=0)
        running_auth = self.sim.running_demand(speculative=False)
        cap = self._cap
        spec_jobs = sorted(
            (j for j in self.sim.running.values() if j.speculative),
            key=lambda j: j.meta.get("eu", 0.0),
        )
        while spec_jobs:
            spec_total = self.sim.running_demand(speculative=True)
            overload = (running_auth + need + spec_total) > cap + 1e-9
            relievable = overload & (spec_total > 1e-12)
            if not np.any(relievable):
                break
            victim = next(
                (j for j in spec_jobs if np.any(j.demand[relievable] > 0)), None
            )
            if victim is None:
                break
            spec_jobs.remove(victim)
            self.sim.preempt(victim.jid)
            self.store.abort(victim.meta.get("memo_key"), victim.jid)
            # the preempted job's partial burn is discarded (a relaunch
            # starts a fresh job), so settle it now: no completion callback
            # will ever claim it, and discarded progress is wasted work even
            # if the branch is eventually followed
            self.metrics.spec_solo_seconds += victim.executed_solo_seconds
            self.metrics.wasted_solo_seconds += victim.executed_solo_seconds
            # the victim's node reverts to pending: its episode's cached
            # launch frontier changed
            self._mark_dirty_eid(victim.meta.get("eid"))
            nr = victim.meta.get("node_run")
            if nr is not None:
                nr.status = "pending"
                nr.job = None

    # ==================================================================
    # Phase 3: run authoritative jobs (primary policy: FIFO, always fits)
    # ==================================================================
    def _phase3(self):
        """Run authoritative tool jobs (Algorithm 1 phase 3): drain each
        episode's queue FIFO.  Authoritative work always starts — Phase 2
        has already cleared any speculative oversubscription, and the
        interference model stretches rather than blocks.  Model steps do
        NOT pass through here: they are owned by the model-step service
        (``_start_model_step`` → ``ModelStepService.submit``), which
        dispatches solo or micro-batched authoritative jobs directly."""
        if self._event:
            woken = sorted(self._auth_idx)
            self._auth_idx.clear()
            for i in woken:
                es = self.episodes[i]
                while es.auth_queue:
                    self.sim.start(es.auth_queue.pop(0))
            return
        for es in self.episodes:
            while es.auth_queue:
                job = es.auth_queue.pop(0)
                self.sim.start(job)

    # ==================================================================
    # Phase 4: opportunistic branch scheduling
    # ==================================================================
    def _phase4(self):
        """Shared cross-episode admission: refresh every active episode's
        beam, pool the idle candidates, run ONE fused admission pass against
        the machine-global slack/budget.  Per-episode passes inside the same
        tick each measured slack *before* sibling episodes' admissions
        launched, so two tenants could both be admitted against the same
        slack (cross-tenant double-booking); a single pass accumulates the
        admitted demand across tenants inside the greedy loop.

        Event scheduler: beam refresh + frontier walks run only for DIRTY
        episodes (something they subscribe to fired since their last
        rebuild: a job/timer completion, a beam change, a memo publish
        consumed by one of their nodes, an authoritative action landing);
        clean episodes contribute their cached frontiers/pool entries.
        Slack needs no dirty tracking — it is sampled fresh inside every
        admission pass, which runs whenever the pooled beam is non-empty,
        so slack-threshold crossings are seen the tick they happen."""
        if self.rcfg.mode == "serial":
            self._dirty.clear()
            return
        if self._event:
            self._phase4_event()
            return
        pool: List[Tuple[EpisodeState, HypRun, List[int]]] = []
        n_active = 0
        for es in self.episodes:
            if es.phase not in ("reasoning", "executing"):
                continue
            if not es.history:
                continue
            self._refresh_beam(es)
            active = [hr for hr in es.hyp_runs if hr.status == "active"]
            n_active += len(active)
            # admission (re-)scores IDLE branches only: a branch with
            # running nodes was already admitted — its demand conditions
            # this pass via spec_rho, its meta_admitted persists, and
            # _launch_nodes keeps launching its ready siblings without
            # re-admission (scoring it again would double-charge its
            # in-flight demand against the packed prefix rho)
            for hr in active:
                if any(nr.status == "running" for nr in hr.node_runs):
                    continue
                fr = self._launch_frontier(es, hr)
                if fr:
                    pool.append((es, hr, fr))
        self._admit_shared(pool, n_active)
        if self.rcfg.race_mask or self.sanitizer is not None:
            self._check_write_races(pool)
        self._launch_nodes()

    def _phase4_event(self):
        """Dirty-set variant of the shared admission pass: O(dirty) rebuild
        + O(pool) admission instead of O(c) scans.  Per-episode caches
        (active-branch count, launch frontiers, pool candidacy) are rebuilt
        only for woken episodes; the pooled candidate list is then assembled
        from cache in ascending episode index — the exact order the dense
        scan produces, so packing signatures, fairness weights and greedy
        admission see identical inputs."""
        for i in sorted(self._dirty):
            self._rebuild_cache(i)
        self._dirty.clear()
        pool: List[Tuple[EpisodeState, HypRun, List[int]]] = []
        for i in sorted(self._pool_idx):
            pool.extend(self._contrib[i])
        self._admit_shared(pool, self._n_active_tot)
        if self.rcfg.race_mask or self.sanitizer is not None:
            self._check_write_races(pool)
        self._launch_nodes_event()

    def _rebuild_cache(self, i: int):
        """Recompute one episode's phase-4 contribution: refresh its beam,
        walk every active branch's launch frontier once (the walk also
        settles env_warmup no-ops, same as the dense loop's walk), and
        split the result into launchable caches — ALL branches with a
        frontier (``_frontiers``, what _launch_nodes_event retries each
        tick) and the idle subset (``_contrib``, the admission pool)."""
        es = self.episodes[i]
        frs: List[Tuple[HypRun, List[int]]] = []
        contrib: List[Tuple[EpisodeState, HypRun, List[int]]] = []
        nact = 0
        if es.phase in ("reasoning", "executing") and es.history:
            self._refresh_beam(es)
            for hr in es.hyp_runs:
                if hr.status != "active":
                    continue
                nact += 1
                fr = self._launch_frontier(es, hr)
                if not fr:
                    continue
                frs.append((hr, fr))
                if not any(nr.status == "running" for nr in hr.node_runs):
                    contrib.append((es, hr, fr))
        self._n_active_tot += nact - self._nact.get(i, 0)
        self._nact[i] = nact
        if frs:
            self._frontiers[i] = frs
            self._spec_idx.add(i)
        else:
            self._frontiers.pop(i, None)
            self._spec_idx.discard(i)
        if contrib:
            self._contrib[i] = contrib
            self._pool_idx.add(i)
        else:
            self._contrib.pop(i, None)
            self._pool_idx.discard(i)

    def _remaining_key(self, node_runs_or_nodes):
        out = []
        for x in node_runs_or_nodes:
            nr_status = getattr(x, "status", "pending")
            node = getattr(x, "node", x)
            if node.kind != NodeKind.TOOL:
                continue
            if nr_status in ("reused", "promoted"):
                continue
            out.append(node.tool)
        return tuple(out)

    def _refresh_beam(self, es: EpisodeState):
        active = [hr for hr in es.hyp_runs if hr.status == "active"]
        if len(active) >= self.rcfg.beam_k:
            return      # beam full — don't pay the builder for discards
        # dedup is scoped by build context: a carried-over branch resolves
        # its late bindings against ITS build-time history, so it is NOT a
        # duplicate of a fresh same-tool-sequence hypothesis built for the
        # current context (blocking the fresh one would leave only a branch
        # whose args contradict the agent's actual next action)
        have = {(self._remaining_key(hr.node_runs), hr.hyp.context_key)
                for hr in active}
        if self.rcfg.mode == "paste":
            builder = dataclasses.replace(self.builder, max_depth=1, with_prep=False)
        else:
            builder = self.builder
        hist = list(es.history)
        if es.phase == "executing" and es.inflight is not None:
            # speculate ACROSS the in-flight tool: its signature is known,
            # its result is not (bindings to it resolve lazily, post-landing)
            t, a = es.inflight
            hist = hist + [Event("tool", t, dict(a), None)]
        fresh = builder.build(hist, now=self.sim.now,
                              beam_width=self.rcfg.beam_k)
        for h in fresh:
            key = (self._remaining_key(h.nodes), h.context_key)
            if key in have or len(active) >= self.rcfg.beam_k:
                continue
            nrs = []
            ok = True
            for n in h.nodes:
                if n.kind != NodeKind.TOOL:
                    nrs.append(NodeRun(n, {}, run_tool=n.tool))
                    continue
                form = self.policy.speculative_form(n.tool)
                if form is None:
                    ok = False
                    break
                run_tool, transformed = form
                # resolve against the BUILD context (with the in-flight
                # placeholder): mined offsets are relative to `hist`, and a
                # binding that targets the unlanded event must yield None
                # now rather than a wrong value from the prior event
                args = {b.arg_name: b.resolve(hist) for b in n.bindings}
                args = {k: v for k, v in args.items() if v is not None}
                nrs.append(NodeRun(n, args, run_tool=run_tool, transformed=transformed))
            if not ok:
                continue
            hr = HypRun(h, es.ep.eid, Sandbox(es.state, h.hid), nrs, eu=0.0,
                        parents=h.parent_map(), base_len=len(hist))
            es.hyp_runs.append(hr)
            active.append(hr)
            have.add(key)

    def _packed_for(self, cand: List[HypRun]) -> PackedBeam:
        """Incremental beam packing: re-pack only when the pooled candidate
        beam actually changed, otherwise reuse the cached PackedBeam — beams
        are stable across most ticks.  The ordered hid tuple fully
        determines the packed tables even when candidates from several
        EpisodeStates share one pack: hids are globally unique across
        episodes (one builder numbers every hypothesis) and BranchHypothesis
        is immutable after build (node statuses live on NodeRun, which
        pack_beam never reads; fairness weights are passed alongside, not
        packed)."""
        sig = tuple(hr.hyp.hid for hr in cand)
        if self._packed_sig == sig and self._packed_beam is not None:
            self.metrics.sched_pack_hits += 1
            return self._packed_beam
        self.metrics.sched_pack_misses += 1
        k = bucket_k(len(cand), self.scorer.k_max)
        if len(self._pack_rows) > 8192:
            self._pack_rows.clear()           # bounded (hids grow per build)
        self._packed_beam = pack_beam([hr.hyp for hr in cand], k,
                                      self.scorer.n_max,
                                      row_cache=self._pack_rows)
        self._packed_sig = sig
        return self._packed_beam

    def _fairness_weights(
        self, pool: List[Tuple[EpisodeState, HypRun, List[int]]]
    ) -> Optional[np.ndarray]:
        """Per-candidate EU multipliers for the shared beam: tenants already
        holding in-flight speculative capacity get discounted so one
        episode's deep tree cannot starve another's candidates round after
        round.  Returns None (exactly the unweighted pass) when fairness is
        off or only one tenant has candidates — a uniform weight is a common
        positive factor and cannot change decisions, so skipping it keeps
        single-episode runs bit-identical to the pre-shared-beam path."""
        eids = [es.ep.eid for es, _, _ in pool]
        if self.rcfg.fairness_alpha <= 0 or len(set(eids)) < 2:
            return None
        cap = self._cap
        share: Dict[int, float] = {eid: 0.0 for eid in eids}
        for j in self.sim.running.values():
            if not j.speculative:
                continue
            eid = j.meta.get("eid")
            if eid in share:
                share[eid] += float(np.max(j.demand / cap))
        w = tenant_fairness_weights(share, self.rcfg.fairness_alpha)
        return np.array([w[eid] for eid in eids])

    def _memo_terms(
        self, pool: List[Tuple[EpisodeState, HypRun, List[int]]]
    ) -> Tuple[Optional[np.ndarray], Optional[np.ndarray]]:
        """Per-candidate reuse term for admission: a per-node ``memo_mask``
        marking launch-frontier TOOL nodes whose (tool, resolved args)
        already has a valid store entry, and the matching memo-excluded
        prefix ρ.  Memoized nodes contribute EU at zero demand — admission
        learns to prefer branches the store will partly serve for free.
        Rides ALONGSIDE the PackedBeam cache (store contents change every
        tick, the pack does not).  Returns (None, None) when the store has
        nothing to offer (keeps the no-memo path bit-identical)."""
        if not self._memo_on or not self.store.entries:
            return None, None
        # pass 1: which frontier nodes are servable?  Validation runs
        # against the BRANCH SANDBOX — exactly what the launch-time serve
        # will check — so a node whose entry conflicts with the branch's own
        # staged overlay is never scored as zero-demand and then executed
        # for real (over-admission past the Eq. 5 limit).
        excls: List[set] = []
        any_memo = False
        tool_pubs = self.store.tool_pubs
        inval = self.store.invalidations
        n_max = self.scorer.n_max
        for es, hr, fr in pool:
            excl = set()
            epoch = es.epoch
            for i in fr:
                nr = hr.node_runs[i]
                node = nr.node
                if node.kind != NodeKind.TOOL or node.idx >= n_max:
                    continue
                # verdict cache: every input to the servability decision
                # below is pinned by (episode epoch, this tool's publish
                # count, and — positives only — the invalidation counter);
                # see the NodeRun field comment for the argument.
                tp = tool_pubs.get(nr.run_tool, 0)
                if (nr.serv_epoch == epoch and nr.serv_pubs == tp
                        and (not nr.serv_ok or nr.serv_inval == inval)):
                    if nr.serv_ok:
                        excl.add(node.idx)
                        any_memo = True
                    continue
                ok = False
                if self.store.has_tool(nr.run_tool):
                    if node.bindings:
                        args = self._cached_node_args(es, hr, i)
                        complete = len(args) >= len(node.bindings)
                    else:
                        args = nr.resolved_args
                        complete = True
                    if complete:
                        # canonical key under the same epoch guard as the
                        # args (the canonicalization repr is pure in
                        # (tool, args))
                        if nr.mkey_epoch == epoch:
                            key = nr.mkey_cache
                        else:
                            key = memo_key(nr.run_tool, args)
                            nr.mkey_epoch, nr.mkey_cache = epoch, key
                        entry = self.store.entries.get(key)
                        if entry is not None and not entry.valid:
                            entry = None              # exactly store.peek
                        # track=False: a scoring-time peek must not hand
                        # the branch a base read-set it never earned (the
                        # launch-time serve re-validates with tracking ON
                        # before anything is consumed)
                        ok = entry is not None and self.store.validate(
                            entry, hr.sandbox, track=False)
                nr.serv_epoch, nr.serv_pubs = epoch, tp
                nr.serv_inval, nr.serv_ok = inval, ok
                if ok:
                    excl.add(node.idx)
                    any_memo = True
            excls.append(excl)
        if not any_memo:
            return None, None                 # no rho recompute on the hot path
        # pass 2: masks + memo-excluded prefix demand.  Unexcluded rows get
        # the STATIC prefix_rho(h), memoized per hid (hypotheses are
        # immutable after build) so steady-state ticks skip the Python DP.
        masks = np.zeros((len(pool), self.scorer.n_max))
        rhos = np.zeros((len(pool), RESOURCE_DIMS))
        for ci, (_es, hr, _fr) in enumerate(pool):
            excl = excls[ci]
            if excl:
                for idx in excl:
                    masks[ci, idx] = 1.0
                ek = (hr.hyp.hid, frozenset(excl))
                rho_e = self._rho_excl_cache.get(ek)
                if rho_e is None:
                    if len(self._rho_excl_cache) > 8192:
                        self._rho_excl_cache.clear()  # bounded
                    rho_e = self._rho_excl_cache[ek] = prefix_rho(
                        hr.hyp, ek[1])
                rhos[ci] = rho_e
            else:
                hid = hr.hyp.hid
                cached = self._rho_cache.get(hid)
                if cached is None:
                    if len(self._rho_cache) > 4096:
                        self._rho_cache.clear()   # bounded (hids grow per build)
                    cached = self._rho_cache[hid] = prefix_rho(hr.hyp)
                rhos[ci] = cached
        return masks, rhos

    def _admit_shared(self, pool: List[Tuple[EpisodeState, HypRun, List[int]]],
                      n_active: int):
        cand = [hr for _, hr, _ in pool]
        if not cand:
            return
        # beam fullness when an admission pass actually runs: every active
        # hypothesis across every active episode occupies a slot, whether
        # launchable this tick or mid-flight (Metrics.beam_occupancy_samples)
        self.metrics.beam_occupancy_samples.append(n_active)
        # ALL in-flight speculative demand is part of the conditioning
        # state: it stretches candidates (ΔI), consumes the budget B, and
        # shrinks the slack exactly like admitted-set demand (candidates
        # are idle, so nothing is charged twice)
        spec_rho = self.sim.running_demand(speculative=True)
        auth_rho = self.sim.running_demand(speculative=False) + spec_rho
        slack = self.sim.slack()
        budget = np.maximum(self.rcfg.budget.as_array() - spec_rho, 0.0)
        if self.rcfg.mode == "parallel":
            for hr in cand:
                hr.eu = hr.hyp.q
                hr.meta_admitted = True
            return
        weights = self._fairness_weights(pool)
        memo_masks, memo_rho = self._memo_terms(pool)
        # Never-fits pre-filter: the greedy (reference AND fused) admits a
        # candidate only when admitted_demand + ρ ≤ _fit_limit(limit), with
        # admitted_demand monotone from zero — so a candidate whose OWN
        # effective prefix ρ already exceeds the fit limit on any dimension
        # can never be picked on ANY iteration, and (EU is per-row, weights
        # are per-candidate) its presence cannot perturb any other row.
        # Dropping such rows before packing is decision- and value-identical
        # while collapsing the kernel's bucketed K in exactly the saturated
        # c≫1 regime where admission dominates the tick.  Weights/memo terms
        # are computed on the ORIGINAL pool above so per-candidate values
        # (incl. the <2-tenants uniform-weight gate) cannot shift.
        fit_lim = _fit_limit(np.minimum(slack, budget))
        if memo_rho is not None:
            eff_rho = memo_rho
        else:
            eff_rho = np.empty((len(cand), RESOURCE_DIMS))
            for ci, hr in enumerate(cand):
                hid = hr.hyp.hid
                rho_c = self._rho_cache.get(hid)
                if rho_c is None:
                    if len(self._rho_cache) > 4096:
                        self._rho_cache.clear()
                    rho_c = self._rho_cache[hid] = prefix_rho(hr.hyp)
                eff_rho[ci] = rho_c
        keep = np.flatnonzero(np.all(eff_rho <= fit_lim[None, :], axis=1))
        if len(keep) < len(cand):
            kept = set(keep.tolist())
            for ci, hr in enumerate(cand):
                if ci not in kept:
                    hr.meta_admitted = False  # exactly the rejected-path mark
            if not len(keep):
                return
            cand = [cand[ci] for ci in keep]
            if weights is not None:
                weights = weights[keep]
            if memo_masks is not None:
                memo_masks = memo_masks[keep]
            if memo_rho is not None:
                memo_rho = memo_rho[keep]
        # model-step-service feedback: a branch's ΔU payoff (unlocking the
        # next reasoning step early) is discounted by the expected wait that
        # step would see in the batch admission window — 0.0 under the
        # max_batch=1 baseline, keeping scoring bit-identical
        model_delay = self.model_service.expected_unlock_delay()
        # slot-marginal model-step cost (spec_model_steps): a hypothesis
        # whose speculative MODEL step would ride an idle slot of the
        # forming under-full batch pays ~0, one that would have to open a
        # new batch pays the full dispatch latency.  None when the path is
        # off OR every cost is zero — a zeros vector is an IEEE-exact no-op
        # in all three kernels, and None keeps the admission signature (and
        # the warm-start hit rate) identical to the flag's absence.
        spec_costs = None
        if self._spec_on and not self.model_service.spec_slot_free:
            base = self.tools["model_step"].base_latency
            sc = np.array([base if hr.hyp.model_idx >= 0 else 0.0
                           for hr in cand])
            if np.any(sc):
                spec_costs = sc
        # load-shedding tax (open-loop overload ladder): arrived-but-
        # unlaunched tenants are about to claim the idle window every
        # candidate's ΔO counts on, so the whole beam is taxed
        # shed_alpha × backlog — the lowest-EU speculation sheds first,
        # and past the knee the beam prices itself out entirely before any
        # authoritative work queues behind speculative demand.  0.0 when
        # the knob is off or nothing is queued: an IEEE-exact no-op in all
        # three kernels, so closed-loop schedules are bit-identical.
        shed_penalty = 0.0
        if self.rcfg.shed_alpha > 0:
            backlog = self._arrival_backlog()
            if backlog:
                shed_penalty = self.rcfg.shed_alpha * backlog
                self.metrics.shed_passes += 1
                self.metrics.shed_peak_backlog = max(
                    self.metrics.shed_peak_backlog, backlog)
        # Verified admission warm-start: the greedy/fused kernels are
        # deterministic functions of exactly the inputs signed below (see
        # admission_signature), so when nothing a decision depends on moved
        # since the last full pass, that pass's admitted set IS this pass's
        # answer — replay it instead of rescoring the pool.  Any deviation
        # (slack, demand, pool membership, weights, memo terms, model
        # delay) misses the signature and falls through to the full pass.
        sig = None
        if self.rcfg.warm_admit:
            sig = admission_signature(
                (hr.hyp.hid for hr in cand), slack, budget, auth_rho,
                weights, memo_masks, memo_rho, model_delay,
                spec_costs=spec_costs, shed_penalty=shed_penalty)
        if (sig is not None and self._warm_admitted is not None
                and sig == self._warm_sig):
            t0 = time.perf_counter()
            if self.rcfg.admission != "reference":
                # same pack-cache touch as the cold fused path (sig equality
                # implies the hid tuple matches, so this records a pack hit
                # and leaves the cache state exactly as the cold pass would)
                self._packed_for(cand)
            admitted_ids = self._warm_admitted
            for hr in cand:
                if hr.hyp.hid in admitted_ids:
                    hr.eu = admitted_ids[hr.hyp.hid]
                    hr.meta_admitted = True
                else:
                    hr.meta_admitted = False
            self.metrics.sched_admit_seconds += time.perf_counter() - t0
            self.metrics.sched_admit_calls += 1
            self.metrics.sched_warm_hits += 1
            return
        if self.rcfg.warm_admit:
            self.metrics.sched_warm_misses += 1
        hyps = [hr.hyp for hr in cand]
        t0 = time.perf_counter()
        if self.rcfg.admission == "reference":
            res = greedy_admit(
                hyps, self.scorer, slack, budget, auth_rho,
                idle_window=self.rcfg.idle_window, weights=weights,
                memo_masks=memo_masks, memo_rho=memo_rho,
                model_delay=model_delay, spec_costs=spec_costs,
                shed_penalty=shed_penalty,
            )
        else:
            if len(self._static_rows) > 8192:
                self._static_rows.clear()     # bounded (hids grow per build)
            res = fused_admit(
                hyps, self.scorer, slack, budget, auth_rho,
                idle_window=self.rcfg.idle_window,
                packed=self._packed_for(cand), weights=weights,
                memo_masks=memo_masks, memo_rho=memo_rho,
                model_delay=model_delay, spec_costs=spec_costs,
                shed_penalty=shed_penalty,
                small_beam_threshold=self.rcfg.host_admit_max,
                static_cache=self._static_rows if self.rcfg.warm_admit
                else None,
            )
        self.metrics.sched_admit_seconds += time.perf_counter() - t0
        self.metrics.sched_admit_calls += 1
        if shed_penalty > 0:
            # candidates priced out while the shed tax was active — the
            # graceful-degradation evidence trail (upper bound: capacity
            # rejections during overload are exactly the ladder working)
            self.metrics.shed_rejections += len(res.rejected)
        admitted_ids = {h.hid: res.eu[h.hid] for h in res.admitted}
        if sig is not None:
            self._warm_sig = sig
            self._warm_admitted = admitted_ids
        for hr in cand:
            if hr.hyp.hid in admitted_ids:
                hr.eu = admitted_ids[hr.hyp.hid]
                hr.meta_admitted = True
            else:
                hr.meta_admitted = False

    def _check_write_races(self, pool: List[Tuple[EpisodeState, HypRun, List[int]]]):
        """R3 (cross-branch write–write races) threaded into the shared
        admission pass: walk the just-admitted candidates in launch order
        (descending EU, then hid — the order ``_launch_nodes`` starts them)
        and track the EXACT (non-glob) write keys their frontier tools
        declare.  Two different tools claiming one key in the same pass
        would stage divergent writes to the same state.  With ``race_mask``
        on, the later (lower-EU) claimant is de-admitted this pass — it
        re-enters the pool next tick once the winner's write has landed;
        under ``sanitize`` alone the conflict is reported but not masked.
        Same-tool claims are benign (identical deterministic writes; true
        duplicates dedup through the result store) and glob overlaps
        usually hit distinct keys — neither is flagged, which is what keeps
        the default config race-silent."""
        admitted = [(es, hr, fr) for es, hr, fr in pool
                    if getattr(hr, "meta_admitted", False)]
        if len(admitted) < 2:
            return
        admitted.sort(key=lambda t: (-t[1].eu, t[1].hyp.hid))
        claimed: Dict[str, str] = {}      # exact write key -> claiming tool
        for es, hr, fr in admitted:
            keys: List[Tuple[str, str]] = []
            conflict = None
            for i in fr:
                nr = hr.node_runs[i]
                if nr.node.kind != NodeKind.TOOL:
                    continue
                spec = self.tools.get(nr.run_tool)
                if spec is None:
                    continue
                for pat in spec.writes:
                    if any(c in pat for c in "*?["):
                        continue          # glob: keys usually distinct
                    keys.append((pat, nr.run_tool))
                    prev = claimed.get(pat)
                    if conflict is None and prev is not None and prev != nr.run_tool:
                        conflict = (pat, prev, nr.run_tool)
            if conflict is not None:
                key, winner, loser = conflict
                if self.sanitizer is not None:
                    self.sanitizer._add(
                        "R3-write-race", "warn",
                        f"admit e{es.ep.eid} h{hr.hyp.hid}",
                        f"co-admitted {loser!r} writes {key!r} already "
                        f"claimed by {winner!r} this pass")
                if self.rcfg.race_mask:
                    hr.meta_admitted = False
                    self.metrics.race_masked += 1
                    continue              # masked branch claims nothing
            for key, tool in keys:
                claimed.setdefault(key, tool)

    def _launch_frontier(self, es: EpisodeState, hr: HypRun,
                         settle_warm: bool = True) -> List[int]:
        """Indices of every launchable (TOOL/PREP) node on the branch's
        ready frontier: pending nodes whose executable ancestors along the
        root path are all done/reused.  A running or blocked node gates only
        its OWN subtree — sibling branches keep their frontier (the serial
        node_runs-order walk this replaces assumed a linear chain).

        Per path: BARRIERs pass when staged execution is allowed; MODEL
        nodes always bound (reasoning is not tool-speculable here);
        NON_SPECULATIVE bounds; beyond a model-originated-args TOOL node
        only Level-0 PREP nodes may run (§7 Level 0: warm-up needs no
        arguments).

        ``settle_warm=False`` is the SIDE-EFFECT-FREE variant for the
        runtime sanitizer: already-warm pending preps are treated as settled
        without mutating their status, so a verification walk returns what
        the scheduler's walk would have cached without changing anything."""
        allow_staged = self.policy.max_level >= SafetyLevel.STAGED_WRITE
        out: List[int] = []
        open_: Dict[int, bool] = {}      # subtree not bounded above
        ready: Dict[int, bool] = {}      # executable ancestors all finished
        preponly: Dict[int, bool] = {}   # past a missing-args boundary
        for i, nr in enumerate(hr.node_runs):
            kind = nr.node.kind
            ps = hr.parents.get(i, ())
            if ps:
                op = all(open_.get(p, False) for p in ps)
                rd = all(ready.get(p, False) for p in ps)
                po = any(preponly.get(p, False) for p in ps)
            else:
                op, rd, po = True, True, False
            open_[i], ready[i], preponly[i] = False, False, po
            if not op:
                continue
            if kind == NodeKind.MODEL:
                if (self._spec_on and i == hr.hyp.model_idx
                        and hr.hyp.spine_leaf >= 0):
                    # speculative reasoning step: surfaced while "pending"
                    # — the submit path decides which boundary (deepest
                    # materialized spine prefix) is draftable, so neither
                    # full-spine readiness nor a missing-args bound blocks
                    # drafting the boundaries BEFORE the bound.  The
                    # post-MODEL segment opens only when the whole spine is
                    # materialized AND the join's own predicted outcome
                    # landed ("done") or the authoritative step validated
                    # it ("reused").
                    rd_spine = ready.get(hr.hyp.spine_leaf, False)
                    if nr.status == "pending":
                        out.append(i)
                    open_[i] = True
                    ready[i] = rd_spine and nr.status in ("done", "reused")
                continue
            if nr.node.level == SafetyLevel.NON_SPECULATIVE:
                continue
            if kind == NodeKind.BARRIER:
                open_[i], ready[i] = allow_staged, rd
                continue
            if kind == NodeKind.TOOL and nr.node.missing_args:
                open_[i], ready[i], preponly[i] = True, rd, True
                continue
            status = nr.status
            if kind == NodeKind.PREP and status == "pending"                     and nr.run_tool == "env_warmup" and self.sim.now <= es.warm_until:
                status = "reused"             # already warm — prep is a no-op
                if settle_warm:
                    nr.status = status
            if status == "pending" and rd and (kind == NodeKind.PREP or not po):
                out.append(i)
            open_[i] = True
            ready[i] = rd and status in ("done", "reused")
        return out

    def _launch_nodes(self):
        """Start admitted frontier nodes in descending admission-EU order
        (Algorithm 1: highest-value branches claim the slack first — with a
        wide beam, list order would let low-value branches starve the
        valuable ones at the capacity boundary).  The capacity fit check
        lives in ``_start_spec_node`` AFTER the store serve attempt: serving
        a memoized node costs zero slack, so a saturated machine must not
        block it — that is exactly the regime the store exists for."""
        ready: List[Tuple[float, int, int, EpisodeState, HypRun]] = []
        for es in self.episodes:
            for hr in es.hyp_runs:
                if hr.status != "active" or not getattr(hr, "meta_admitted", False):
                    continue
                for i in self._launch_frontier(es, hr):
                    ready.append((-hr.eu, hr.hyp.hid, i, es, hr))
        ready.sort(key=lambda t: t[:3])
        for _, _, i, es, hr in ready:
            self._start_spec_node(es, hr, i)

    def _launch_nodes_event(self):
        """Cached-frontier variant of ``_launch_nodes``: the frontier walk
        already ran in ``_rebuild_cache`` (this tick for dirty episodes, a
        previous tick for clean ones — every node-status change dirties its
        episode, so the cache is current), and launching is a retry loop
        over it — nodes that failed the fit/args check keep retrying every
        tick exactly as the dense re-walk would."""
        ready: List[Tuple[float, int, int, EpisodeState, HypRun]] = []
        for idx in sorted(self._spec_idx):
            es = self.episodes[idx]
            for hr, fr in self._frontiers[idx]:
                if hr.status != "active" or not getattr(hr, "meta_admitted", False):
                    continue
                for i in fr:
                    ready.append((-hr.eu, hr.hyp.hid, i, es, hr))
        ready.sort(key=lambda t: t[:3])
        for _, _, i, es, hr in ready:
            self._start_spec_node(es, hr, i)

    def _serve_spec(self, es: EpisodeState, hr: HypRun, i: int,
                    entry: MemoEntry) -> None:
        """Serve a store entry INTO a sandbox: the node completes instantly
        (zero slack burned), its staged writes land in the branch overlay,
        and validation reads have already been pulled through the CowView —
        so the entry's dependencies sit in the branch's base read-set and
        conflict pruning covers served results like executed ones."""
        self._mark_dirty(es)
        nr = hr.node_runs[i]
        self.store.apply_writes(entry, hr.sandbox)
        nr.result = entry.result
        nr.status = "done"
        nr.served = True
        entry.serves += 1
        hr.sandbox.record(Event("tool", nr.run_tool, dict(nr.resolved_args),
                                nr.result, self.sim.now, self.sim.now,
                                es.ep.eid))
        self._snapshot(hr, nr)
        self.metrics.memo_hits += 1
        # no spec_solo_seconds: nothing executed, so a later squash books
        # zero waste for this node (job stays None)

    def _start_spec_node(self, es: EpisodeState, hr: HypRun, i: int) -> bool:
        nr = hr.node_runs[i]
        if nr.node.kind == NodeKind.MODEL:
            # speculative reasoning step: rides an idle slot of the forming
            # batch instead of a simulator job of its own
            return self._submit_spec_step(es, hr, i)
        if nr.waiting:
            return False                  # subscribed to an in-flight twin
        if nr.node.kind == NodeKind.TOOL and nr.node.bindings:
            # copy: resolved_args outlives the epoch (sandbox events, memo
            # keys), the cached dict does not
            nr.resolved_args = dict(self._cached_node_args(es, hr, i))
            if len(nr.resolved_args) < len(nr.node.bindings):
                return False                  # inputs not materialized yet
        key = None
        if self._memo_on and nr.node.kind == NodeKind.TOOL:
            # epoch-cached canonical key (shared with _memo_terms): the
            # launch retry loop re-peeks every candidate every tick, and
            # re-canonicalizing unchanged args dominated those retries
            if nr.mkey_epoch == es.epoch:
                key = nr.mkey_cache
            else:
                key = memo_key(nr.run_tool, nr.resolved_args)
                nr.mkey_epoch, nr.mkey_cache = es.epoch, key
            entry = self.store.entries.get(key)
            if entry is not None and not entry.valid:
                entry = None                  # exactly store.peek
            if entry is not None and self.store.validate(entry, hr.sandbox):
                self._serve_spec(es, hr, i, entry)
                return True
            if self.store.is_pending(key):
                # an identical computation is in flight (another branch or
                # tenant): subscribe to its result instead of burning the
                # slack twice
                def on_pub(pub_entry, es=es, hr=hr, i=i):
                    self._mark_dirty(es)   # node unblocked (or re-armed)
                    nr2 = hr.node_runs[i]
                    nr2.waiting = False
                    if pub_entry is None:         # owner preempted: re-arm
                        return
                    if hr.status != "active" or nr2.status != "pending":
                        return
                    if not self.store.validate(pub_entry, hr.sandbox):
                        return
                    self._serve_spec(es, hr, i, pub_entry)

                self.store.subscribe(key, on_pub)
                nr.waiting = True
                self.metrics.memo_dedups += 1
                return False
        spec = self.tools[nr.run_tool]
        demand = nr.node.rho.as_array()
        total = self.sim.running_demand() + demand
        if np.any((total > self._cap + 1e-9) & (demand > 1e-12)):
            return False                      # no slack on a dim we need
        dur = spec.det_latency(nr.resolved_args)
        if nr.run_tool in self.COLD_TOOLS and self.sim.now <= es.warm_until:
            dur *= self.rcfg.warm_discount

        def done(sim: Simulator, job: SimJob, es=es, hr=hr, i=i):
            self._mark_dirty(es)      # node finished: frontier advances
            nr2 = hr.node_runs[i]
            mk = job.meta.get("memo_key")
            if nr2.run_tool == "env_warmup":
                # warmth is tenant-local: this episode's environment only
                es.warm_until = max(es.warm_until, sim.now + self.rcfg.warm_ttl)
            if hr.status != "active" and nr2.status != "promoted":
                self.store.abort(mk, job.jid)
                return
            fac = StateFacade(hr.sandbox)
            try:
                nr2.result = execute_tool(nr2.run_tool, nr2.resolved_args, fac)
            except KeyError:
                nr2.result = None
            else:
                if self.sanitizer is not None:
                    self.sanitizer.check_footprint(
                        nr2.run_tool, fac, f"spec e{es.ep.eid} h{hr.hyp.hid}.{i}")
            hr.sandbox.record(Event("tool", nr2.run_tool, nr2.resolved_args,
                                    nr2.result, job.started_at or 0.0, sim.now,
                                    es.ep.eid))
            if nr2.status != "promoted":
                nr2.status = "done"
            self._snapshot(hr, nr2)
            self.metrics.spec_solo_seconds += job.work
            if mk is not None:
                # publish the sandbox-computed result (per-call footprint;
                # sandbox writes are NOT authoritative, so no note_writes) —
                # resolves the pending entry and feeds every subscriber
                if not self._publish_result(fac, nr2.run_tool,
                                            nr2.resolved_args, nr2.result,
                                            es.ep.eid, note=False):
                    self.store.abort(mk, job.jid)

        job = self.sim.new_job(
            f"spec:{nr.run_tool}[h{hr.hyp.hid}.{i}]",
            spec.rho.as_array(), dur, speculative=True, on_complete=done,
            meta={"eu": hr.eu, "node_run": nr, "hyp": hr.hyp.hid,
                  "eid": es.ep.eid},
        )
        if key is not None:
            self.store.begin(key, job.jid)
            job.meta["memo_key"] = key
        nr.job = job
        nr.status = "running"
        self._mark_dirty(es)          # idle branch became in-flight
        self.sim.start(job)
        return True

    # ==================================================================
    def _tick(self, sim: Simulator):
        t0 = time.perf_counter()
        self._phase1()
        self._phase2()
        self._phase3()
        self._phase4()
        self._qos_tick(sim)
        if self.sanitizer is not None:
            # after the phases: the dirty set now holds exactly the episodes
            # whose caches are legitimately pending a rebuild, so every
            # OTHER episode's cached frontier must match a fresh walk
            self.sanitizer.on_tick()
        self.metrics.sched_ticks += 1
        self.metrics.sched_tick_seconds += time.perf_counter() - t0

    def _qos_tick(self, sim: Simulator):
        # QoS accounting: authoritative slowdown attributable to speculation,
        # attributed per tenant (arrival timers are zero-demand bookkeeping
        # jobs — they would dilute the samples with 1.0 ratios)
        dem = [j for j in sim.running.values() if not j.meta.get("timer")]
        if dem and any(j.speculative for j in dem):
            from repro.core.interference import slowdowns as _sl
            auth = [j for j in dem if not j.speculative]
            if auth:
                mat_all = np.stack([j.demand for j in dem])
                slows_all = _sl(mat_all, self._cap)
                mat_auth = np.stack([j.demand for j in auth])
                slows_auth = _sl(mat_auth, self._cap)
                auth_all = [(j, s) for j, s in zip(dem, slows_all, strict=True)
                            if not j.speculative]
                for (j, s_with), s_without in zip(auth_all, slows_auth, strict=True):
                    ratio = float(s_with / max(s_without, 1e-9))
                    self.metrics.auth_slowdown_samples.append(ratio)
                    # a batched model job serves SEVERAL tenants at once
                    # (meta["eids"]): speculation stretching the batch taxes
                    # every member, so the per-tenant slowdown sample and
                    # any QoS violation land on each of them — per-batch
                    # attribution, not first-member-only
                    eids = j.meta.get("eids")
                    if eids is None:
                        eid = j.meta.get("eid")
                        eids = [eid] if eid is not None else []
                    for eid in eids:
                        self.metrics.tenant_slowdown_samples.setdefault(
                            eid, []).append(ratio)
                    if ratio > 1.05:
                        self.metrics.qos_violations += 1
                        for eid in eids:
                            self.metrics.tenant_qos_violations[eid] = (
                                self.metrics.tenant_qos_violations.get(eid, 0)
                                + 1)


def run_mode(
    episodes: List[Episode],
    engine: PatternEngine,
    mode: str,
    machine: Optional[Machine] = None,
    policy: EligibilityPolicy = FULL_POLICY,
    seed: int = 0,
    episode_source: Optional[Iterator[Episode]] = None,
    **kw,
) -> Metrics:
    """``episode_source`` switches the run to OPEN-LOOP serving: episodes
    come from the lazy iterator (nondecreasing arrivals, e.g.
    ``workload.open_loop_source``) as they arrive, and ``episodes`` is then
    usually the empty seed roster.  None keeps the frozen closed-loop
    roster semantics bit-identical."""
    rcfg = RuntimeConfig(mode=mode, seed=seed, **kw)
    if machine is None:
        machine = Machine()
    rt = BPasteRuntime(episodes, engine, machine, policy, rcfg,
                       episode_source=episode_source)
    return rt.run()
