"""PASTE pattern tuples (C, T, f, p) + late-bound argument resolvers Φ.

The context C is an event-signature suffix; T the predicted tool; f a
*late-binding* argument mapping (args derived from prior tool outputs via
simple transformations, per PASTE's data-flow regularity observation); p the
empirical confidence.  B-PASTE uses these as building blocks for assembling
bounded future subgraphs (hypothesis.py).

Paper anchor: §3 (pattern tuples, data-flow regularities), Eq. 1's Φ (the
late-bound argument resolvers hypotheses carry).
Upstream: mining/prefixspan.py (motifs), events.py (signatures).
Downstream: hypothesis.py (root prediction + tree expansion via
``PatternEngine.predict_sigs``), runtime.py (miss-pruning predictions).
"""
from __future__ import annotations

import os
import re
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Hashable, List, Optional, Sequence, Tuple

from repro.core.events import Event, Trace, signature, trace_signatures
from repro.core.mining.prefixspan import conditional_next, prefixspan

# ----------------------------------------------------------------------
# Late-binding transformations: arg <- transform(prior event output field)
# ----------------------------------------------------------------------

def _t_identity(v):
    return v


def _t_first(v):
    if isinstance(v, (list, tuple)) and v:
        return v[0]
    return None


def _t_basename(v):
    return os.path.basename(v) if isinstance(v, str) else None


def _t_dirname(v):
    return os.path.dirname(v) if isinstance(v, str) else None


TRANSFORMS: Dict[str, Callable[[Any], Any]] = {
    "identity": _t_identity,
    "first": _t_first,
    "basename": _t_basename,
    "dirname": _t_dirname,
}


@dataclass(frozen=True)
class ArgBinding:
    """arg_name <- transform(source event's result field).  source_offset is
    the (negative) event index relative to the prediction point."""
    arg_name: str
    source_offset: int           # -1 = immediately preceding event, etc.
    source_field: Optional[str]  # None = whole result; else result[field]
    transform: str               # key into TRANSFORMS

    def resolve(self, history: Sequence[Event]) -> Any:
        if len(history) < -self.source_offset:
            return None
        ev = history[self.source_offset]
        v = ev.result
        if self.source_field is not None:
            if isinstance(v, dict):
                v = v.get(self.source_field)
            else:
                v = getattr(v, self.source_field, None)
        return TRANSFORMS[self.transform](v)


@dataclass(frozen=True)
class PatternTuple:
    """PASTE (C, T, f, p)."""
    context: Tuple[Hashable, ...]       # event-signature suffix
    tool: str                           # predicted tool T
    bindings: Tuple[ArgBinding, ...]    # f (late-binding arg mapping)
    confidence: float                   # p
    next_sig: Optional[Hashable] = None # full predicted event signature
    missing_args: Tuple[str, ...] = () # observed args with NO reliable binding
                                        # (model-originated — a speculation
                                        # boundary, cf. PASTE's "freshly
                                        # hallucinated" arguments)

    def resolve_args(self, history: Sequence[Event]) -> Dict[str, Any]:
        return {b.arg_name: b.resolve(history) for b in self.bindings}


def _candidate_values(ev: Event) -> List[Tuple[Optional[str], str, Any]]:
    """(field, transform, value) candidates derivable from an event result."""
    out = []
    results = [(None, ev.result)]
    if isinstance(ev.result, dict):
        results += [(k, v) for k, v in ev.result.items()]
    for fieldname, v in results:
        for tname, fn in TRANSFORMS.items():
            try:
                tv = fn(v)
            except Exception:
                tv = None
            if tv is not None and isinstance(tv, (str, int, float)):
                out.append((fieldname, tname, tv))
    return out


def mine_bindings(
    traces: Sequence[Trace], context: Tuple, tool: str, lookback: int = 3,
    min_frac: float = 0.6,
) -> Tuple[Tuple[ArgBinding, ...], Tuple[str, ...]]:
    """For each arg of `tool` occurring after `context`, find a (offset,
    field, transform) that reproduces the observed value in >= min_frac of
    occurrences — PASTE's data-flow regularity mining."""
    # collect (history, args) occurrences
    occs: List[Tuple[List[Event], Dict[str, Any]]] = []
    cl = len(context)
    for tr in traces:
        sigs = trace_signatures(tr)
        for i in range(cl, len(tr)):
            if tr[i].tool == tool and tuple(sigs[i - cl : i]) == context:
                occs.append((tr[:i], tr[i].args))
    if not occs:
        return (), ()
    arg_names = sorted({k for _, a in occs for k in a})
    bindings: List[ArgBinding] = []
    for arg in arg_names:
        best: Optional[ArgBinding] = None
        best_frac = 0.0
        # hit fractions denominate over ALL occurrences carrying the arg:
        # an offset only reachable in a few occurrences (len(hist) < off
        # elsewhere) must not score its hits against that tiny sample — a
        # frac-1.0-of-2 binding would beat a frac-0.9-of-20 one and resolve
        # garbage on the 18 histories where its source event doesn't exist
        n_arg = sum(1 for _, args in occs if arg in args)
        for off in range(1, lookback + 1):
            # tally candidate (field, transform) hits across occurrences
            tallies: Dict[Tuple[Optional[str], str], int] = {}
            for hist, args in occs:
                if arg not in args or len(hist) < off:
                    continue
                for fieldname, tname, tv in _candidate_values(hist[-off]):
                    if tv == args[arg]:
                        tallies[(fieldname, tname)] = tallies.get((fieldname, tname), 0) + 1
            for (fieldname, tname), hits in tallies.items():
                frac = hits / max(n_arg, 1)
                # prefer equally-reliable bindings with EARLIER sources: their
                # inputs materialize sooner, so branch nodes can launch while
                # later tools are still in flight
                if frac > best_frac or (frac == best_frac and best is not None
                                        and -off < best.source_offset):
                    best_frac = frac
                    best = ArgBinding(arg, -off, fieldname, tname)
        if best is not None and best_frac >= min_frac:
            bindings.append(best)
    bound = {b.arg_name for b in bindings}
    missing = tuple(a for a in arg_names if a not in bound)
    return tuple(bindings), missing


@dataclass
class PatternEngine:
    """Offline-mined pattern store + online next-tool prediction."""
    context_len: int = 2
    min_support: int = 2
    patterns: List[PatternTuple] = field(default_factory=list)
    next_tables: Dict[Tuple, Dict[Hashable, float]] = field(default_factory=dict)
    motifs: List = field(default_factory=list)
    # context -> [(pattern, confidence) desc] — prediction is on the per-tick
    # hot path (every tree-node expansion queries it), so no linear scans
    _by_context: Dict[Tuple, List[Tuple[PatternTuple, float]]] = field(
        default_factory=dict, repr=False)

    def fit(self, traces: Sequence[Trace]) -> "PatternEngine":
        seqs = [trace_signatures(t) for t in traces]
        self.next_tables = conditional_next(seqs, self.context_len, self.min_support)
        self.motifs = prefixspan(seqs, min_support=self.min_support, max_len=5, max_gap=1)
        # build pattern tuples for the most confident (context -> tool) pairs
        self.patterns = []
        for ctx, table in self.next_tables.items():
            for nxt_sig, p in table.items():
                tool = nxt_sig[1]
                bindings, missing = mine_bindings(traces, ctx, tool)
                self.patterns.append(
                    PatternTuple(ctx, tool, bindings, p, nxt_sig, missing))
        self.patterns.sort(key=lambda pt: -pt.confidence)
        self._index()
        return self

    def _index(self) -> Dict[Tuple, List[Tuple[PatternTuple, float]]]:
        self._by_context = {}
        for pt in self.patterns:          # already confidence-descending
            self._by_context.setdefault(pt.context, []).append(
                (pt, pt.confidence))
        return self._by_context

    def predict(
        self, history: Sequence[Event], top: int = 4, backoff: str = "longest"
    ) -> List[Tuple[PatternTuple, float]]:
        """Top candidate next tools for the current history (longest matching
        context wins; confidence from the empirical table)."""
        return self.predict_sigs([signature(e) for e in history], top, backoff)

    def predict_sigs(
        self, sigs: Sequence[Hashable], top: int = 4, backoff: str = "longest"
    ) -> List[Tuple[PatternTuple, float]]:
        """Signature-space prediction (used for subgraph expansion, where
        future events exist only as predicted signatures).

        backoff="longest": candidates from the longest matching context only
        (the classic backoff — stop at the most specific table).
        backoff="merge": candidates from every matching context length,
        most-specific first, deduplicated by predicted signature — shorter
        contexts contribute *additional* distinct roots, which is what lets
        a beam fill past the fan-out of one table (multi-root fill)."""
        by_ctx = self._by_context or (self._index() if self.patterns else {})
        merged: List[Tuple[PatternTuple, float]] = []
        seen_sigs = set()
        for cl in range(self.context_len, 0, -1):
            if len(sigs) < cl:
                continue
            ctx = tuple(sigs[-cl:])
            cands = [(pt, c) for pt, c in by_ctx.get(ctx, ())
                     if pt.next_sig not in seen_sigs]
            if backoff == "longest":
                if cands:
                    return cands[:top]
                continue
            for pt, _ in cands:
                seen_sigs.add(pt.next_sig)
            merged.extend(cands)
            if len(merged) >= top:
                break
        return merged[:top]
