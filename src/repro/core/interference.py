"""Multi-resource co-run interference model (paper Eq. 4, §2, §5).

Bottleneck (roofline-style) slowdown: for a machine with capacity vector
cap and a co-running job set with demand vectors ρ_j, the per-dimension
utilization is u_d = Σ_j ρ_jd / cap_d; any dimension with u_d > 1 stretches
every job that uses it by u_d.  A job's slowdown is the max stretch over
the dimensions it touches:

    slow_j = max_d ( u_d if ρ_jd > 0 else 1,  1 )
    L_j^co = L_j^solo · slow_j          =>   ΔI = L^co − L^solo

This is the TPU/host-idiomatic replacement for the paper's (unspecified)
Thor SoC measurement: it captures exactly the phenomenon the paper targets
— co-location can raise aggregate throughput while delaying the critical
branch.  Deterministic, differentiable, and vectorizable (scoring.py).

Also home of the model-step batch-efficiency curve
(``batched_step_latency``): the sublinear cost model the batched model-step
service (model_service.py) charges per micro-batched invocation.

Upstream: events.py (ResourceVector).  Downstream: simulator.py (job
progress rates), scoring.py (ΔI), runtime Phase 2 (protection),
model_service.py (batch latency).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.events import ResourceVector


@dataclass(frozen=True)
class Machine:
    """Thor-class edge box by default: 12 cores, 100 GB/s mem, 500 MB/s io,
    1 accelerator slot."""
    capacity: ResourceVector = ResourceVector(cpu=12, mem_bw=100, io=500, accel=1)

    def cap_array(self) -> np.ndarray:
        return np.maximum(self.capacity.as_array(), 1e-9)


def utilization(demands: np.ndarray, cap: np.ndarray) -> np.ndarray:
    """demands (J, R) -> per-dim utilization (R,)."""
    if demands.size == 0:
        return np.zeros_like(cap)
    return demands.sum(axis=0) / cap


def slowdowns(demands: np.ndarray, cap: np.ndarray) -> np.ndarray:
    """Per-job slowdown factors (J,) for a co-running set."""
    if demands.size == 0:
        return np.zeros((0,))
    u = np.maximum(utilization(demands, cap), 1.0)     # (R,)
    uses = demands > 0
    per_job = np.where(uses, u[None, :], 1.0)
    return per_job.max(axis=1)


def co_run_latency(
    solo: np.ndarray, demands: np.ndarray, cap: np.ndarray
) -> np.ndarray:
    return solo * slowdowns(demands, cap)


def batched_step_latency(works: Sequence[float], marginal: float = 0.3) -> float:
    """Latency of ONE batched model invocation serving ``b = len(works)``
    coalesced reasoning steps (model_service.py).

    Continuous-batching cost model, the ``base + marginal·(b−1)`` shape the
    inference literature measures for decode batching (SPORK / Speculative
    Actions exploit the same sublinearity on the model side): the longest
    member sets the base — the batch is one forward pass per token, so it
    cannot finish before its longest sequence — and every ADDITIONAL member
    adds only ``marginal`` of its solo work (extra rows in the same matmuls
    are close to free on a memory-bound accelerator, but KV traffic and
    padding are not zero):

        L(batch) = max_i w_i + marginal · (Σ_i w_i − max_i w_i)

    Properties the scheduler relies on:
      * b=1 is EXACT: ``L([w]) = w`` — a solo dispatch costs what the
        unbatched runtime charged, which is what keeps ``max_batch=1``
        bit-identical to the pre-service behavior.
      * Sublinear but not free: serial cost Σw is reached only at
        ``marginal=1``; ``marginal=0`` would be the (unphysical) free-batch
        limit.  0 < marginal < 1 ⇒ batching strictly beats the serial queue
        and strictly loses to a second accelerator.
      * Monotone in every member's work and in batch size.
    """
    w = np.asarray(list(works), float)
    if w.size == 0:
        return 0.0
    base = float(w.max())
    return base + marginal * float(w.sum() - base)


def batch_efficiency(b: int, marginal: float = 0.3) -> float:
    """Per-step cost of a size-``b`` batch relative to serial execution, for
    equal-work members: ``(1 + marginal·(b−1)) / b``.  The calibration curve
    behind ``batched_step_latency`` — 1.0 at b=1, approaching ``marginal``
    as b grows (an 8-wide batch at marginal=0.3 costs ~0.39 of serial)."""
    b = max(int(b), 1)
    return (1.0 + marginal * (b - 1)) / b


def marginal_interference(
    cand_solo: float, cand_rho: np.ndarray,
    admitted_solo: np.ndarray, admitted_rho: np.ndarray,
    cap: np.ndarray,
) -> float:
    """ΔI_i(S): candidate's own stretch PLUS the extra stretch it inflicts on
    the already-admitted set (full marginal, §5)."""
    if admitted_rho.size == 0:
        base = np.zeros((0,))
        all_rho = cand_rho[None, :]
        all_solo = np.array([cand_solo])
        new = co_run_latency(all_solo, all_rho, cap)
        return float(new[0] - cand_solo)
    before = co_run_latency(admitted_solo, admitted_rho, cap)
    all_rho = np.concatenate([admitted_rho, cand_rho[None, :]], axis=0)
    all_solo = np.concatenate([admitted_solo, [cand_solo]])
    after = co_run_latency(all_solo, all_rho, cap)
    self_delta = after[-1] - cand_solo
    others_delta = float(np.sum(after[:-1] - before))
    return float(self_delta + others_delta)
