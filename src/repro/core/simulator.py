"""Discrete-event simulator: virtual clock, multi-resource machine,
interference-stretched preemptible jobs — event-driven core.

Progress model: a job j with solo work W_j progresses at rate 1/slow_j(S)
where slow_j is the bottleneck-model stretch of the *current* co-run set S
(interference.py).  Rates change only when the run set changes (start /
finish / preempt / cancel) — progress is piecewise linear, completion
times exact.

The pre-event implementation re-derived every job's rate and re-scanned
all running jobs for the minimum completion time at every step (O(n) per
event, O(n^2) across a drain).  This core replaces that with:

* an **indexed event queue** — a heap of projected completion times
  ``(t_proj, seq, jid)`` with lazy invalidation: a stale entry (the job's
  rate changed, or the job left the run set) is skipped on pop instead of
  being searched for and removed;
* **lazy settlement** — a job's ``remaining``/``executed_solo_seconds``
  are brought forward to ``now`` only when something needs them (its rate
  changes, it completes, it is preempted, or a caller asks via
  :meth:`settled_remaining`), not for every running job at every event;
* **incremental demand accounting** — ``running_demand``/``slack`` read
  O(#distinct demand vectors) group counters maintained on start/stop,
  instead of O(n) re-sums (counters, not +=/-= accumulators, so there is
  no drift to accumulate and the recomputed-slack invariant holds
  exactly);
* **selective rate recomputation** — on a run-set change only the
  per-dimension utilizations that actually moved are propagated, and only
  jobs *using* a moved dimension get a new rate + fresh queue entry.  In
  the common under-capacity regime (all utilizations <= 1) no rate ever
  changes and a job touches the queue exactly once.

The runtime (runtime.py) plugs in as a ``tick(sim)`` callback invoked
after every state change; preemption keeps remaining work so jobs resume
without losing progress (paper §6: speculative work must be immediately
preemptible and reclaimable).

Observability: ``record_log=False`` disables the event log (an unbounded
list is a memory blowup at c=1024 — benches turn it off); ``slow_samples``
is a bounded ring buffer that skips zero-demand bookkeeping timers; an
optional ``recorder`` hook (see trace.py) receives every
start/finish/preempt/cancel for Gantt/timeline dumps.

Paper anchor: §5–6 (slack, preemptibility), Eq. 4 via interference.py.
Upstream: interference.Machine (capacities, slowdown model).  Downstream:
runtime.py (every authoritative/speculative job and timer),
model_service.py (batched model invocations + linger timers).
"""
from __future__ import annotations

import heapq
import itertools
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.events import RESOURCE_DIMS
from repro.core.interference import Machine

EPS = 1e-9

# ring-buffer capacity for co-run slowdown samples: diagnostics only, and
# an unbounded list grew without limit on long serving sweeps
SLOW_SAMPLE_CAP = 65536


@dataclass
class SimJob:
    jid: int
    name: str
    demand: np.ndarray            # (R,)
    work: float                   # solo seconds
    speculative: bool
    priority: int = 0             # 0 = authoritative, 1 = speculative
    remaining: float = -1.0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    preempt_count: int = 0
    executed_solo_seconds: float = 0.0   # work actually burned (for waste metric)
    on_complete: Optional[Callable[["Simulator", "SimJob"], None]] = None
    meta: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        if self.remaining < 0:
            self.remaining = self.work


class Simulator:
    def __init__(self, machine: Machine, tick: Callable[["Simulator"], None],
                 *, record_log: bool = True, recorder=None):
        self.machine = machine
        self.cap = machine.cap_array()
        self.now = 0.0
        self.running: Dict[int, SimJob] = {}
        self.tick = tick
        self._jid = itertools.count()
        self.record_log = record_log
        self.log: List[tuple] = []
        # co-run slowdown ratio samples (diagnostics): bounded ring buffer,
        # appended when a job's rate is (re)priced — zero-demand bookkeeping
        # timers are excluded (they always sample 1.0 and polluted the ring)
        self.slow_samples: deque = deque(maxlen=SLOW_SAMPLE_CAP)
        self.truncated: Optional[str] = None  # "max_time"|"max_steps" when
                                              # run() stopped before drain
        # optional per-event observer: recorder(sim, kind, job) with kind in
        # {"start","finish","preempt","cancel"} — trace.GanttRecorder plugs
        # in here for the opt-in full timeline dump
        self.recorder = recorder
        # optional consumer hook for run(): () -> bool, True while the tick
        # callback still holds parked work that needs another tick pass even
        # though the event queue is empty (see the drain loop in run())
        self.drain_probe: Optional[Callable[[], bool]] = None

        # ---- event-queue core state --------------------------------------
        self._heap: List[tuple] = []          # (t_proj, entry_seq, jid)
        self._live: Dict[int, int] = {}       # jid -> valid entry_seq
        self._eseq = itertools.count()        # heap entry sequence
        self._rate: Dict[int, float] = {}     # jid -> current progress rate
        self._last: Dict[int, float] = {}     # jid -> last settlement time
        self._sord: Dict[int, int] = {}       # jid -> start order (callback
                                              # ordering for same-time batches)
        self._sseq = itertools.count()
        # demand groups: demand-vector bytes -> [vec, n_total, n_speculative].
        # Counters (exact integers) times the group vector reconstruct the
        # running demand in O(#groups) with zero accumulated float drift.
        self._groups: Dict[bytes, list] = {}
        self._by_dim: List[set] = [set() for _ in range(RESOURCE_DIMS)]
        self._slow = np.ones(RESOURCE_DIMS)   # clipped per-dim utilization
        # memoized running_demand per speculative-class flag, invalidated on
        # any counter change (start/remove/class flip).  The launch retry
        # loop reads demand once per candidate per tick — recomputing the
        # O(#groups) sum each time was measurable at c≫1.  Values are
        # recomputed from the same counters, so cached == recomputed exactly.
        self._demand_cache: Dict[Optional[bool], np.ndarray] = {}

    # ------------------------------------------------------------------
    def new_job(self, name: str, demand: np.ndarray, work: float, *,
                speculative: bool, on_complete=None, meta=None) -> SimJob:
        return SimJob(
            jid=next(self._jid), name=name, demand=np.asarray(demand, float),
            work=work, speculative=speculative,
            priority=1 if speculative else 0,
            on_complete=on_complete, meta=meta or {},
        )

    def start(self, job: SimJob):
        if job.started_at is None:
            job.started_at = self.now
        self.running[job.jid] = job
        self._sord[job.jid] = next(self._sseq)
        self._last[job.jid] = self.now
        key = job.demand.tobytes()
        g = self._groups.get(key)
        if g is None:
            g = self._groups[key] = [job.demand.copy(), 0, 0]
        g[1] += 1
        if job.speculative:
            g[2] += 1
        self._demand_cache.clear()
        for d in range(RESOURCE_DIMS):
            if job.demand[d] > 0.0:
                self._by_dim[d].add(job.jid)
        if self.record_log:
            self.log.append((self.now, "start", job.name, job.jid, job.speculative))
        if self.recorder is not None:
            self.recorder(self, "start", job)
        self._reprice(touch=job.jid)

    def preempt(self, jid: int) -> Optional[SimJob]:
        job = self.running.get(jid)
        if job is None:
            return None
        self._settle(job)
        self._remove(job)
        job.preempt_count += 1
        if self.record_log:
            self.log.append((self.now, "preempt", job.name, job.jid, job.speculative))
        if self.recorder is not None:
            self.recorder(self, "preempt", job)
        self._reprice()
        return job

    def cancel(self, jid: int) -> Optional[SimJob]:
        """Remove a bookkeeping job (e.g. a batch-linger or arrival timer)
        without the preemption bookkeeping: no preempt_count bump and no
        "preempt" log line — cancelling a timer is not a scheduling decision
        and must not read as one in the logs or waste accounting.  The job's
        ``on_complete`` never fires."""
        job = self.running.get(jid)
        if job is None:
            return None
        self._settle(job)
        self._remove(job)
        if self.record_log:
            self.log.append((self.now, "cancel", job.name, job.jid, job.speculative))
        if self.recorder is not None:
            self.recorder(self, "cancel", job)
        self._reprice()
        return job

    def set_speculative(self, job: SimJob, speculative: bool) -> None:
        """Flip a job's speculative/authoritative class in place (Phase-1
        promotion).  Keeps the incremental auth/spec demand split coherent —
        mutating ``job.speculative`` directly would silently corrupt
        ``running_demand(speculative=...)``.  Rates are class-blind, so no
        repricing is needed."""
        if job.speculative == speculative:
            return
        job.speculative = speculative
        job.priority = 1 if speculative else 0
        if job.jid in self.running:
            g = self._groups[job.demand.tobytes()]
            g[2] += 1 if speculative else -1
            self._demand_cache.clear()

    def running_demand(self, *, speculative: Optional[bool] = None) -> np.ndarray:
        cached = self._demand_cache.get(speculative)
        if cached is not None:
            return cached.copy()          # callers may accumulate in place
        tot = np.zeros(RESOURCE_DIMS)
        for vec, n, ns in self._groups.values():
            k = n if speculative is None else (ns if speculative else n - ns)
            if k:
                tot += k * vec
        self._demand_cache[speculative] = tot
        return tot.copy()

    def slack(self) -> np.ndarray:
        return np.maximum(self.cap - self.running_demand(), 0.0)

    def dense_running_demand(self, *, speculative: Optional[bool] = None) -> np.ndarray:
        """Brute-force O(n) re-sum over ``self.running`` — the pre-event
        implementation of :meth:`running_demand`.  The runtime sanitizer
        (core/analysis.py check S3) diffs the counter-group value against
        this on a sampled schedule; it is NOT for hot paths."""
        tot = np.zeros(RESOURCE_DIMS)
        for job in self.running.values():
            if speculative is None or job.speculative == speculative:
                tot += job.demand
        return tot

    # ------------------------------------------------------------------
    # event-queue internals
    # ------------------------------------------------------------------
    def _settle(self, job: SimJob) -> None:
        """Bring the job's progress forward to ``now`` under its current
        (piecewise-constant) rate."""
        dt = self.now - self._last[job.jid]
        if dt > 0.0:
            adv = dt * self._rate[job.jid]
            job.remaining -= adv
            job.executed_solo_seconds += adv
        self._last[job.jid] = self.now

    def settled_remaining(self, job: SimJob) -> float:
        """The job's remaining solo work as of ``now`` (settling it first if
        it is running — lazy settlement means the raw field can be stale)."""
        if job.jid in self.running:
            self._settle(job)
        return job.remaining

    def _remove(self, job: SimJob) -> None:
        del self.running[job.jid]
        self._live.pop(job.jid, None)         # lazy heap invalidation
        self._rate.pop(job.jid, None)
        self._last.pop(job.jid, None)
        self._sord.pop(job.jid, None)
        g = self._groups[job.demand.tobytes()]
        g[1] -= 1
        if job.speculative:
            g[2] -= 1
        self._demand_cache.clear()
        for d in range(RESOURCE_DIMS):
            if job.demand[d] > 0.0:
                self._by_dim[d].discard(job.jid)

    def _push(self, job: SimJob) -> None:
        seq = next(self._eseq)
        self._live[job.jid] = seq
        t_proj = self.now + job.remaining / self._rate[job.jid]
        heapq.heappush(self._heap, (t_proj, seq, job.jid))

    def _job_slow(self, job: SimJob) -> float:
        s = 1.0
        for d in range(RESOURCE_DIMS):
            if job.demand[d] > 0.0 and self._slow[d] > s:
                s = self._slow[d]
        return s

    def _reprice(self, touch: Optional[int] = None) -> None:
        """Recompute per-dimension utilization after a run-set change and
        re-rate ONLY the jobs whose bottleneck actually moved (plus the
        newly started ``touch`` job, which has no rate yet).  Each re-rated
        job is settled under its old rate first, then gets a fresh event
        queue entry; its old entry goes stale in place."""
        tot = np.zeros(RESOURCE_DIMS)
        for vec, n, _ns in self._groups.values():
            if n:
                tot += n * vec
        u = np.maximum(tot / self.cap, 1.0)
        affected: set = set()
        for d in range(RESOURCE_DIMS):
            if u[d] != self._slow[d]:
                affected |= self._by_dim[d]
        self._slow = u
        if touch is not None:
            affected.add(touch)
        for jid in affected:
            job = self.running.get(jid)
            if job is None:
                continue
            if jid in self._rate:
                self._settle(job)
            slow = self._job_slow(job)
            self._rate[jid] = 1.0 / slow
            if not job.speculative and np.any(job.demand > 0.0):
                self.slow_samples.append(float(slow))
            self._push(job)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Advance to the next completion.  Returns False when idle."""
        if not self.running:
            return False
        heap = self._heap
        while heap and self._live.get(heap[0][2]) != heap[0][1]:
            heapq.heappop(heap)               # skip stale entries
        if not heap:
            return False                      # defensive: shouldn't happen
        t_next = heap[0][0]
        # pop every event in the completion window: exact ties plus FP dust
        # (the <= EPS remaining-work criterion below matches the pre-event
        # done test, so near-simultaneous completions batch identically)
        popped: List[SimJob] = []
        while heap and heap[0][0] <= t_next + EPS:
            t, seq, jid = heapq.heappop(heap)
            if self._live.get(jid) == seq:
                popped.append(self.running[jid])
        self.now = t_next
        done: List[SimJob] = []
        for job in popped:
            self._settle(job)
            if job.remaining <= EPS:
                done.append(job)
            else:
                self._push(job)               # not actually finished: re-arm
        # completion callbacks fire in start order — the dict-insertion
        # order the dense scan produced for same-instant batches
        done.sort(key=lambda j: self._sord[j.jid])
        for job in done:
            self._remove(job)
            job.finished_at = self.now
            if self.record_log:
                self.log.append((self.now, "finish", job.name, job.jid,
                                 job.speculative))
            if self.recorder is not None:
                self.recorder(self, "finish", job)
        if done:
            self._reprice()
        for job in done:
            if job.on_complete:
                job.on_complete(self, job)
        return True

    def run(self, max_time: float = 1e7, max_steps: int = 2_000_000) -> bool:
        """Drive to quiescence.  Returns True when the simulation drained
        (no runnable jobs left); False when it hit ``max_time``/``max_steps``
        with work still outstanding — the stop reason lands in
        ``self.truncated`` and a warning fires, so downstream makespans can't
        silently report a truncated clock as a completed run."""
        self.truncated = None
        self.tick(self)
        steps = 0
        while True:
            if self.now >= max_time:
                self.truncated = "max_time"
                break
            if steps >= max_steps:
                self.truncated = "max_steps"
                break
            if not self.step():
                # Queue empty — but a completion cascade can park new work
                # with no event left to carry it (e.g. an instant
                # store-serve chained into a validate-on-arrival spec-step
                # acceptance leaves a pending action that only the NEXT
                # tick dispatches, and that dispatch can itself resolve
                # instantly and park another).  Grant drain ticks while the
                # consumer's ``drain_probe`` reports parked work — each
                # tick consumes it, so this terminates — and exit the
                # moment nothing is runnable and nothing is parked, so
                # ordinary runs keep their exact tick count.
                if not (self.drain_probe is not None
                        and self.drain_probe()):
                    break
            self.tick(self)
            steps += 1
        if self.truncated is not None and not self.running:
            self.truncated = None        # cap hit exactly at drain — complete
        if self.truncated is not None:
            warnings.warn(
                f"Simulator.run stopped on {self.truncated} at t={self.now:.1f} "
                f"with {len(self.running)} job(s) still running; makespan is "
                f"a lower bound", RuntimeWarning, stacklevel=2)
        return self.truncated is None
