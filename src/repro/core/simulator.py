"""Discrete-event simulator: virtual clock, multi-resource machine,
interference-stretched preemptible jobs.

Progress model: a job j with solo work W_j progresses at rate 1/slow_j(S)
where slow_j is the bottleneck-model stretch of the *current* co-run set S
(interference.py).  Whenever the run set changes (start / finish / preempt)
rates are recomputed — piecewise-linear progress, exact completion times.

The runtime (runtime.py) plugs in as a `tick(sim)` callback invoked after
every state change; preemption keeps remaining work so jobs resume without
losing progress (paper §6: speculative work must be immediately
preemptible and reclaimable).

Paper anchor: §5–6 (slack, preemptibility), Eq. 4 via interference.py.
Upstream: interference.Machine (capacities, slowdown model).  Downstream:
runtime.py (every authoritative/speculative job and timer),
model_service.py (batched model invocations + linger timers).
"""
from __future__ import annotations

import itertools
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.events import RESOURCE_DIMS
from repro.core.interference import Machine, slowdowns

EPS = 1e-9


@dataclass
class SimJob:
    jid: int
    name: str
    demand: np.ndarray            # (R,)
    work: float                   # solo seconds
    speculative: bool
    priority: int = 0             # 0 = authoritative, 1 = speculative
    remaining: float = -1.0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    preempt_count: int = 0
    executed_solo_seconds: float = 0.0   # work actually burned (for waste metric)
    on_complete: Optional[Callable[["Simulator", "SimJob"], None]] = None
    meta: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        if self.remaining < 0:
            self.remaining = self.work


class Simulator:
    def __init__(self, machine: Machine, tick: Callable[["Simulator"], None]):
        self.machine = machine
        self.cap = machine.cap_array()
        self.now = 0.0
        self.running: Dict[int, SimJob] = {}
        self.tick = tick
        self._jid = itertools.count()
        self.log: List[tuple] = []
        self.slow_samples: List[float] = []   # co-run slowdown ratio samples
        self.truncated: Optional[str] = None  # "max_time"|"max_steps" when
                                              # run() stopped before drain

    # ------------------------------------------------------------------
    def new_job(self, name: str, demand: np.ndarray, work: float, *,
                speculative: bool, on_complete=None, meta=None) -> SimJob:
        return SimJob(
            jid=next(self._jid), name=name, demand=np.asarray(demand, float),
            work=work, speculative=speculative,
            priority=1 if speculative else 0,
            on_complete=on_complete, meta=meta or {},
        )

    def start(self, job: SimJob):
        if job.started_at is None:
            job.started_at = self.now
        self.running[job.jid] = job
        self.log.append((self.now, "start", job.name, job.jid, job.speculative))

    def preempt(self, jid: int) -> Optional[SimJob]:
        job = self.running.pop(jid, None)
        if job is not None:
            job.preempt_count += 1
            self.log.append((self.now, "preempt", job.name, job.jid, job.speculative))
        return job

    def cancel(self, jid: int) -> Optional[SimJob]:
        """Remove a bookkeeping job (e.g. a batch-linger or arrival timer)
        without the preemption bookkeeping: no preempt_count bump and no
        "preempt" log line — cancelling a timer is not a scheduling decision
        and must not read as one in the logs or waste accounting.  The job's
        ``on_complete`` never fires."""
        job = self.running.pop(jid, None)
        if job is not None:
            self.log.append((self.now, "cancel", job.name, job.jid, job.speculative))
        return job

    def running_demand(self, *, speculative: Optional[bool] = None) -> np.ndarray:
        tot = np.zeros(RESOURCE_DIMS)
        for j in self.running.values():
            if speculative is None or j.speculative == speculative:
                tot += j.demand
        return tot

    def slack(self) -> np.ndarray:
        return np.maximum(self.cap - self.running_demand(), 0.0)

    # ------------------------------------------------------------------
    def _rates(self) -> Dict[int, float]:
        jobs = list(self.running.values())
        if not jobs:
            return {}
        dem = np.stack([j.demand for j in jobs])
        slow = slowdowns(dem, self.cap)
        for j, s in zip(jobs, slow):
            if not j.speculative:
                self.slow_samples.append(float(s))
        return {j.jid: 1.0 / s for j, s in zip(jobs, slow)}

    def step(self) -> bool:
        """Advance to the next completion.  Returns False when idle."""
        if not self.running:
            return False
        rates = self._rates()
        t_next = min(self.now + j.remaining / rates[j.jid] for j in self.running.values())
        dt = t_next - self.now
        self.now = t_next
        done: List[SimJob] = []
        for j in self.running.values():
            adv = dt * rates[j.jid]
            j.remaining -= adv
            j.executed_solo_seconds += adv
            if j.remaining <= EPS:
                done.append(j)
        for j in done:
            del self.running[j.jid]
            j.finished_at = self.now
            self.log.append((self.now, "finish", j.name, j.jid, j.speculative))
        for j in done:
            if j.on_complete:
                j.on_complete(self, j)
        return True

    def run(self, max_time: float = 1e7, max_steps: int = 2_000_000) -> bool:
        """Drive to quiescence.  Returns True when the simulation drained
        (no runnable jobs left); False when it hit ``max_time``/``max_steps``
        with work still outstanding — the stop reason lands in
        ``self.truncated`` and a warning fires, so downstream makespans can't
        silently report a truncated clock as a completed run."""
        self.truncated = None
        self.tick(self)
        steps = 0
        while True:
            if self.now >= max_time:
                self.truncated = "max_time"
                break
            if steps >= max_steps:
                self.truncated = "max_steps"
                break
            if not self.step():
                break
            self.tick(self)
            steps += 1
        if self.truncated is not None and not self.running:
            self.truncated = None        # cap hit exactly at drain — complete
        if self.truncated is not None:
            warnings.warn(
                f"Simulator.run stopped on {self.truncated} at t={self.now:.1f} "
                f"with {len(self.running)} job(s) still running; makespan is "
                f"a lower bound", RuntimeWarning, stacklevel=2)
        return self.truncated is None
