"""Tool execution semantics: deterministic results over sandboxed state.

Every tool is a pure function of (args, state views); speculative runs get a
Sandbox (CoW views), authoritative runs get the live AgentState.  Results
are structured dicts so late-binding transforms (patterns.py) have fields to
key on — mirroring PASTE's observation that many arguments are derivable
from prior outputs.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Dict, Optional, Union

from repro.core.events import DEFAULT_TOOLS, Event, SafetyLevel, ToolSpec
from repro.core.sandbox import AgentState, CowView, Sandbox


def _h(s: str) -> str:
    return hashlib.sha1(str(s).encode()).hexdigest()[:8]


class StateFacade:
    """Uniform M/F/E access over AgentState or Sandbox."""

    def __init__(self, st: Union[AgentState, Sandbox]):
        self._st = st
        self.writes: set = set()            # namespaced keys written (live only)
        if isinstance(st, Sandbox):
            self.M, self.F, self.E = st.M, st.F, st.E
            self.sandboxed = True
        else:
            self.M = _DictView(st.memory, self.writes, "M")
            self.F = _DictView(st.fs, self.writes, "F")
            self.E = _DictView(st.env, self.writes, "E")
            self.sandboxed = False

    def bump_if_live(self):
        if not self.sandboxed:
            self._st.bump()


class _DictView:
    def __init__(self, d: Dict[str, Any], writes: set = None, ns: str = ""):
        self._d = d
        self._writes = writes
        self._ns = ns

    def get(self, k, default=None):
        return self._d.get(k, default)

    def set(self, k, v):
        self._d[k] = v
        if self._writes is not None:
            self._writes.add(f"{self._ns}:{k}")

    def delete(self, k):
        self._d.pop(k, None)
        if self._writes is not None:
            self._writes.add(f"{self._ns}:{k}")

    def __contains__(self, k):
        return k in self._d

    def keys(self):
        return set(self._d.keys())


def execute_tool(tool: str, args: Dict[str, Any], state: StateFacade) -> Dict[str, Any]:
    """Deterministic tool semantics (synthetic but stateful)."""
    if tool == "search":
        q = str(args.get("query", ""))
        urls = [f"url://{_h(q)}/{i}" for i in range(3)]
        return {"results": urls, "top": urls[0]}
    if tool in ("visit", "fetch"):
        url = str(args.get("url", args.get("path", "")))
        content = f"content::{_h(url)}"
        state.F.set(url, content)          # read-through cache write (L1-safe)
        return {"path": url, "content": content}
    if tool == "grep":
        pat = str(args.get("pattern", ""))
        path = f"src/{_h(pat)}.py"
        return {"path": path, "matches": 3}
    if tool == "read":
        path = str(args.get("path", ""))
        return {"path": path, "content": state.F.get(path, f"orig::{_h(path)}")}
    if tool == "parse":
        path = str(args.get("path", ""))
        content = state.F.get(path, "")
        return {"path": path, "summary": f"sum::{_h(str(content))}"}
    if tool == "edit":
        path = str(args.get("path", ""))
        change = str(args.get("change", ""))
        state.F.set(path, f"edited::{change}")
        state.bump_if_live()
        return {"path": path, "ok": True}
    if tool == "test":
        target = str(args.get("target", ""))
        content = str(state.F.get(target, ""))
        ok = content.startswith("edited::fix")
        return {"target": target, "pass": ok}
    if tool == "build":
        state.E.set("built", True)
        state.bump_if_live()
        return {"ok": True}
    if tool == "pip_install":
        pkg = str(args.get("pkg", ""))
        state.E.set(f"pkg:{pkg}", "installed")
        state.bump_if_live()
        return {"pkg": pkg, "ok": True}
    if tool == "pip_download":
        pkg = str(args.get("pkg", ""))
        state.F.set(f"cache/{pkg}.whl", "wheel")
        return {"pkg": pkg, "cached": True}
    if tool in ("session_init", "env_warmup"):
        state.E.set(f"warm:{tool}", True)
        return {"ok": True}
    if tool == "deploy":
        state.E.set("deployed", True)
        state.bump_if_live()
        return {"ok": True}
    if tool == "model_step":
        return {"ok": True}
    raise KeyError(f"unknown tool {tool!r}")
