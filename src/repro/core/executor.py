"""Tool execution semantics: deterministic results over sandboxed state.

Every tool is a pure function of (args, state views); speculative runs get a
Sandbox (CoW views), authoritative runs get the live AgentState.  Results
are structured dicts so late-binding transforms (patterns.py) have fields to
key on — mirroring PASTE's observation that many arguments are derivable
from prior outputs.

The ``StateFacade`` additionally records a **per-call footprint** — the
namespaced keys each tool invocation read (with the observed value, or an
ABSENT marker when the read fell through to the tool's internal default) and
the overlay it wrote.  The cross-episode result store (memo.py) keys entry
validity on exactly this footprint; the old whole-sandbox
``CowView.base_reads`` set is lifetime-cumulative (over-broad for per-call
entries) and live ``_DictView`` reads were not tracked at all.  A read of a
key the same call already wrote is a self-read — replay reproduces it — and
is excluded from the footprint.

Paper anchor: §4.2 (deterministic replayable tools — the Level-1/Level-2
execution contract of §7).  Upstream: runtime.py (authoritative and
speculative calls), workload.py (episode scripting uses the same
semantics).  Downstream: sandbox.py views, memo.py (footprints key entry
validity).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Dict, Optional, Union

from repro.core.sandbox import ABSENT, AgentState, CowView, Sandbox, _TOMBSTONE


def _h(s: str) -> str:
    return hashlib.sha1(str(s).encode()).hexdigest()[:8]


class StateFacade:
    """Uniform M/F/E access over AgentState or Sandbox, with per-call
    read/write footprint tracking (memo.py consumes it)."""

    def __init__(self, st: Union[AgentState, Sandbox]):
        self._st = st
        self.writes: set = set()             # namespaced keys written (cumulative)
        self.reads: Dict[str, Any] = {}      # per-call: ns key -> value | ABSENT
        self.write_values: Dict[str, Any] = {}  # per-call: ns key -> value | _TOMBSTONE
        if isinstance(st, Sandbox):
            inner = {"M": st.M, "F": st.F, "E": st.E}
            self.sandboxed = True
        else:
            inner = {"M": _DictView(st.memory), "F": _DictView(st.fs),
                     "E": _DictView(st.env)}
            self.sandboxed = False
        self.M = _TrackedView(inner["M"], "M", self)
        self.F = _TrackedView(inner["F"], "F", self)
        self.E = _TrackedView(inner["E"], "E", self)

    def begin_call(self):
        """Reset the per-call footprint (``writes`` stays cumulative — the
        runtime unions it across a replayed path for conflict pruning)."""
        self.reads = {}
        self.write_values = {}

    def footprint(self):
        """(reads, write overlay) of the current call."""
        return dict(self.reads), dict(self.write_values)

    def bump_if_live(self):
        if not self.sandboxed:
            self._st.bump()


class _DictView:
    """Plain dict adapter giving live AgentState namespaces the CowView
    read/write protocol (footprint recording lives in _TrackedView)."""

    def __init__(self, d: Dict[str, Any]):
        self._d = d

    def get(self, k, default=None):
        return self._d.get(k, default)

    def set(self, k, v):
        self._d[k] = v

    def delete(self, k):
        self._d.pop(k, None)

    def __contains__(self, k):
        return k in self._d

    def keys(self):
        return set(self._d.keys())


class _TrackedView:
    """Footprint-recording wrapper over a CowView (sandbox) or _DictView
    (live).  Writes pass straight through; reads record (key, observed
    value) unless the same call already wrote the key (self-read)."""

    def __init__(self, inner, ns: str, fac: StateFacade):
        self._inner = inner
        self._ns = ns
        self._fac = fac

    def get(self, k, default=None):
        nk = f"{self._ns}:{k}"
        wv = self._fac.write_values
        if nk in wv:
            v = wv[nk]
            return default if v is _TOMBSTONE else v
        present = k in self._inner
        v = self._inner.get(k, default)
        self._fac.reads[nk] = v if present else ABSENT
        return v

    def set(self, k, v):
        nk = f"{self._ns}:{k}"
        self._inner.set(k, v)
        self._fac.writes.add(nk)
        self._fac.write_values[nk] = v

    def delete(self, k):
        nk = f"{self._ns}:{k}"
        self._inner.delete(k)
        self._fac.writes.add(nk)
        self._fac.write_values[nk] = _TOMBSTONE

    def __contains__(self, k):
        nk = f"{self._ns}:{k}"
        wv = self._fac.write_values
        if nk in wv:
            return wv[nk] is not _TOMBSTONE
        return k in self._inner

    def keys(self):
        return self._inner.keys()


def execute_tool(tool: str, args: Dict[str, Any], state: StateFacade) -> Dict[str, Any]:
    """Deterministic tool semantics (synthetic but stateful)."""
    if tool == "search":
        q = str(args.get("query", ""))
        urls = [f"url://{_h(q)}/{i}" for i in range(3)]
        return {"results": urls, "top": urls[0]}
    if tool in ("visit", "fetch"):
        url = str(args.get("url", args.get("path", "")))
        content = f"content::{_h(url)}"
        state.F.set(url, content)          # read-through cache write (L1-safe)
        # any live base mutation must advance the version or Sandbox.is_stale
        # misses it (bump is a no-op for sandboxed runs)
        state.bump_if_live()
        return {"path": url, "content": content}
    if tool == "grep":
        pat = str(args.get("pattern", ""))
        path = f"src/{_h(pat)}.py"
        return {"path": path, "matches": 3}
    if tool == "read":
        path = str(args.get("path", ""))
        return {"path": path, "content": state.F.get(path, f"orig::{_h(path)}")}
    if tool == "parse":
        path = str(args.get("path", ""))
        content = state.F.get(path, "")
        return {"path": path, "summary": f"sum::{_h(str(content))}"}
    if tool == "edit":
        path = str(args.get("path", ""))
        change = str(args.get("change", ""))
        state.F.set(path, f"edited::{change}")
        state.bump_if_live()
        return {"path": path, "ok": True}
    if tool == "test":
        target = str(args.get("target", ""))
        content = str(state.F.get(target, ""))
        ok = content.startswith("edited::fix")
        return {"target": target, "pass": ok}
    if tool == "build":
        state.E.set("built", True)
        state.bump_if_live()
        return {"ok": True}
    if tool == "pip_install":
        pkg = str(args.get("pkg", ""))
        state.E.set(f"pkg:{pkg}", "installed")
        state.bump_if_live()
        return {"pkg": pkg, "ok": True}
    if tool == "pip_download":
        pkg = str(args.get("pkg", ""))
        state.F.set(f"cache/{pkg}.whl", "wheel")
        state.bump_if_live()
        return {"pkg": pkg, "cached": True}
    if tool in ("session_init", "env_warmup"):
        state.E.set(f"warm:{tool}", True)
        # live base mutation like every other env write: without the bump a
        # pre-existing sandbox would keep validating (is_stale()==False)
        # against a base that has diverged, and execution would disagree
        # with cache-serving of the identical action (which does bump)
        state.bump_if_live()
        return {"ok": True}
    if tool == "deploy":
        state.E.set("deployed", True)
        state.bump_if_live()
        return {"ok": True}
    if tool == "model_step":
        return {"ok": True}
    raise KeyError(f"unknown tool {tool!r}")


# ----------------------------------------------------------------------
# Dry-run support for the static analyzer (core/analysis.py rule R1).
# ----------------------------------------------------------------------

# Representative concrete arguments per tool: enough to drive every state
# access in execute_tool's semantics (the implementations key state touches
# on arg *presence*, not payload, so any concrete value exercises the same
# footprint shape).
SAMPLE_ARGS: Dict[str, Dict[str, Any]] = {
    "search": {"query": "q"},
    "visit": {"url": "u"},
    "fetch": {"url": "u"},
    "grep": {"pattern": "p"},
    "read": {"path": "f"},
    "parse": {"path": "f"},
    "edit": {"path": "f", "change": "c"},
    "test": {"target": "f"},
    "pip_install": {"pkg": "p"},
    "pip_download": {"pkg": "p"},
    "session_init": {},
    "env_warmup": {},
    "deploy": {},
    "model_step": {},
}


def dry_run_footprint(tool: str, args: Optional[Dict[str, Any]] = None):
    """Execute ``tool`` against a throwaway AgentState and return its tracked
    per-call ``(reads, write overlay)`` footprint.  Raises KeyError for tools
    without an executor implementation (the analyzer skips those)."""
    fac = StateFacade(AgentState())
    fac.begin_call()
    execute_tool(tool, dict(args if args is not None else SAMPLE_ARGS.get(tool, {})), fac)
    return fac.footprint()
