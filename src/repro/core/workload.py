"""Agent workload generator: ReAct-style scripted episodes over the paper's
recurring motifs (edit-verify, locate-examine, search-visit, setup).

Episodes are fully scripted at construction (tool semantics are
deterministic over state, so the ground-truth action stream — including
late-bound arguments — is computable ahead of time).  Every scheduler
(serial / PASTE / B-PASTE / naive-parallel) replays the SAME episodes, so
end-to-end comparisons are exact.  The runtime only ever sees the next
action after the preceding model step completes — the execution graph is
revealed online, per the paper's core premise.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import DEFAULT_TOOLS, Event
from repro.core.executor import StateFacade, execute_tool
from repro.core.sandbox import AgentState


@dataclass
class Step:
    model_work: float            # reasoning latency preceding the action
    tool: str
    args: Dict[str, Any]


@dataclass
class Episode:
    eid: int
    kind: str
    steps: List[Step]

    def serial_latency(self, tools=DEFAULT_TOOLS) -> float:
        return sum(s.model_work + tools[s.tool].det_latency(s.args) for s in self.steps)


def _model_work(rng) -> float:
    return float(np.clip(rng.normal(2.5, 0.5), 1.0, 5.0))


def _script_fix_bug(eid: int, rng) -> List[Step]:
    """locate-examine + edit-verify motif."""
    st = AgentState()
    fac = StateFacade(st)
    steps: List[Step] = []

    def act(tool, **args):
        steps.append(Step(_model_work(rng), tool, dict(args)))
        return execute_tool(tool, args, fac)

    r = act("grep", pattern=f"bug_{eid}")
    path = r["path"]
    act("read", path=path)
    n_attempts = int(rng.integers(1, 4))
    for j in range(n_attempts - 1):
        act("edit", path=path, change=f"attempt{j}")
        act("test", target=path)
    act("edit", path=path, change="fix")
    act("test", target=path)
    return steps


def _script_research(eid: int, rng) -> List[Step]:
    """search-visit motif."""
    st = AgentState()
    fac = StateFacade(st)
    steps: List[Step] = []

    def act(tool, **args):
        steps.append(Step(_model_work(rng), tool, dict(args)))
        return execute_tool(tool, args, fac)

    n_rounds = int(rng.integers(1, 4))
    for k in range(n_rounds):
        r = act("search", query=f"topic_{eid}_{k}")
        r2 = act("visit", url=r["top"])
        act("parse", path=r2["path"])
    return steps


def _script_setup(eid: int, rng) -> List[Step]:
    """environment setup motif (Level-2 heavy: exercises transformed
    speculation + staged writes)."""
    st = AgentState()
    fac = StateFacade(st)
    steps: List[Step] = []

    def act(tool, **args):
        steps.append(Step(_model_work(rng), tool, dict(args)))
        return execute_tool(tool, args, fac)

    act("pip_install", pkg=f"dep_{eid}")
    act("build")
    r = act("grep", pattern=f"entry_{eid}")
    act("test", target=r["path"])
    return steps


KINDS = {
    "fix_bug": _script_fix_bug,
    "research": _script_research,
    "setup": _script_setup,
}


@dataclass
class WorkloadConfig:
    seed: int = 0
    n_episodes: int = 20
    mix: Tuple[Tuple[str, float], ...] = (
        ("fix_bug", 0.5), ("research", 0.3), ("setup", 0.2),
    )


def make_episodes(cfg: WorkloadConfig) -> List[Episode]:
    rng = np.random.default_rng(cfg.seed)
    kinds, probs = zip(*cfg.mix)
    episodes = []
    for eid in range(cfg.n_episodes):
        kind = str(rng.choice(kinds, p=np.array(probs) / sum(probs)))
        steps = KINDS[kind](eid, rng)
        episodes.append(Episode(eid, kind, steps))
    return episodes


def episodes_to_traces(episodes: Sequence[Episode]) -> List[List[Event]]:
    """Offline mining traces: serially execute each episode and record events
    with real results (timestamps synthetic; mining is time-free)."""
    traces: List[List[Event]] = []
    for ep in episodes:
        st = AgentState()
        fac = StateFacade(st)
        t = 0.0
        trace: List[Event] = []
        for s in ep.steps:
            t += s.model_work
            res = execute_tool(s.tool, s.args, fac)
            dur = DEFAULT_TOOLS[s.tool].base_latency
            trace.append(Event("tool", s.tool, dict(s.args), res, t, t + dur, ep.eid))
            t += dur
        traces.append(trace)
    return traces
