"""Agent workload generator: ReAct-style scripted episodes over the paper's
recurring motifs (edit-verify, locate-examine, search-visit, setup).

Episodes are fully scripted at construction (tool semantics are
deterministic over state, so the ground-truth action stream — including
late-bound arguments — is computable ahead of time).  Every scheduler
(serial / PASTE / B-PASTE / naive-parallel) replays the SAME episodes, so
end-to-end comparisons are exact.  The runtime only ever sees the next
action after the preceding model step completes — the execution graph is
revealed online, per the paper's core premise.

Each motif carries seeded *variant* steps (examine-before-edit, fetch
instead of visit, deep-dive read, retry-after-failed-test) with
probabilities scaled by ``WorkloadConfig.variation``: agent control flow
shares prefixes but diverges, so the mined conditional tables have fan-out
>1 — the regime where tree-shaped hypotheses and multi-root beam fill pay
off (and real ReAct traces live, per PASTE's characterization).  Set
``variation=0`` for the fully deterministic legacy streams.

Paper anchor: §2/§8 (ReAct agent workloads, recurring motifs), §9's
evaluation regimes (concurrency, staggered arrivals, shared corpora).
Upstream: events.py tools, executor.py semantics (steps are scripted by
actually executing them).  Downstream: runtime.py (episodes to serve),
patterns.py (offline mining traces via ``episodes_to_traces``),
model_service.py (per-step ``batchable`` metadata).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import DEFAULT_TOOLS, Event
from repro.core.executor import StateFacade, execute_tool
from repro.core.sandbox import AgentState


@dataclass
class Step:
    model_work: float            # reasoning latency preceding the action
    tool: str
    args: Dict[str, Any]
    batchable: bool = True       # may this step's reasoning coalesce into a
                                 # micro-batched model invocation
                                 # (model_service.py)?  False pins the step
                                 # to a solo dispatch — the escape hatch for
                                 # latency-critical steps that must not pay
                                 # the batch admission window (linger)


@dataclass
class Episode:
    eid: int
    kind: str
    steps: List[Step]
    arrival: float = 0.0          # tenant arrival time (0 = present at t=0);
                                  # the runtime launches an episode no
                                  # earlier than its arrival

    def serial_latency(self, tools=DEFAULT_TOOLS) -> float:
        return sum(s.model_work + tools[s.tool].det_latency(s.args) for s in self.steps)


def _model_work(rng) -> float:
    return float(np.clip(rng.normal(2.5, 0.5), 1.0, 5.0))


def _script_fix_bug(eid: int, rng, var: float = 1.0,
                    ident: Optional[str] = None) -> List[Step]:
    """locate-examine + edit-verify motif."""
    ident = str(eid) if ident is None else ident
    st = AgentState()
    fac = StateFacade(st)
    steps: List[Step] = []

    def act(tool, **args):
        steps.append(Step(_model_work(rng), tool, dict(args), batchable=True))
        return execute_tool(tool, args, fac)

    r = act("grep", pattern=f"bug_{ident}")
    path = r["path"]
    act("read", path=path)
    if var > 0 and rng.random() < 0.35 * var:
        act("parse", path=path)            # examine variant before editing
    n_attempts = int(rng.integers(1, 4))
    for j in range(n_attempts - 1):
        act("edit", path=path, change=f"attempt{j}")
        act("test", target=path)
        if var > 0 and rng.random() < 0.25 * var:
            act("read", path=path)         # re-examine after a failed attempt
    act("edit", path=path, change="fix")
    act("test", target=path)
    return steps


def _script_research(eid: int, rng, var: float = 1.0,
                     ident: Optional[str] = None) -> List[Step]:
    """search-visit motif."""
    ident = str(eid) if ident is None else ident
    st = AgentState()
    fac = StateFacade(st)
    steps: List[Step] = []

    def act(tool, **args):
        steps.append(Step(_model_work(rng), tool, dict(args), batchable=True))
        return execute_tool(tool, args, fac)

    n_rounds = int(rng.integers(1, 4))
    for k in range(n_rounds):
        r = act("search", query=f"topic_{ident}_{k}")
        if var > 0 and rng.random() < 0.3 * var:
            r2 = act("fetch", url=r["top"])    # bulk-fetch variant
        else:
            r2 = act("visit", url=r["top"])
        act("parse", path=r2["path"])
        if var > 0 and rng.random() < 0.25 * var:
            act("read", path=r2["path"])       # deep-dive variant
    return steps


def _script_setup(eid: int, rng, var: float = 1.0,
                  ident: Optional[str] = None) -> List[Step]:
    """environment setup motif (Level-2 heavy: exercises transformed
    speculation + staged writes)."""
    ident = str(eid) if ident is None else ident
    st = AgentState()
    fac = StateFacade(st)
    steps: List[Step] = []

    def act(tool, **args):
        steps.append(Step(_model_work(rng), tool, dict(args), batchable=True))
        return execute_tool(tool, args, fac)

    act("pip_install", pkg=f"dep_{ident}")
    if var > 0 and rng.random() < 0.3 * var:
        act("pip_install", pkg=f"extra_{ident}")  # second dependency variant
    act("build")
    r = act("grep", pattern=f"entry_{ident}")
    act("test", target=r["path"])
    if var > 0 and rng.random() < 0.25 * var:
        act("edit", path=r["path"], change="fix")   # post-setup patch variant
        act("test", target=r["path"])
    return steps


def _script_audit(eid: int, rng, var: float = 1.0,
                  ident: Optional[str] = None) -> List[Step]:
    """cross-cutting review motif: locate-examine interleaved with research
    before an edit-verify tail.  Passes THROUGH the other motifs' contexts
    with different continuations (e.g. grep,read -> search instead of edit;
    visit,parse -> edit instead of search), so shared-prefix fan-out shows
    up in the mined tables."""
    ident = str(eid) if ident is None else ident
    st = AgentState()
    fac = StateFacade(st)
    steps: List[Step] = []

    def act(tool, **args):
        steps.append(Step(_model_work(rng), tool, dict(args), batchable=True))
        return execute_tool(tool, args, fac)

    r = act("grep", pattern=f"audit_{ident}")
    act("read", path=r["path"])
    s = act("search", query=f"ref_{ident}")
    v = act("visit", url=s["top"])
    act("parse", path=v["path"])
    act("edit", path=r["path"], change="fix")
    act("test", target=r["path"])
    return steps


KINDS = {
    "fix_bug": _script_fix_bug,
    "research": _script_research,
    "setup": _script_setup,
    "audit": _script_audit,
}


@dataclass
class WorkloadConfig:
    seed: int = 0
    n_episodes: int = 20
    mix: Tuple[Tuple[str, float], ...] = (
        ("fix_bug", 0.5), ("research", 0.3), ("setup", 0.2),
    )
    variation: float = 1.0        # scales motif-variant probabilities;
                                  # 0 = deterministic legacy streams
    arrival_stagger: float = 0.0  # mean inter-arrival gap (exponential) for
                                  # staggered multi-tenant serving; 0 = all
                                  # tenants present at t=0 (legacy, and the
                                  # draw-for-draw reproduction guarantee:
                                  # no extra rng draws happen when off)
    shared_frac: float = 0.0      # probability an episode works on a SHARED
                                  # subject (drawn from a small global pool)
                                  # instead of its private one: tenants then
                                  # overlap on queries/paths/packages — the
                                  # corpus-overlap regime cross-tenant result
                                  # caching targets.  0 = fully tenant-
                                  # private (legacy, draw-for-draw: no rng
                                  # draw is taken when off)
    shared_pool: int = 4          # number of distinct shared subjects
    open_loop_rate: float = 0.0   # offered load (episodes/sec) for OPEN-LOOP
                                  # serving: every episode (including eid 0)
                                  # arrives after an additional exponential
                                  # gap with mean 1/rate, independent of how
                                  # fast the box drains.  Composes with
                                  # arrival_stagger (gaps add).  0 = closed
                                  # loop (legacy, draw-for-draw: no rng draw
                                  # is taken when off)


def open_loop_source(cfg: WorkloadConfig) -> Iterator[Episode]:
    """Lazy episode stream with nondecreasing arrivals.

    ``list(open_loop_source(cfg)) == make_episodes(cfg)`` draw-for-draw:
    the runtime can pull episodes one at a time mid-run (open-loop serving)
    while tests and closed-loop callers materialise the identical roster
    up front.  Arrival gaps are drawn AFTER each episode's own draws so
    every legacy stream reproduces exactly when both knobs are off."""
    rng = np.random.default_rng(cfg.seed)
    kinds, probs = zip(*cfg.mix, strict=True)
    t_arrive = 0.0
    for eid in range(cfg.n_episodes):
        kind = str(rng.choice(kinds, p=np.array(probs) / sum(probs)))
        # the cross-cutting audit motif rides on variation so that
        # variation=0 reproduces the legacy streams draw-for-draw
        if cfg.variation > 0 and "audit" not in dict(cfg.mix) \
                and rng.random() < 0.25 * cfg.variation:
            kind = "audit"
        # shared-corpus draw (serving workloads): some tenants work the same
        # subject, so identical (tool, args) invocations recur ACROSS
        # episodes — drawn only when the knob is on (legacy reproduction)
        ident = None
        if cfg.shared_frac > 0 and rng.random() < cfg.shared_frac:
            ident = f"shared{int(rng.integers(0, max(cfg.shared_pool, 1)))}"
        steps = KINDS[kind](eid, rng, cfg.variation, ident=ident)
        # Poisson-ish open arrivals: cumulative exponential gaps, drawn
        # AFTER the episode's own draws so arrival_stagger=0 keeps every
        # legacy stream draw-for-draw (no draw is taken when off)
        if cfg.arrival_stagger > 0 and eid > 0:
            t_arrive += float(rng.exponential(cfg.arrival_stagger))
        # open-loop offered load: an independent exponential inter-arrival
        # with mean 1/rate, charged to EVERY episode (the first tenant of a
        # sustained stream does not arrive at t=0).  Gaps add on top of any
        # stagger so the two processes compose.
        if cfg.open_loop_rate > 0:
            t_arrive += float(rng.exponential(1.0 / cfg.open_loop_rate))
        yield Episode(eid, kind, steps, arrival=t_arrive)


def make_episodes(cfg: WorkloadConfig) -> List[Episode]:
    return list(open_loop_source(cfg))


def episodes_to_traces(episodes: Sequence[Episode]) -> List[List[Event]]:
    """Offline mining traces: serially execute each episode and record events
    with real results (timestamps synthetic; mining is time-free)."""
    traces: List[List[Event]] = []
    for ep in episodes:
        st = AgentState()
        fac = StateFacade(st)
        t = 0.0
        trace: List[Event] = []
        for s in ep.steps:
            t += s.model_work
            res = execute_tool(s.tool, s.args, fac)
            dur = DEFAULT_TOOLS[s.tool].base_latency
            trace.append(Event("tool", s.tool, dict(s.args), res, t, t + dur, ep.eid))
            t += dur
        traces.append(trace)
    return traces
