"""Branch hypotheses H = (G, q, Φ, ρ, σ)  (paper Eq. 1, §4).

A hypothesis packages a *bounded local future subgraph* G (Tool /
Preparation / Model / Barrier-Commit nodes with edges), the follow
probability q, late-bound argument resolvers Φ, an aggregate multi-resource
profile ρ, and safety annotations σ.  Hypotheses are assembled online by
chaining PASTE pattern tuples from the pattern engine: each root candidate
(context → tool) is extended depth-first with its own most-likely
continuations, up to (max_depth, max_nodes) bounds, inserting PREP nodes
before cold tools and BARRIER nodes before Level-2 (staged-write) nodes.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import (
    DEFAULT_TOOLS, Event, ResourceVector, SafetyLevel, ToolSpec, signature,
)
from repro.core.patterns import ArgBinding, PatternEngine, PatternTuple


class NodeKind(str, Enum):
    TOOL = "tool"
    PREP = "prep"
    MODEL = "model"
    BARRIER = "barrier"


@dataclass
class Node:
    """One node of a future subgraph."""
    idx: int
    kind: NodeKind
    tool: str
    level: SafetyLevel
    rho: ResourceVector
    est_latency: float
    bindings: Tuple[ArgBinding, ...] = ()
    missing_args: Tuple[str, ...] = ()
    cond_prob: float = 1.0        # P(this node | parent executed)

    @property
    def speculative_allowed(self) -> bool:
        return self.level != SafetyLevel.NON_SPECULATIVE


@dataclass
class BranchHypothesis:
    """H_i = (G_i, q_i, Φ_i, ρ_i, σ_i)."""
    hid: int
    nodes: List[Node]
    edges: List[Tuple[int, int]]          # DAG over node idx
    q: float                              # follow probability
    context_key: Tuple                    # signature context it was built from
    created_t: float = 0.0

    # ---- derived ----
    @property
    def rho(self) -> ResourceVector:
        """Aggregate resource profile (peak over the serial chain = max)."""
        agg = ResourceVector()
        for n in self.nodes:
            agg = ResourceVector(
                max(agg.cpu, n.rho.cpu), max(agg.mem_bw, n.rho.mem_bw),
                max(agg.io, n.rho.io), max(agg.accel, n.rho.accel),
            )
        return agg

    @property
    def sigma(self) -> SafetyLevel:
        """Strictest safety class present."""
        return max((n.level for n in self.nodes), default=SafetyLevel.READ_ONLY)

    def solo_latency(self) -> float:
        return sum(n.est_latency for n in self.nodes)

    def safe_prefix(self, allow_staged: bool = True) -> List[Node]:
        """Longest speculatively-executable prefix (§6.3).

        MODEL nodes are future reasoning boundaries — never executed by the
        tool-speculation runtime (they bound the prefix).  BARRIER nodes
        bound the prefix unless the policy allows staged Level-2 execution
        (writes stay sandbox-local until authoritative confirmation either
        way).  NON_SPECULATIVE always bounds."""
        out = []
        for n in self.nodes:
            if n.kind == NodeKind.MODEL:
                break
            if n.kind == NodeKind.BARRIER and not allow_staged:
                break
            if n.level == SafetyLevel.NON_SPECULATIVE:
                break
            if n.kind == NodeKind.TOOL and n.missing_args:
                break   # model-originated args: not executable ahead of time
            if n.kind == NodeKind.BARRIER:
                continue
            out.append(n)
        return out

    def first_tool(self) -> Optional[Node]:
        for n in self.nodes:
            if n.kind == NodeKind.TOOL:
                return n
        return None


@dataclass
class HypothesisBuilder:
    engine: PatternEngine
    tools: Dict[str, ToolSpec] = field(default_factory=lambda: dict(DEFAULT_TOOLS))
    max_depth: int = 4
    max_nodes: int = 8
    branch_factor: int = 3
    min_q: float = 0.05
    with_prep: bool = True        # PREP nodes are a B-PASTE §4.1 feature
    _next_hid: itertools.count = field(default_factory=itertools.count)

    def _tool_node(self, idx: int, pt: PatternTuple, cond: float) -> Node:
        spec = self.tools[pt.tool]
        return Node(
            idx=idx, kind=NodeKind.TOOL, tool=pt.tool, level=spec.level,
            rho=spec.rho, est_latency=spec.base_latency,
            bindings=pt.bindings, missing_args=pt.missing_args, cond_prob=cond,
        )

    def build(self, history: Sequence[Event], now: float = 0.0,
              beam_width: int = 8) -> List[BranchHypothesis]:
        """Enumerate up to beam_width branch hypotheses for the current state."""
        roots = self.engine.predict(history, top=self.branch_factor)
        sigs = [signature(e) for e in history]
        hyps: List[BranchHypothesis] = []
        for root_pt, root_p in roots:
            chains = self._expand_chain(sigs, root_pt, root_p)
            for chain_pts, q in chains:
                if q < self.min_q:
                    continue
                hyps.append(self._assemble(chain_pts, q, history, now))
                if len(hyps) >= beam_width:
                    break
            if len(hyps) >= beam_width:
                break
        return hyps

    def _expand_chain(
        self, sigs: List, root: PatternTuple, root_p: float
    ) -> List[Tuple[List[PatternTuple], float]]:
        """Depth-first chains of pattern tuples: the root plus its most
        likely continuations (predicted signatures appended in sig space)."""
        chains: List[Tuple[List[PatternTuple], float]] = []

        def grow(chain: List[PatternTuple], q: float, pseudo_sigs: List):
            chains.append((list(chain), q))
            if len(chain) >= self.max_depth:
                return
            nxt = self.engine.predict_sigs(pseudo_sigs, top=1)
            for pt, p in nxt:
                if q * p < self.min_q or pt.next_sig is None:
                    continue
                grow(chain + [pt], q * p, pseudo_sigs + [pt.next_sig])

        grow([root], root_p, list(sigs) + [root.next_sig])
        # prefer deeper chains first (they subsume shallower ones), then q
        chains.sort(key=lambda c: (-len(c[0]), -c[1]))
        # dedup: keep the maximal chain per root tool sequence
        seen = set()
        out = []
        for chain, q in chains:
            key = tuple(pt.tool for pt in chain)
            if any(key == k[: len(key)] for k in seen):
                continue
            seen.add(key)
            out.append((chain, q))
        return out

    def _assemble(
        self, chain: List[PatternTuple], q: float, history: Sequence[Event], now: float
    ) -> BranchHypothesis:
        nodes: List[Node] = []
        edges: List[Tuple[int, int]] = []
        idx = 0
        prev: Optional[int] = None
        cold_tools = {"test", "build", "pip_install"}
        for depth, pt in enumerate(chain):
            spec = self.tools[pt.tool]
            # preparation node before cold tools (speculative warm-up, §4.1)
            if self.with_prep and pt.tool in cold_tools:
                prep_spec = self.tools["env_warmup"]
                nodes.append(Node(idx, NodeKind.PREP, "env_warmup",
                                  prep_spec.level, prep_spec.rho,
                                  prep_spec.base_latency))
                if prev is not None:
                    edges.append((prev, idx))
                prev = idx
                idx += 1
            # commit barrier before Level-2 nodes (§4.1, §6.3)
            if spec.level >= SafetyLevel.STAGED_WRITE:
                nodes.append(Node(idx, NodeKind.BARRIER, "barrier",
                                  SafetyLevel.READ_ONLY, ResourceVector(), 0.0))
                if prev is not None:
                    edges.append((prev, idx))
                prev = idx
                idx += 1
            cond = pt.confidence if depth > 0 else 1.0
            nodes.append(self._tool_node(idx, pt, cond))
            if prev is not None:
                edges.append((prev, idx))
            prev = idx
            idx += 1
            if idx >= self.max_nodes:
                break
        # model node: the reasoning boundary that this branch would unlock
        model_spec = self.tools["model_step"]
        nodes.append(Node(idx, NodeKind.MODEL, "model_step", model_spec.level,
                          model_spec.rho, model_spec.base_latency))
        if prev is not None:
            edges.append((prev, idx))
        hist_key = tuple(signature(e) for e in history[-2:])
        return BranchHypothesis(
            hid=next(self._next_hid), nodes=nodes, edges=edges, q=q,
            context_key=hist_key, created_t=now,
        )
