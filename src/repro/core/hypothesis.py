"""Branch hypotheses H = (G, q, Φ, ρ, σ)  (paper Eq. 1, §4).

A hypothesis packages a *bounded local future subgraph* G (Tool /
Preparation / Model / Barrier-Commit nodes with edges), the follow
probability q, late-bound argument resolvers Φ, an aggregate multi-resource
profile ρ, and safety annotations σ.  Hypotheses are assembled online from
PASTE pattern tuples: each root candidate (context → tool) is grown
best-first into a bounded **tree** — every node is extended with the top
``branch_factor`` continuations from the pattern engine, with the parent's
follow mass split across children via the empirical conditional
probabilities — up to (max_depth, max_nodes) bounds, inserting PREP nodes
before cold tools and BARRIER nodes before Level-2 (staged-write) nodes.
The beam is filled with one tree per predicted root (multi-root fill, roots
drawn with merged context backoff), so no single root can monopolize
``beam_width``.

``assembly="chain"`` keeps the pre-tree behavior (each root expanded with
its single most likely continuation into a linear chain) as a measured
baseline for benchmarks/bench_beam.py.

Paper anchor: Eq. 1 (hypothesis tuple), §4 (bounded local future
subgraphs), §6.3 (safe prefix — here the frontier region
``safe_prefix()``), §7 (PREP/BARRIER insertion per safety level).
Upstream: patterns.py (root predictions, continuations, arg bindings),
events.py (ToolSpec ρ/latency/levels).  Downstream: scoring.py packs
beams of these into padded tables, admission.py admits them, runtime.py
executes them as HypRun branches inside sandboxes.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import (
    DEFAULT_TOOLS, Event, ResourceVector, SafetyLevel, ToolSpec, signature,
)
from repro.core.patterns import ArgBinding, PatternEngine, PatternTuple


class NodeKind(str, Enum):
    TOOL = "tool"
    PREP = "prep"
    MODEL = "model"
    BARRIER = "barrier"


@dataclass
class Node:
    """One node of a future subgraph."""
    idx: int
    kind: NodeKind
    tool: str
    level: SafetyLevel
    rho: ResourceVector
    est_latency: float
    bindings: Tuple[ArgBinding, ...] = ()
    missing_args: Tuple[str, ...] = ()
    cond_prob: float = 1.0        # P(this node | parent executed)

    @property
    def speculative_allowed(self) -> bool:
        return self.level != SafetyLevel.NON_SPECULATIVE


@dataclass
class BranchHypothesis:
    """H_i = (G_i, q_i, Φ_i, ρ_i, σ_i)."""
    hid: int
    nodes: List[Node]
    edges: List[Tuple[int, int]]          # DAG over node idx
    q: float                              # follow probability
    context_key: Tuple                    # signature context it was built from
    created_t: float = 0.0
    model_idx: int = -1                   # idx of the terminal MODEL join
    spine_leaf: int = -1                  # idx of the max-path_q leaf: the
                                          # continuation the speculative
                                          # model step assumes (two-segment
                                          # assembly emits this leaf's edge
                                          # into the MODEL join FIRST, so
                                          # path_to(model_idx) walks the
                                          # spine)

    # ---- derived ----
    @property
    def rho(self) -> ResourceVector:
        """Aggregate resource profile (peak over the serial chain = max)."""
        agg = ResourceVector()
        for n in self.nodes:
            agg = ResourceVector(
                max(agg.cpu, n.rho.cpu), max(agg.mem_bw, n.rho.mem_bw),
                max(agg.io, n.rho.io), max(agg.accel, n.rho.accel),
            )
        return agg

    @property
    def sigma(self) -> SafetyLevel:
        """Strictest safety class present."""
        return max((n.level for n in self.nodes), default=SafetyLevel.READ_ONLY)

    def solo_latency(self) -> float:
        return sum(n.est_latency for n in self.nodes)

    def parent_map(self) -> Dict[int, Tuple[int, ...]]:
        """idx -> parent idx tuple.  Nodes are emitted in topological order
        (parents precede children in ``nodes``); only the terminal MODEL
        join has more than one parent."""
        parents: Dict[int, List[int]] = {}
        for i, j in self.edges:
            parents.setdefault(j, []).append(i)
        return {j: tuple(ps) for j, ps in parents.items()}

    def path_to(self, idx: int,
                parents: Optional[Dict[int, Tuple[int, ...]]] = None) -> List[int]:
        """Root-to-node index path.  Every non-MODEL node has at most one
        parent, so the path is unique (MODEL joins are never path targets).
        Callers holding a cached ``parent_map()`` can pass it in."""
        if parents is None:
            parents = self.parent_map()
        path = [idx]
        while True:
            ps = parents.get(path[0], ())
            if not ps:
                return path
            path.insert(0, ps[0])

    def safe_prefix(self, allow_staged: bool = True) -> List[Node]:
        """Speculatively-executable frontier region of G (§6.3).

        A node is in the prefix iff it is executable AND every ancestor on
        its root path is prefix-transparent — a per-branch generalization of
        the linear "longest prefix": one blocked branch no longer cuts off
        its siblings.  MODEL nodes are future reasoning boundaries — never
        executed by the tool-speculation runtime (they bound their branch).
        BARRIER nodes bound a branch unless the policy allows staged Level-2
        execution (writes stay sandbox-local until authoritative
        confirmation either way); when passed they are transparent but not
        emitted.  NON_SPECULATIVE and model-originated-args TOOL nodes bound
        their branch."""
        parents = self.parent_map()
        open_: Dict[int, bool] = {}
        out = []
        for n in self.nodes:                       # topological order
            ps = parents.get(n.idx, ())
            if ps and not all(open_.get(p, False) for p in ps):
                open_[n.idx] = False
                continue
            if n.kind == NodeKind.MODEL:
                open_[n.idx] = False
                continue
            if n.kind == NodeKind.BARRIER:
                open_[n.idx] = allow_staged
                continue
            if n.level == SafetyLevel.NON_SPECULATIVE:
                open_[n.idx] = False
                continue
            if n.kind == NodeKind.TOOL and n.missing_args:
                open_[n.idx] = False   # model-originated args: not executable
                continue
            open_[n.idx] = True
            out.append(n)
        return out

    def first_tool(self) -> Optional[Node]:
        for n in self.nodes:
            if n.kind == NodeKind.TOOL:
                return n
        return None


COLD_TOOLS = frozenset({"test", "build", "pip_install"})


def barrier_violations(h: BranchHypothesis) -> List[int]:
    """Node indices of Level-2+ TOOL nodes missing their commit BARRIER.

    The assembly invariant (§4.1, §6.3): every TOOL node whose safety level
    is STAGED_WRITE or stricter has a BARRIER node as its immediate parent,
    so staged writes can never leak past an unconfirmed prefix.  The static
    analyzer (core/analysis.py rule R4) checks this on real assembled beams
    rather than trusting the builder."""
    by_idx = {n.idx: n for n in h.nodes}
    parents = h.parent_map()
    bad: List[int] = []
    for n in h.nodes:
        if n.kind != NodeKind.TOOL or n.level < SafetyLevel.STAGED_WRITE:
            continue
        ps = parents.get(n.idx, ())
        if not any(by_idx[p].kind == NodeKind.BARRIER for p in ps):
            bad.append(n.idx)
    return bad


@dataclass
class _TreeNode:
    """Expansion-time tree of pattern tuples (pre-assembly)."""
    pt: PatternTuple
    cond: float                   # P(this node | parent executed)
    path_q: float                 # root_p · Π cond along the root path
    depth: int
    children: List["_TreeNode"] = field(default_factory=list)


@dataclass
class HypothesisBuilder:
    engine: PatternEngine
    tools: Dict[str, ToolSpec] = field(default_factory=lambda: dict(DEFAULT_TOOLS))
    max_depth: int = 4
    max_nodes: int = 8
    branch_factor: int = 3
    min_q: float = 0.05
    with_prep: bool = True        # PREP nodes are a B-PASTE §4.1 feature
    assembly: str = "tree"        # "tree" | "chain" (pre-tree linear baseline)
    spec_steps: bool = False      # two-segment trees: continue past the MODEL
                                  # join with the mined table's top predicted
                                  # continuation (speculative reasoning steps)
    _next_hid: itertools.count = field(default_factory=itertools.count)

    def _context_key(self, history: Sequence[Event]) -> Tuple:
        """Signature suffix identifying the build context — as long as the
        engine's mining context (NOT a hard-coded 2: an engine configured
        with a different ``context_len`` must produce keys the runtime's
        carry-over classification can compare against its own tails)."""
        cl = self.engine.context_len
        return tuple(signature(e) for e in history[-cl:]) if cl > 0 else ()

    def _tool_node(self, idx: int, pt: PatternTuple, cond: float) -> Node:
        spec = self.tools[pt.tool]
        return Node(
            idx=idx, kind=NodeKind.TOOL, tool=pt.tool, level=spec.level,
            rho=spec.rho, est_latency=spec.base_latency,
            bindings=pt.bindings, missing_args=pt.missing_args, cond_prob=cond,
        )

    def build(self, history: Sequence[Event], now: float = 0.0,
              beam_width: int = 8) -> List[BranchHypothesis]:
        """Enumerate up to beam_width branch hypotheses for the current state.

        Tree assembly: one bounded tree-shaped subgraph per predicted root,
        roots drawn with merged context-backoff (multi-root fill — beam
        width is bounded by root supply, never by the first root saturating
        it).  Chain assembly (baseline): linear chains, first root may
        monopolize the beam."""
        if self.assembly == "chain":
            return self._build_chains(history, now, beam_width)
        sigs = [signature(e) for e in history]
        # multi-root fill: one bounded tree per predicted root (merged
        # backoff supplies roots past the most specific table's fan-out),
        # so the beam width is bounded by root supply, never by the first
        # root saturating it
        roots = self.engine.predict_sigs(sigs, top=beam_width, backoff="merge")
        hyps: List[BranchHypothesis] = []
        for root_pt, root_p in roots:
            if root_p < self.min_q:
                continue
            tree = self._expand_tree(sigs, root_pt, root_p)
            hyps.append(self._assemble_tree(tree, root_p, history, now))
        return hyps

    def _build_chains(self, history: Sequence[Event], now: float,
                      beam_width: int) -> List[BranchHypothesis]:
        roots = self.engine.predict(history, top=self.branch_factor)
        sigs = [signature(e) for e in history]
        hyps: List[BranchHypothesis] = []
        for root_pt, root_p in roots:
            chains = self._expand_chain(sigs, root_pt, root_p)
            for chain_pts, q in chains:
                if q < self.min_q:
                    continue
                hyps.append(self._assemble(chain_pts, q, history, now))
                if len(hyps) >= beam_width:
                    break
            if len(hyps) >= beam_width:
                break
        return hyps

    def _node_cost(self, pt: PatternTuple) -> int:
        """Assembled-node footprint of one pattern tuple (tool node plus any
        PREP / BARRIER helpers _assemble_tree will insert before it)."""
        cost = 1
        if self.with_prep and pt.tool in COLD_TOOLS:
            cost += 1
        if self.tools[pt.tool].level >= SafetyLevel.STAGED_WRITE:
            cost += 1
        return cost

    def _expand_tree(
        self, sigs: List, root: PatternTuple, root_p: float
    ) -> _TreeNode:
        """Best-first tree growth: repeatedly take the highest-path-probability
        node and attach its top ``branch_factor`` continuations, splitting the
        parent's follow mass across children via the empirical conditional
        probabilities (predicted signatures appended in sig space).  Bounded
        by ``max_depth`` (tools per path), ``max_nodes`` (assembled node
        budget) and ``min_q`` (path-probability floor)."""
        root_tn = _TreeNode(root, 1.0, root_p, 1)
        budget = self.max_nodes - self._node_cost(root)
        heap: List[Tuple[float, int, _TreeNode, List]] = []
        ctr = itertools.count()

        def push(tn: _TreeNode, pseudo_sigs: List):
            if tn.depth < self.max_depth:
                heapq.heappush(heap, (-tn.path_q, next(ctr), tn, pseudo_sigs))

        push(root_tn, list(sigs) + [root.next_sig])
        while heap and budget > 0:
            _, _, tn, pseudo = heapq.heappop(heap)
            for pt, p in self.engine.predict_sigs(pseudo, top=self.branch_factor):
                q_child = tn.path_q * p
                if q_child < self.min_q or pt.next_sig is None:
                    continue
                cost = self._node_cost(pt)
                if cost > budget:
                    continue
                budget -= cost
                child = _TreeNode(pt, p, q_child, tn.depth + 1)
                tn.children.append(child)
                push(child, pseudo + [pt.next_sig])
        return root_tn

    def _assemble_tree(
        self, tree: _TreeNode, q: float, history: Sequence[Event], now: float
    ) -> BranchHypothesis:
        """Emit the bounded subgraph G: PREP before cold tools, BARRIER
        before Level-2 nodes (both on the branch's own path), branching edges
        at interior nodes, and a single MODEL join behind every leaf (the
        reasoning boundary whichever branch the agent follows).

        With ``spec_steps`` the tree is **two-segment**: the spine (max
        path-probability root-to-leaf path) continues PAST the MODEL join
        with the mined table's top predicted continuation — the reasoning
        outcome a speculative model step would assume.  The spine leaf's
        edge into the MODEL join is emitted first so ``path_to(model_idx)``
        walks the spine (``path_to`` follows first parents)."""
        nodes: List[Node] = []
        edges: List[Tuple[int, int]] = []
        leaves: List[int] = []
        leaf_info: List[Tuple[int, float, List]] = []
        idx = 0

        def emit(tn: _TreeNode, parent: Optional[int], path_sigs: List):
            nonlocal idx
            spec = self.tools[tn.pt.tool]
            prev = parent
            # preparation node before cold tools (speculative warm-up, §4.1)
            if self.with_prep and tn.pt.tool in COLD_TOOLS:
                prep_spec = self.tools["env_warmup"]
                nodes.append(Node(idx, NodeKind.PREP, "env_warmup",
                                  prep_spec.level, prep_spec.rho,
                                  prep_spec.base_latency))
                if prev is not None:
                    edges.append((prev, idx))
                prev = idx
                idx += 1
            # commit barrier before Level-2 nodes (§4.1, §6.3)
            if spec.level >= SafetyLevel.STAGED_WRITE:
                nodes.append(Node(idx, NodeKind.BARRIER, "barrier",
                                  SafetyLevel.READ_ONLY, ResourceVector(), 0.0))
                if prev is not None:
                    edges.append((prev, idx))
                prev = idx
                idx += 1
            nodes.append(self._tool_node(idx, tn.pt, tn.cond))
            if prev is not None:
                edges.append((prev, idx))
            tool_idx = idx
            idx += 1
            if not tn.children:
                leaves.append(tool_idx)
                leaf_info.append((tool_idx, tn.path_q, path_sigs))
            for child in tn.children:
                emit(child, tool_idx, path_sigs + [child.pt.next_sig])

        sigs = [signature(e) for e in history]
        emit(tree, None, sigs + [tree.pt.next_sig])
        # spine: max-path_q root-to-leaf path (ties break to emission order)
        spine_idx, _, spine_sigs = max(leaf_info, key=lambda t: t[1])
        # model node: the reasoning boundary that this subgraph would unlock
        model_spec = self.tools["model_step"]
        midx = idx
        nodes.append(Node(midx, NodeKind.MODEL, "model_step", model_spec.level,
                          model_spec.rho, model_spec.base_latency))
        if self.spec_steps:
            # spine leaf first: path_to(model_idx) must walk the spine
            for leaf in [spine_idx] + [lf for lf in leaves if lf != spine_idx]:
                edges.append((leaf, midx))
            self._emit_segment2(nodes, edges, midx, spine_sigs)
        else:
            for leaf in leaves:
                edges.append((leaf, midx))
        hist_key = self._context_key(history)
        return BranchHypothesis(
            hid=next(self._next_hid), nodes=nodes, edges=edges, q=q,
            context_key=hist_key, created_t=now,
            model_idx=midx, spine_leaf=spine_idx,
        )

    def _emit_segment2(self, nodes: List[Node], edges: List[Tuple[int, int]],
                       model_idx: int, spine_sigs: List) -> None:
        """Segment 2 of a two-segment tree: the mined table's top
        continuation PAST the reasoning boundary.  Model steps never appear
        in the mined signature stream, so the same ``predict_sigs`` call
        that would have extended the spine leaf predicts what the agent's
        next reasoning step will decide.  The subtree stays closed (MODEL
        is not in ``safe_prefix``) until the runtime validates a speculative
        model step against the authoritative history; it then launches like
        any frontier node.  PREP/BARRIER helpers are inserted exactly as in
        segment 1 (R4: staged writes keep their commit barrier)."""
        preds = self.engine.predict_sigs(spine_sigs, top=1)
        if not preds:
            return
        pt, p = preds[0]
        if p < self.min_q or pt.next_sig is None:
            return
        spec = self.tools[pt.tool]
        idx = len(nodes)
        prev = model_idx
        if self.with_prep and pt.tool in COLD_TOOLS:
            prep_spec = self.tools["env_warmup"]
            nodes.append(Node(idx, NodeKind.PREP, "env_warmup",
                              prep_spec.level, prep_spec.rho,
                              prep_spec.base_latency))
            edges.append((prev, idx))
            prev = idx
            idx += 1
        if spec.level >= SafetyLevel.STAGED_WRITE:
            nodes.append(Node(idx, NodeKind.BARRIER, "barrier",
                              SafetyLevel.READ_ONLY, ResourceVector(), 0.0))
            edges.append((prev, idx))
            prev = idx
            idx += 1
        nodes.append(self._tool_node(idx, pt, p))
        edges.append((prev, idx))

    def _expand_chain(
        self, sigs: List, root: PatternTuple, root_p: float
    ) -> List[Tuple[List[PatternTuple], float]]:
        """Depth-first chains of pattern tuples: the root plus its most
        likely continuations (predicted signatures appended in sig space)."""
        chains: List[Tuple[List[PatternTuple], float]] = []

        def grow(chain: List[PatternTuple], q: float, pseudo_sigs: List):
            chains.append((list(chain), q))
            if len(chain) >= self.max_depth:
                return
            nxt = self.engine.predict_sigs(pseudo_sigs, top=1)
            for pt, p in nxt:
                if q * p < self.min_q or pt.next_sig is None:
                    continue
                grow(chain + [pt], q * p, pseudo_sigs + [pt.next_sig])

        grow([root], root_p, list(sigs) + [root.next_sig])
        # prefer deeper chains first (they subsume shallower ones), then q
        chains.sort(key=lambda c: (-len(c[0]), -c[1]))
        # dedup: keep the maximal chain per root tool sequence
        seen = set()
        out = []
        for chain, q in chains:
            key = tuple(pt.tool for pt in chain)
            if any(key == k[: len(key)] for k in seen):
                continue
            seen.add(key)
            out.append((chain, q))
        return out

    def _assemble(
        self, chain: List[PatternTuple], q: float, history: Sequence[Event], now: float
    ) -> BranchHypothesis:
        nodes: List[Node] = []
        edges: List[Tuple[int, int]] = []
        idx = 0
        prev: Optional[int] = None
        for depth, pt in enumerate(chain):
            spec = self.tools[pt.tool]
            # preparation node before cold tools (speculative warm-up, §4.1)
            if self.with_prep and pt.tool in COLD_TOOLS:
                prep_spec = self.tools["env_warmup"]
                nodes.append(Node(idx, NodeKind.PREP, "env_warmup",
                                  prep_spec.level, prep_spec.rho,
                                  prep_spec.base_latency))
                if prev is not None:
                    edges.append((prev, idx))
                prev = idx
                idx += 1
            # commit barrier before Level-2 nodes (§4.1, §6.3)
            if spec.level >= SafetyLevel.STAGED_WRITE:
                nodes.append(Node(idx, NodeKind.BARRIER, "barrier",
                                  SafetyLevel.READ_ONLY, ResourceVector(), 0.0))
                if prev is not None:
                    edges.append((prev, idx))
                prev = idx
                idx += 1
            cond = pt.confidence if depth > 0 else 1.0
            nodes.append(self._tool_node(idx, pt, cond))
            if prev is not None:
                edges.append((prev, idx))
            prev = idx
            idx += 1
            if idx >= self.max_nodes:
                break
        # model node: the reasoning boundary that this branch would unlock
        model_spec = self.tools["model_step"]
        nodes.append(Node(idx, NodeKind.MODEL, "model_step", model_spec.level,
                          model_spec.rho, model_spec.base_latency))
        if prev is not None:
            edges.append((prev, idx))
        hist_key = self._context_key(history)
        return BranchHypothesis(
            hid=next(self._next_hid), nodes=nodes, edges=edges, q=q,
            context_key=hist_key, created_t=now,
            model_idx=idx, spine_leaf=prev if prev is not None else -1,
        )
