"""Copy-on-write execution sandbox S = (M, F, E, H)  (paper Eq. 2, §4.2).

Reads fall through to the base state; writes are overlay-isolated until
promotion.  Mis-speculation consumes bounded resources but never corrupts
the live authoritative state.  Promotion (`commit`) merges the overlay into
the base iff the base has not diverged under the sandbox (version check);
`squash` drops everything.

Paper anchor: Eq. 2 / §4.2 (sandbox tuple S, state-safety constraints σ).
Upstream: runtime.py creates one Sandbox per admitted branch.
Downstream: executor.py runs tools against the CoW views; memo.py
validates store entries through them (``state_reader``) and uses the
shared ABSENT marker for footprint reads.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set

from repro.core.events import Event


_TOMBSTONE = object()


class _Absent:
    """Footprint marker: a read fell through to the caller's default — the
    key was not present in the state.  Shared by the executor's per-call
    footprint recording and the result store's validation (memo.py); it must
    be one object so identity checks agree across modules."""

    def __repr__(self):  # pragma: no cover - debug aid
        return "<ABSENT>"


ABSENT = _Absent()


class CowView:
    """Copy-on-write dict view over a base dict."""

    def __init__(self, base: Dict[str, Any]):
        self._base = base
        self._overlay: Dict[str, Any] = {}
        self.base_reads: Set[str] = set()   # keys read THROUGH to the base

    # -- reads fall through --
    def get(self, key: str, default=None):
        if key in self._overlay:
            v = self._overlay[key]
            return default if v is _TOMBSTONE else v
        self.base_reads.add(key)
        return self._base.get(key, default)

    def __contains__(self, key: str) -> bool:
        if key in self._overlay:
            return self._overlay[key] is not _TOMBSTONE
        return key in self._base

    def keys(self) -> Set[str]:
        ks = {k for k, v in self._overlay.items() if v is not _TOMBSTONE}
        ks |= {k for k in self._base if self._overlay.get(k) is not _TOMBSTONE}
        return ks

    # -- writes isolate --
    def set(self, key: str, value: Any):
        self._overlay[key] = value

    def delete(self, key: str):
        self._overlay[key] = _TOMBSTONE

    @property
    def dirty(self) -> Dict[str, Any]:
        return dict(self._overlay)

    def apply_to(self, target: Dict[str, Any]):
        for k, v in self._overlay.items():
            if v is _TOMBSTONE:
                target.pop(k, None)
            else:
                target[k] = v


@dataclass
class AgentState:
    """Authoritative live state: memory/context M, filesystem F, env E,
    history H — plus a version counter for promotion validity."""
    memory: Dict[str, Any] = field(default_factory=dict)
    fs: Dict[str, Any] = field(default_factory=dict)
    env: Dict[str, Any] = field(default_factory=dict)
    history: List[Event] = field(default_factory=list)
    version: int = 0

    def bump(self):
        self.version += 1


class Sandbox:
    """Branch-local S_i = (M_i, F_i, E_i, H_i) over an AgentState."""

    def __init__(self, base: AgentState, hid: int):
        self.hid = hid
        self._base = base
        self.base_version = base.version
        self.M = CowView(base.memory)
        self.F = CowView(base.fs)
        self.E = CowView(base.env)
        self.H: List[Event] = []          # branch-local execution history
        self.committed = False
        self.squashed = False

    # -- state-safety interface used by the executor --
    def record(self, ev: Event):
        self.H.append(ev)

    @property
    def write_set(self) -> Set[str]:
        return (
            {f"M:{k}" for k in self.M.dirty}
            | {f"F:{k}" for k in self.F.dirty}
            | {f"E:{k}" for k in self.E.dirty}
        )

    @property
    def base_read_set(self) -> Set[str]:
        """Keys this branch read from the LIVE base (speculation is invalid
        once an authoritative write touches any of them)."""
        return (
            {f"M:{k}" for k in self.M.base_reads}
            | {f"F:{k}" for k in self.F.base_reads}
            | {f"E:{k}" for k in self.E.base_reads}
        )

    def is_stale(self) -> bool:
        """Base advanced since the fork — replay validity must be re-checked."""
        return self._base.version != self.base_version

    def commit(self) -> bool:
        """Promote: merge overlay into the authoritative state.  Refuses when
        stale (the authoritative path wrote concurrently) — the caller then
        replays or squashes."""
        if self.squashed or self.committed:
            return False
        if self.is_stale():
            return False
        self.M.apply_to(self._base.memory)
        self.F.apply_to(self._base.fs)
        self.E.apply_to(self._base.env)
        self._base.history.extend(self.H)
        self._base.bump()
        self.base_version = self._base.version
        self.committed = True
        return True

    def squash(self):
        """Drop all speculative effects (bounded waste, zero corruption)."""
        self.squashed = True
        self.M = CowView(self._base.memory)
        self.F = CowView(self._base.fs)
        self.E = CowView(self._base.env)
        self.H = []

    def fork(self, hid: int) -> "Sandbox":
        """Nested branch prefix: fork a sandbox whose base view is this one."""
        child = Sandbox(self._base, hid)
        # seed child overlays with our current overlay (copy-on-write chain
        # flattened at fork time — overlays are small by construction)
        child.M._overlay.update(self.M._overlay)
        child.F._overlay.update(self.F._overlay)
        child.E._overlay.update(self.E._overlay)
        # the child's validity depends on everything its inherited prefix
        # read from the live base: without seeding the read-sets, an
        # authoritative write to a key only the PARENT prefix read slips
        # past the runtime's write-conflict check and the child replays on
        # silently-invalidated state
        child.M.base_reads |= self.M.base_reads
        child.F.base_reads |= self.F.base_reads
        child.E.base_reads |= self.E.base_reads
        child.H = list(self.H)
        return child
