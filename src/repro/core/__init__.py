"""B-PASTE core: the paper's system (scheduler, speculation, serving loop).

Pipeline (each module's own docstring carries its paper anchor and
neighbors; repo-level map in README.md):

    mining/prefixspan -> patterns -> hypothesis -> scoring -> admission
        -> runtime (phases 1-4) over simulator/interference,
           with sandbox+executor (state), safety (policy),
           memo (cross-episode result store),
           model_service (batched model-step queue),
           workload (episodes) and events (shared vocabulary).
"""
