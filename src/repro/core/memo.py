"""Runtime-global, safety-versioned speculative result store.

PR 3's serving bench showed the limit of execution-only speculation: at
saturation there is no slack to convert, so speculation stops paying.  This
module decouples speculative *value* from speculative *execution*: a tool
result computed once — speculatively in any tenant's sandbox, or
authoritatively on any tenant's live state — is published here and can be
*served* to a later identical invocation at zero execution cost ("Speculative
Actions" / SPORK's observation that a validated speculated result is
losslessly reusable).

Correctness model
-----------------
Entries are keyed on ``(tool, canonical args)`` and carry the call's exact
**footprint**: the namespaced keys it read (with the values observed — or an
ABSENT marker when the read fell through to the tool's internal default) and
the overlay it wrote (values, with TOMBSTONEs for deletes).  Tools are
deterministic functions of ``(args, reads)``, so a stored result is valid
for a target state iff every read key currently holds the recorded value
(absent keys must still be absent).  Serving then replays the stored write
overlay, which is exactly what re-execution would have produced.

Two mechanisms keep lookups cheap and entries honest:

* **Footprint invalidation** — every batch of authoritative writes bumps the
  store ``version`` and is intersected against the read index; an entry
  whose recorded read value now conflicts with a written value is
  invalidated eagerly (never whole-store, never whole-sandbox staleness).
* **Versioned validation cache** — value validation against a tenant's live
  state is memoized per ``(entry, tenant)`` at the store version it
  succeeded; any later authoritative write bumps the version and expires
  every cache implicitly.

The store is deliberately ignorant of episodes' AgentState internals: it
validates through a tiny reader protocol (``state_reader``) that works for
both live states and CoW sandboxes.

Pending entries (in-flight dedup)
---------------------------------
``begin`` registers an in-flight computation for a key; duplicate
speculative launches ``subscribe`` instead of burning slack twice, and the
first run's ``publish`` fires every subscriber with the finished entry
(``abort`` fires them with ``None`` so waiters can re-arm).

Paper anchor: the §4.2/§6 replayable-prefix reuse semantics, extended
runtime-global (a validated speculated result is losslessly reusable —
"Speculative Actions" / SPORK); safety gating follows §7 via
``EligibilityPolicy.servable``.
Upstream: executor.StateFacade (per-call read/write footprints),
sandbox.py (state readers for validation).  Downstream: runtime.py
(``_try_serve`` / ``_serve_spec`` / launch dedup), admission's EU reuse
term (``memo_mask`` + memo-excluded prefix ρ in scoring.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple, Union

from repro.core.events import SafetyLevel
from repro.core.sandbox import ABSENT, AgentState, Sandbox, _TOMBSTONE

MemoKey = Tuple[str, Tuple[Tuple[str, str], ...]]


def canonical_args(args: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    """Order-free, hashable argument skeleton.  ``repr`` keeps unhashable
    values (lists in results-derived args) keyable while staying exact for
    the str/int/bool payloads tools actually take."""
    return tuple(sorted((k, repr(v)) for k, v in args.items()))


def memo_key(tool: str, args: Dict[str, Any]) -> MemoKey:
    return (tool, canonical_args(args))


def state_reader(st: Union[AgentState, Sandbox],
                 track: bool = True) -> Callable[[str], Tuple[bool, Any]]:
    """(present, value) accessor over namespaced keys for either a live
    AgentState or a CoW Sandbox.

    For sandboxes, ``track=True`` reads through the CowView, so a
    validation read lands in the branch's base read-set — a SERVED entry's
    dependencies stay conflict-tracked exactly like executed reads.
    ``track=False`` peeks at overlay+base without recording: scoring-time
    validation runs for the whole candidate pool every tick, and recording
    those reads would hand every candidate branch a read-set it never
    earned (spurious write-conflict squashes)."""
    if isinstance(st, Sandbox):
        views = {"M": st.M, "F": st.F, "E": st.E}

        if track:
            def read(nskey: str) -> Tuple[bool, Any]:
                ns, k = nskey.split(":", 1)
                v = views[ns]
                return (k in v, v.get(k))
        else:
            def read(nskey: str) -> Tuple[bool, Any]:
                ns, k = nskey.split(":", 1)
                v = views[ns]
                if k in v._overlay:
                    ov = v._overlay[k]
                    if ov is _TOMBSTONE:
                        return (False, None)
                    return (True, ov)
                return (k in v._base, v._base.get(k))
    else:
        dicts = {"M": st.memory, "F": st.fs, "E": st.env}

        def read(nskey: str) -> Tuple[bool, Any]:
            ns, k = nskey.split(":", 1)
            d = dicts[ns]
            return (k in d, d.get(k))
    return read


@dataclass
class MemoEntry:
    tool: str
    args: Dict[str, Any]
    result: Any
    reads: Dict[str, Any]          # ns key -> observed value | ABSENT
    writes: Dict[str, Any]         # ns key -> written value | _TOMBSTONE
    level: SafetyLevel
    solo_work: float               # counterfactual solo latency (savings)
    base_version: int              # store version at publish time
    producer_eid: int
    valid: bool = True
    serves: int = 0
    # eid -> store version at which value validation last succeeded against
    # that tenant's live state (expires implicitly on any version bump)
    validated_at: Dict[int, int] = field(default_factory=dict)


@dataclass
class _Pending:
    owner_jid: int
    subscribers: List[Callable[[Optional[MemoEntry]], None]] = field(
        default_factory=list)


class ResultStore:
    """One per runtime: spans every episode/tenant (`BPasteRuntime.store`)."""

    def __init__(self):
        self.version: int = 0
        self.entries: Dict[MemoKey, MemoEntry] = {}
        self.pending: Dict[MemoKey, _Pending] = {}
        self._read_index: Dict[str, Set[MemoKey]] = {}
        self._tools: Dict[str, int] = {}     # tool -> live entry count
        # tool -> MONOTONE publish count.  A key can only BECOME servable
        # through a publish of its tool (invalidation/replacement only
        # retract), so a scoring-time "nothing for this node" verdict stays
        # correct until this counter moves — the memo-mask pass caches its
        # per-node verdicts against it (see BPasteRuntime._memo_terms).
        self.tool_pubs: Dict[str, int] = {}
        # counters (runtime copies these into Metrics at run end)
        self.publishes: int = 0
        self.invalidations: int = 0

    # -- lookup ---------------------------------------------------------
    def has_tool(self, tool: str) -> bool:
        """Cheap pre-filter for hot loops (memo-mask scoring): any valid
        entry for this tool at all?"""
        return self._tools.get(tool, 0) > 0

    def peek(self, tool: str, args: Dict[str, Any]) -> Optional[MemoEntry]:
        e = self.entries.get(memo_key(tool, args))
        return e if e is not None and e.valid else None

    def is_pending(self, key: MemoKey) -> bool:
        return key in self.pending

    # -- validation -----------------------------------------------------
    def validate(self, entry: MemoEntry,
                 st: Union[AgentState, Sandbox],
                 eid: Optional[int] = None, track: bool = True) -> bool:
        """Value-validate the entry's read footprint against ``st``.

        ``eid`` enables the versioned cache and must only be passed for a
        tenant's LIVE state (sandboxes of one episode diverge per branch, so
        a per-eid cache entry would alias across overlays).  ``track=False``
        keeps sandbox validation reads out of the branch's base read-set
        (see ``state_reader``) — use it for scoring-time peeks that do not
        commit to serving."""
        if not entry.valid:
            return False
        if eid is not None and entry.validated_at.get(eid) == self.version:
            return True
        read = state_reader(st, track=track)
        for nk, want in entry.reads.items():
            present, got = read(nk)
            if want is ABSENT:
                if present:
                    return False
            elif not present or got != want:
                return False
        if eid is not None:
            entry.validated_at[eid] = self.version
        return True

    def apply_writes(self, entry: MemoEntry,
                     st: Union[AgentState, Sandbox]) -> Set[str]:
        """Replay the stored overlay onto ``st`` (live dict or sandbox CoW
        view — sandbox writes stay overlay-isolated like executed ones).
        Returns the namespaced keys touched."""
        if isinstance(st, Sandbox):
            views = {"M": st.M, "F": st.F, "E": st.E}
            for nk, v in entry.writes.items():
                ns, k = nk.split(":", 1)
                if v is _TOMBSTONE:
                    views[ns].delete(k)
                else:
                    views[ns].set(k, v)
        else:
            dicts = {"M": st.memory, "F": st.fs, "E": st.env}
            for nk, v in entry.writes.items():
                ns, k = nk.split(":", 1)
                if v is _TOMBSTONE:
                    dicts[ns].pop(k, None)
                else:
                    dicts[ns][k] = v
        return set(entry.writes)

    # -- publication ----------------------------------------------------
    def publish(self, tool: str, args: Dict[str, Any], result: Any, *,
                reads: Dict[str, Any], writes: Dict[str, Any],
                level: SafetyLevel, solo_work: float,
                eid: int) -> MemoEntry:
        """Insert/refresh the entry for ``(tool, args)`` and resolve any
        pending computation for the key (subscribers fire with the entry)."""
        key = memo_key(tool, args)
        old = self.entries.get(key)
        if old is not None:
            self._deindex(key, old)
        entry = MemoEntry(tool, dict(args), result, dict(reads), dict(writes),
                          level, solo_work, self.version, eid)
        self.entries[key] = entry
        for nk in entry.reads:
            self._read_index.setdefault(nk, set()).add(key)
        self._tools[tool] = self._tools.get(tool, 0) + 1
        self.tool_pubs[tool] = self.tool_pubs.get(tool, 0) + 1
        self.publishes += 1
        self._resolve_pending(key, entry)
        return entry

    # -- in-flight dedup ------------------------------------------------
    def begin(self, key: MemoKey, owner_jid: int) -> None:
        self.pending[key] = _Pending(owner_jid)

    def subscribe(self, key: MemoKey,
                  cb: Callable[[Optional[MemoEntry]], None]) -> bool:
        p = self.pending.get(key)
        if p is None:
            return False
        p.subscribers.append(cb)
        return True

    def abort(self, key: Optional[MemoKey], owner_jid: int) -> None:
        """Owner died (preemption/squash): drop the pending entry and wake
        subscribers with None so their nodes can re-arm and launch
        themselves next tick."""
        if key is None:
            return
        p = self.pending.get(key)
        if p is None or p.owner_jid != owner_jid:
            return
        del self.pending[key]
        for cb in p.subscribers:
            cb(None)

    def _resolve_pending(self, key: MemoKey, entry: MemoEntry) -> None:
        p = self.pending.pop(key, None)
        if p is None:
            return
        for cb in p.subscribers:
            cb(entry)

    # -- invalidation ---------------------------------------------------
    def note_writes(self, write_values: Dict[str, Any]) -> None:
        """Authoritative writes landed (any tenant): bump the safety version
        and invalidate by FOOTPRINT INTERSECTION — only entries that read one
        of the written keys, and only when the written value actually
        conflicts with the value the entry observed (a write that re-asserts
        the observed value leaves the entry valid; serving still
        value-validates per target state either way)."""
        if not write_values:
            return
        self.version += 1
        for nk, wv in write_values.items():
            for key in list(self._read_index.get(nk, ())):
                entry = self.entries.get(key)
                if entry is None or not entry.valid:
                    continue
                want = entry.reads.get(nk, ABSENT)
                consistent = (
                    (want is ABSENT and wv is _TOMBSTONE)
                    or (want is not ABSENT and wv is not _TOMBSTONE
                        and wv == want)
                )
                if not consistent:
                    self.invalidate(key)

    def invalidate(self, key: MemoKey) -> None:
        entry = self.entries.get(key)
        if entry is None or not entry.valid:
            return
        entry.valid = False
        self.invalidations += 1
        self._deindex(key, entry)
        self.entries.pop(key, None)

    def _deindex(self, key: MemoKey, entry: MemoEntry) -> None:
        for nk in entry.reads:
            s = self._read_index.get(nk)
            if s is not None:
                s.discard(key)
                if not s:
                    del self._read_index[nk]
        n = self._tools.get(entry.tool, 0) - 1
        if n > 0:
            self._tools[entry.tool] = n
        else:
            self._tools.pop(entry.tool, None)

    # -- sanitizer support ----------------------------------------------
    def check_integrity(self) -> List[str]:
        """Cross-check the derived indices against the entry table (runtime
        sanitizer check S5).  Returns human-readable divergence descriptions
        (empty = coherent): read-index entries must point at live entries
        that actually read the key, every live entry must be indexed under
        each of its read keys, and the per-tool live counts must match."""
        problems: List[str] = []
        for nk, keys in self._read_index.items():
            for key in keys:
                e = self.entries.get(key)
                if e is None or not e.valid:
                    problems.append(f"read_index[{nk!r}] -> dead entry {key!r}")
                elif nk not in e.reads:
                    problems.append(
                        f"read_index[{nk!r}] -> entry {key!r} that never read it")
        tools: Dict[str, int] = {}
        for key, e in self.entries.items():
            if not e.valid:
                problems.append(f"entries[{key!r}] held while invalid")
                continue
            tools[e.tool] = tools.get(e.tool, 0) + 1
            for nk in e.reads:
                if key not in self._read_index.get(nk, ()):
                    problems.append(f"entry {key!r} missing from read_index[{nk!r}]")
        if tools != self._tools:
            problems.append(f"tool counts drifted: derived {tools} != cached {self._tools}")
        return problems

    def __len__(self) -> int:
        return len(self.entries)
