"""Event model: tools, event signatures, traces.

PASTE's key observation is that agent traces exhibit stable *application
level* control-flow patterns over **event signatures** — the (tool, arg
schema) skeleton of an invocation, NOT its high-variance textual payload.
B-PASTE mines short-horizon motifs over these signature streams and uses
them to assemble branch hypotheses.

Paper anchor: §3 (event signatures), §7 (SafetyLevel execution classes),
Eq. 2/4 (ResourceVector ρ — per-tool multi-resource demand).
Upstream: nothing (this is the shared vocabulary).  Downstream: everything
— mining/patterns consume signatures, hypothesis/scoring consume ToolSpec
ρ/latency, workload scripts episodes of Events, runtime executes them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import IntEnum
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np


class SafetyLevel(IntEnum):
    """Paper §7 execution levels."""
    PREP_ONLY = 0        # warm-up, session establishment
    READ_ONLY = 1        # pure fetch/grep/parse — replayable prefix
    STAGED_WRITE = 2     # mutating; branch-local only, commit barrier
    NON_SPECULATIVE = 3  # never speculate


@dataclass(frozen=True)
class ResourceVector:
    """Multi-resource demand/capacity ρ: (cpu cores, mem GB/s, io MB/s, accel slots)."""
    cpu: float = 0.0
    mem_bw: float = 0.0
    io: float = 0.0
    accel: float = 0.0

    def as_array(self) -> np.ndarray:
        return np.array([self.cpu, self.mem_bw, self.io, self.accel], np.float64)

    def __add__(self, o: "ResourceVector") -> "ResourceVector":
        return ResourceVector(
            self.cpu + o.cpu, self.mem_bw + o.mem_bw, self.io + o.io, self.accel + o.accel
        )

    def fits(self, cap: "ResourceVector") -> bool:
        return bool(np.all(self.as_array() <= cap.as_array() + 1e-9))


RESOURCE_DIMS = 4


@dataclass(frozen=True)
class ToolSpec:
    """Registered tool: safety class, resource profile, latency model, and a
    *declared* state footprint.

    ``reads``/``writes`` are glob patterns over namespaced state keys
    (``M:``/``F:``/``E:`` prefixes, fnmatch semantics) describing what the
    executor implementation may touch.  They are the contract the static
    analyzer (core/analysis.py rule R1) and the runtime sanitizer (S4) check
    the *tracked* per-call footprints against: a PREP_ONLY/READ_ONLY tool
    whose implementation writes outside its declaration is exactly the kind
    of mis-classification that lets a speculative run leak side effects."""
    name: str
    level: SafetyLevel
    rho: ResourceVector
    base_latency: float           # seconds, before interference
    latency_jitter: float = 0.2   # lognormal sigma
    transformed: Optional[str] = None  # speculative transform (e.g. dry-run)
    reads: Tuple[str, ...] = ()   # declared read footprint (glob patterns)
    writes: Tuple[str, ...] = ()  # declared write footprint (glob patterns)

    def sample_latency(self, rng: np.random.Generator) -> float:
        return float(self.base_latency * np.exp(rng.normal(0.0, self.latency_jitter)))

    def det_latency(self, args: Dict[str, Any]) -> float:
        """Deterministic latency for a concrete invocation: the same
        (tool, args) always takes the same time, so speculative and
        authoritative executions of one action agree exactly and scheduler
        modes are compared on identical ground truth."""
        import hashlib
        key = f"{self.name}|{sorted(args.items())!r}"
        seed = int(hashlib.sha1(key.encode()).hexdigest()[:8], 16)
        g = np.random.default_rng(seed)
        return float(self.base_latency * np.exp(g.normal(0.0, self.latency_jitter)))


# ----------------------------------------------------------------------
# Default edge-agent tool registry (Thor-class profiles).
# Latencies/profiles follow PASTE's characterization: tool execution is a
# substantial fraction of end-to-end latency; motifs like edit-verify,
# locate-examine, search-visit recur.
# ----------------------------------------------------------------------

DEFAULT_TOOLS: Dict[str, ToolSpec] = {
    t.name: t
    for t in [
        # Latency profile follows PASTE's characterization: tool execution
        # is a substantial (~50-60%) fraction of end-to-end agent latency.
        # ``reads``/``writes`` declare the executor footprint (checked by
        # core/analysis.py R1 against a tracked dry-run).  visit/fetch are
        # READ_ONLY yet declare an F: write: the read-through cache write is
        # an L1-safe idempotent materialization, declared so the analyzer
        # can tell it from an *undeclared* side effect.
        ToolSpec("search", SafetyLevel.READ_ONLY, ResourceVector(0.2, 0.5, 5, 0), 2.5),
        ToolSpec("visit", SafetyLevel.READ_ONLY, ResourceVector(0.3, 1.0, 20, 0), 4.0,
                 writes=("F:*",)),
        ToolSpec("fetch", SafetyLevel.READ_ONLY, ResourceVector(0.2, 1.0, 30, 0), 3.0,
                 writes=("F:*",)),
        ToolSpec("grep", SafetyLevel.READ_ONLY, ResourceVector(1.0, 4.0, 50, 0), 1.5),
        ToolSpec("read", SafetyLevel.READ_ONLY, ResourceVector(0.3, 2.0, 20, 0), 0.8,
                 reads=("F:*",)),
        ToolSpec("parse", SafetyLevel.READ_ONLY, ResourceVector(1.0, 2.0, 5, 0), 2.0,
                 reads=("F:*",)),
        ToolSpec("edit", SafetyLevel.STAGED_WRITE, ResourceVector(0.5, 1.0, 10, 0), 1.2,
                 writes=("F:*",)),
        ToolSpec("test", SafetyLevel.STAGED_WRITE, ResourceVector(2.0, 6.0, 30, 0), 8.0,
                 reads=("F:*",)),
        ToolSpec("build", SafetyLevel.STAGED_WRITE, ResourceVector(3.0, 8.0, 60, 0), 10.0,
                 writes=("E:built",)),
        ToolSpec("pip_install", SafetyLevel.STAGED_WRITE,
                 ResourceVector(1.0, 2.0, 40, 0), 8.0, transformed="pip_download",
                 writes=("E:pkg:*",)),
        ToolSpec("pip_download", SafetyLevel.READ_ONLY, ResourceVector(0.5, 1.0, 40, 0), 5.0,
                 writes=("F:cache/*",)),
        ToolSpec("session_init", SafetyLevel.PREP_ONLY, ResourceVector(0.5, 1.0, 5, 0), 1.0,
                 writes=("E:warm:*",)),
        ToolSpec("env_warmup", SafetyLevel.PREP_ONLY, ResourceVector(1.0, 2.0, 10, 0), 2.0,
                 writes=("E:warm:*",)),
        ToolSpec("deploy", SafetyLevel.NON_SPECULATIVE, ResourceVector(1.0, 2.0, 20, 0), 4.0,
                 writes=("E:deployed",)),
        # model reasoning step as a pseudo-tool (runs on the accelerator)
        ToolSpec("model_step", SafetyLevel.READ_ONLY, ResourceVector(0.5, 2.0, 0, 1), 2.5),
    ]
}


@dataclass
class Event:
    """One step of an agent trace."""
    kind: str                     # 'tool' | 'model'
    tool: str
    args: Dict[str, Any] = field(default_factory=dict)
    result: Any = None
    t_start: float = 0.0
    t_end: float = 0.0
    request_id: int = 0

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


def arg_schema(args: Dict[str, Any]) -> Tuple[str, ...]:
    """Structural argument skeleton (sorted key:type), payload-free."""
    return tuple(f"{k}:{type(v).__name__}" for k, v in sorted(args.items()))


def signature(ev: Event) -> Tuple[str, str, Tuple[str, ...]]:
    """Payload-free event signature: (kind, tool, arg schema)."""
    return (ev.kind, ev.tool, arg_schema(ev.args))


def sig_str(ev: Event) -> str:
    return f"{ev.tool}({','.join(arg_schema(ev.args))})"


Trace = List[Event]


def trace_signatures(trace: Trace) -> List[Tuple[str, str, Tuple[str, ...]]]:
    return [signature(e) for e in trace]
