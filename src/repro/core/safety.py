"""Operator-defined eligibility policy (paper §7).

Three execution levels (Level 0 prep-only / Level 1 read-only-replayable /
Level 2 staged-write) plus per-tool overrides and *transformed speculation*
(PASTE's example: web search speculates freely while pip_install degrades to
a dry-run/download-only variant).  By construction no speculative side
effect becomes externally visible unless the authoritative path converges —
commits require authoritative confirmation (sandbox.commit at promotion).

Paper anchor: §7 (execution levels, operator policy), Eq. 1's σ.
Upstream: events.py (ToolSpec default levels/transforms).  Downstream:
runtime.py (speculative_form gating at beam build, ``servable`` gating of
store serves), hypothesis.py (BARRIER insertion before Level-2 nodes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.events import DEFAULT_TOOLS, SafetyLevel, ToolSpec


@dataclass
class EligibilityPolicy:
    """max_level: strictest class the runtime may *speculatively start*.
    Level-2 nodes may only run inside a sandbox behind a commit barrier."""
    max_level: SafetyLevel = SafetyLevel.STAGED_WRITE
    overrides: Dict[str, SafetyLevel] = field(default_factory=dict)
    transforms: Dict[str, str] = field(default_factory=dict)
    tools: Dict[str, ToolSpec] = field(default_factory=lambda: dict(DEFAULT_TOOLS))

    def __post_init__(self):
        for name, spec in self.tools.items():
            if not spec.transformed or name in self.transforms:
                continue
            # An explicit operator override to NON_SPECULATIVE is a ban:
            # do NOT auto-install the spec's transform for it, or the tool
            # keeps speculating through the degraded variant anyway.
            if self.overrides.get(name) == SafetyLevel.NON_SPECULATIVE:
                continue
            self.transforms[name] = spec.transformed

    def level(self, tool: str) -> SafetyLevel:
        if tool in self.overrides:
            return self.overrides[tool]
        spec = self.tools.get(tool)
        return spec.level if spec else SafetyLevel.NON_SPECULATIVE

    def eligible(self, tool: str) -> bool:
        """True iff the tool can speculate in *some* form.  Definitionally
        ``speculative_form(tool) is not None`` — keeping the two in sync by
        construction (they drifted before: a transform-degradable staged
        write under a READ_ONLY policy was form-runnable but "ineligible")."""
        return self.speculative_form(tool) is not None

    def speculative_form(self, tool: str) -> Optional[Tuple[str, bool]]:
        """(tool_to_run, transformed?) for speculative execution, or None if
        ineligible.  Tools above max_level degrade to their transformed
        variant when one exists *and the transform target itself clears the
        policy*.  An explicit NON_SPECULATIVE override is an operator ban
        and wins over any transform."""
        if self.overrides.get(tool) == SafetyLevel.NON_SPECULATIVE:
            return None
        lvl = self.level(tool)
        if lvl != SafetyLevel.NON_SPECULATIVE and lvl <= self.max_level:
            return (tool, False)          # Level-2 ⇒ sandbox + barrier
        t2 = self.transforms.get(tool)
        if t2 is not None:
            lvl2 = self.level(t2)
            if lvl2 != SafetyLevel.NON_SPECULATIVE and lvl2 <= self.max_level:
                return (t2, True)
        return None

    def servable(self, tool: str) -> Optional[str]:
        """How a stored result may satisfy an AUTHORITATIVE action from the
        cross-episode result store (memo.py):

          "direct" — PREP_ONLY / READ_ONLY: the result is replayable by
                     definition, serve it as-is (only when the policy admits
                     speculation at that level at all — a stored result only
                     exists because some runtime speculated the action);
          "replay" — STAGED_WRITE: serve by replaying the stored write
                     overlay through the commit barrier onto the live state
                     (version bump included), allowed only when the operator
                     admits staged speculation at all;
          None     — NON_SPECULATIVE (and anything above max_level): always
                     re-execute authoritatively.
        """
        if self.overrides.get(tool) == SafetyLevel.NON_SPECULATIVE:
            return None
        lvl = self.level(tool)
        if lvl <= SafetyLevel.READ_ONLY and lvl <= self.max_level:
            return "direct"
        if lvl == SafetyLevel.STAGED_WRITE and self.max_level >= SafetyLevel.STAGED_WRITE:
            return "replay"
        return None

    def requires_sandbox_write(self, tool: str) -> bool:
        return self.level(tool) >= SafetyLevel.STAGED_WRITE


READ_ONLY_POLICY = EligibilityPolicy(max_level=SafetyLevel.READ_ONLY)
PREP_ONLY_POLICY = EligibilityPolicy(max_level=SafetyLevel.PREP_ONLY)
FULL_POLICY = EligibilityPolicy(max_level=SafetyLevel.STAGED_WRITE)
