"""Operator-defined eligibility policy (paper §7).

Three execution levels (Level 0 prep-only / Level 1 read-only-replayable /
Level 2 staged-write) plus per-tool overrides and *transformed speculation*
(PASTE's example: web search speculates freely while pip_install degrades to
a dry-run/download-only variant).  By construction no speculative side
effect becomes externally visible unless the authoritative path converges —
commits require authoritative confirmation (sandbox.commit at promotion).

Paper anchor: §7 (execution levels, operator policy), Eq. 1's σ.
Upstream: events.py (ToolSpec default levels/transforms).  Downstream:
runtime.py (speculative_form gating at beam build, ``servable`` gating of
store serves), hypothesis.py (BARRIER insertion before Level-2 nodes).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.events import DEFAULT_TOOLS, SafetyLevel, ToolSpec


@dataclass
class EligibilityPolicy:
    """max_level: strictest class the runtime may *speculatively start*.
    Level-2 nodes may only run inside a sandbox behind a commit barrier."""
    max_level: SafetyLevel = SafetyLevel.STAGED_WRITE
    overrides: Dict[str, SafetyLevel] = field(default_factory=dict)
    transforms: Dict[str, str] = field(default_factory=dict)
    tools: Dict[str, ToolSpec] = field(default_factory=lambda: dict(DEFAULT_TOOLS))

    def __post_init__(self):
        for name, spec in self.tools.items():
            if spec.transformed and name not in self.transforms:
                self.transforms[name] = spec.transformed

    def level(self, tool: str) -> SafetyLevel:
        if tool in self.overrides:
            return self.overrides[tool]
        spec = self.tools.get(tool)
        return spec.level if spec else SafetyLevel.NON_SPECULATIVE

    def eligible(self, tool: str) -> bool:
        lvl = self.level(tool)
        if lvl == SafetyLevel.NON_SPECULATIVE:
            return tool in self.transforms
        return lvl <= self.max_level

    def speculative_form(self, tool: str) -> Optional[Tuple[str, bool]]:
        """(tool_to_run, transformed?) for speculative execution, or None if
        ineligible.  Level-2 tools above max_level degrade to their
        transformed variant when one exists."""
        lvl = self.level(tool)
        if lvl <= min(self.max_level, SafetyLevel.READ_ONLY):
            return (tool, False)
        if lvl <= self.max_level and lvl == SafetyLevel.STAGED_WRITE:
            return (tool, False)          # allowed, but sandbox + barrier
        if tool in self.transforms:
            return (self.transforms[tool], True)
        return None

    def servable(self, tool: str) -> Optional[str]:
        """How a stored result may satisfy an AUTHORITATIVE action from the
        cross-episode result store (memo.py):

          "direct" — PREP_ONLY / READ_ONLY: the result is replayable by
                     definition, serve it as-is;
          "replay" — STAGED_WRITE: serve by replaying the stored write
                     overlay through the commit barrier onto the live state
                     (version bump included), allowed only when the operator
                     admits staged speculation at all;
          None     — NON_SPECULATIVE (and staged writes under a stricter
                     policy): always re-execute authoritatively.
        """
        lvl = self.level(tool)
        if lvl <= SafetyLevel.READ_ONLY:
            return "direct"
        if lvl == SafetyLevel.STAGED_WRITE and self.max_level >= SafetyLevel.STAGED_WRITE:
            return "replay"
        return None

    def requires_sandbox_write(self, tool: str) -> bool:
        return self.level(tool) >= SafetyLevel.STAGED_WRITE


READ_ONLY_POLICY = EligibilityPolicy(max_level=SafetyLevel.READ_ONLY)
PREP_ONLY_POLICY = EligibilityPolicy(max_level=SafetyLevel.PREP_ONLY)
FULL_POLICY = EligibilityPolicy(max_level=SafetyLevel.STAGED_WRITE)
