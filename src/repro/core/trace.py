"""Per-episode Gantt/timeline recording for the event-driven scheduler.

A :class:`GanttRecorder` plugs into ``Simulator(recorder=...)`` (or
``RuntimeConfig(trace=...)``) and turns the simulator's job lifecycle
callbacks into timeline ROWS — one per contiguous execution segment:

    {"job": name, "jid": int, "tenant": eid-or-None, "tenants": [eids],
     "t_start": float, "t_end": float, "speculative": bool,
     "batch": batch-id-or-None, "outcome": "finish|preempt|cancel|open"}

A job that is preempted and resumed produces one row per segment (the
Gantt truth: the machine ran it twice, with a gap).  Batched model steps
carry the dispatch-sequence ``batch`` id from model_service and list every
member tenant in ``tenants`` — the attribution a pooled log line can't
give you at c=1024, where printf debugging dies.

This is the opt-in FULL recorder: ``Simulator.log`` stays the bounded
cheap default (and can be disabled outright with ``record_log=False``);
the Gantt dump is what you attach when you need to see the schedule.

Downstream: ``examples/trace_timeline.py`` renders the rows as an ASCII
timeline; ``dump()`` writes them as JSON for external tooling.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional


class GanttRecorder:
    """Callable recorder: ``recorder(sim, kind, job)`` for kind in
    start/finish/preempt/cancel.  Rows are closed in event order; jobs
    still running when recording stops are flushed by :meth:`close` with
    outcome="open"."""

    def __init__(self, skip_timers: bool = True):
        self.rows: List[Dict[str, Any]] = []
        self.skip_timers = skip_timers
        self._open: Dict[int, tuple] = {}      # jid -> (t_start, job)

    def __call__(self, sim, kind: str, job) -> None:
        if self.skip_timers and job.meta.get("timer"):
            return                              # zero-demand bookkeeping
        if kind == "start":
            self._open[job.jid] = (sim.now, job)
            return
        seg = self._open.pop(job.jid, None)
        if seg is None:
            return                              # e.g. cancel of a queued job
        self.rows.append(self._row(job, seg[0], sim.now, kind))

    def _row(self, job, t0: float, t1: float, outcome: str) -> Dict[str, Any]:
        eids = job.meta.get("eids")
        if eids is None:
            eid = job.meta.get("eid")
            eids = [eid] if eid is not None else []
        # speculative reasoning-step passengers riding a batch's idle
        # slots: meta["eids"] stays authoritative-only (QoS fans over it),
        # so the free riders surface through meta["spec_eids"]
        spec_eids = list(job.meta.get("spec_eids") or ())
        return {
            "job": job.name,
            "jid": job.jid,
            "tenant": eids[0] if eids else None,
            "tenants": list(eids),
            "spec_tenants": spec_eids,
            "t_start": t0,
            "t_end": t1,
            "speculative": bool(job.speculative),
            "batch": job.meta.get("batch"),
            "outcome": outcome,
        }

    def close(self, now: float) -> None:
        """Flush still-open segments (jobs running at simulation end)."""
        for _jid, (t0, job) in sorted(self._open.items()):
            self.rows.append(self._row(job, t0, now, "open"))
        self._open.clear()

    # ------------------------------------------------------------------
    def dump(self, path: str) -> None:
        """Write the timeline as a JSON array of row dicts."""
        with open(path, "w") as f:
            json.dump(self.rows, f, indent=1)

    def by_tenant(self) -> Dict[Optional[int], List[Dict[str, Any]]]:
        """Rows grouped per tenant (batched jobs appear under EVERY member
        tenant — each of them occupied the accelerator for that span;
        speculative passengers count too, they rode the same dispatch)."""
        out: Dict[Optional[int], List[Dict[str, Any]]] = {}
        for r in self.rows:
            members = list(r["tenants"]) + [e for e in r.get(
                "spec_tenants", ()) if e not in r["tenants"]]
            for eid in (members or [None]):
                out.setdefault(eid, []).append(r)
        return out


def render_ascii(rows: List[Dict[str, Any]], width: int = 72,
                 max_lanes: int = 40) -> str:
    """Seconds-scale ASCII Gantt: one lane per row (capped), ``=`` for
    authoritative segments, ``~`` for speculative ones, ``%`` for batched
    dispatches whose idle slots carry speculative reasoning-step
    passengers (the label appends ``+Ns``), ``x`` marking a preempted
    end.  Good enough to eyeball overlap structure in a terminal; the
    JSON dump is the machine-readable artifact."""
    if not rows:
        return "(empty timeline)"
    t1 = max(r["t_end"] for r in rows)
    t0 = min(r["t_start"] for r in rows)
    span = max(t1 - t0, 1e-9)
    lanes = sorted(rows, key=lambda r: (r["t_start"], r["jid"]))[:max_lanes]
    label_w = max(len(_label(r)) for r in lanes) + 1
    out = []
    for r in lanes:
        a = int((r["t_start"] - t0) / span * (width - 1))
        b = max(int((r["t_end"] - t0) / span * (width - 1)), a + 1)
        if r.get("spec_tenants"):
            ch = "%"
        else:
            ch = "~" if r["speculative"] else "="
        bar = [" "] * width
        for x in range(a, b):
            bar[x] = ch
        if r["outcome"] == "preempt":
            bar[b - 1] = "x"
        out.append(f"{_label(r):<{label_w}}|{''.join(bar)}|")
    hdr = f"{'':<{label_w}} t={t0:.2f}s {'·' * (width - 18)} t={t1:.2f}s"
    if len(rows) > max_lanes:
        out.append(f"... ({len(rows) - max_lanes} more rows)")
    return "\n".join([hdr] + out)


def _label(r: Dict[str, Any]) -> str:
    tag = f"e{r['tenant']}" if r["tenant"] is not None else "--"
    if r["batch"] is not None:
        tag = f"b{r['batch']}"
    if r.get("spec_tenants"):
        tag += f"+{len(r['spec_tenants'])}s"
    return f"{tag} {r['job'][:28]}"
