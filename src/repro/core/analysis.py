"""Speculation-safety static analyzer + runtime sanitizer.

The paper's correctness story (§7 execution levels, commit barriers, Eq. 1's
σ) rests on invariants the codebase enforces implicitly and in scattered
places, and the event-driven scheduler (PR 6) added a second class — epoch-
guarded caches, dirty-set completeness, counter-group slack — whose only
check was a 4-config event≡dense equivalence test.  This module makes both
classes explicit and checkable:

**Static rules** (pure; run at ``BPasteRuntime`` construction and by
``python -m repro.analysis`` in CI):

  R1-footprint      policy–footprint consistency: dry-run every tool with
                    tracked ``StateFacade`` footprints and diff against the
                    ToolSpec's *declared* read/write glob patterns.  An
                    undeclared write by a PREP_ONLY/READ_ONLY tool is an
                    error (speculation may run it outside a sandbox); an
                    undeclared staged write is a warning (sandboxed, but the
                    declaration the race matrix relies on is stale).
  R2-nonspec-reach  NON_SPECULATIVE tools without a usable transform that
                    are reachable in the mined pattern tables: tree assembly
                    inserts them into hypothesis interiors where they bound
                    every descendant — speculation silently stalls there.
  R3-write-race     cross-branch write–write conflict matrix: speculation-
                    eligible, pattern-reachable tools whose declared write
                    footprints collide on an exact (non-glob) key could be
                    co-admitted in one shared admission pass and stage
                    divergent writes to the same state.  Glob-level overlaps
                    are recorded in ``report.meta["write_conflicts"]`` only
                    (two tools writing distinct keys under ``F:*`` is not a
                    race).  The runtime can additionally thread this as a
                    conflict mask into admission (``RuntimeConfig.race_mask``).
  R4-barrier        commit-barrier placement on REAL assembled beams: every
                    Level-2+ TOOL node must have a BARRIER as its immediate
                    parent (hypothesis.barrier_violations) — the §7
                    insertion invariant, checked instead of trusted.

**Runtime sanitizer** (``RuntimeConfig.sanitize=True``; cross-checks on a
sampled tick schedule, findings through the same report type):

  S1-stale-cache    epoch-guarded per-NodeRun caches (resolved args, memo
                    key, servability verdict) vs fresh recomputation.
  S2-dirty-set      dirty-set completeness: recompute every NON-dirty
                    episode's cached frontiers/active-counts/pool entries
                    with a side-effect-free walk — any divergence means a
                    state change escaped its ``_mark_dirty`` and the event
                    scheduler is serving a stale cache (hard finding).
  S3-slack-drift    counter-group ``running_demand``/``slack`` vs a dense
                    re-sum over the running set.
  S4-footprint      tracked executor footprints vs declared ToolSpec
                    patterns at every real execution (authoritative,
                    speculative, and commit-replay) — R1's dry-run contract
                    enforced on live traffic.
  S5-store-index    ResultStore derived indices (read index, per-tool
                    counts) vs the entry table.

Paper anchor: §7 (execution levels, operator policy), Eq. 1's σ, §4.1/§6.3
(barrier insertion).  Upstream: events.py (declared footprints), safety.py
(policy semantics), executor.py (dry-run), hypothesis.py
(barrier_violations), memo.py (check_integrity), simulator.py
(dense_running_demand).  Downstream: runtime.py (construction-time static
pass + sanitizer hooks), repro/analysis.py (the CLI), CI.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from fnmatch import fnmatchcase
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.events import SafetyLevel, ToolSpec
from repro.core.executor import dry_run_footprint
from repro.core.hypothesis import BranchHypothesis, barrier_violations
from repro.core.memo import memo_key
from repro.core.safety import EligibilityPolicy

SEVERITIES = ("error", "warn", "info")


@dataclass(frozen=True)
class Finding:
    """One typed analyzer/sanitizer finding."""
    rule: str       # "R1-footprint" | ... | "S5-store-index"
    severity: str   # "error" | "warn" | "info"
    site: str       # where: tool name, "hyp 12 node 3", cache name, ...
    detail: str     # human-readable explanation

    def __str__(self) -> str:
        return f"[{self.severity}] {self.rule} @ {self.site}: {self.detail}"


@dataclass
class AnalysisReport:
    findings: List[Finding] = field(default_factory=list)
    meta: Dict[str, Any] = field(default_factory=dict)

    def add(self, rule: str, severity: str, site: str, detail: str) -> Finding:
        assert severity in SEVERITIES, severity
        f = Finding(rule, severity, site, detail)
        self.findings.append(f)
        return f

    def by_rule(self, rule: str) -> List[Finding]:
        return [f for f in self.findings if f.rule == rule]

    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.findings

    def extend(self, other: "AnalysisReport") -> None:
        self.findings.extend(other.findings)
        self.meta.update(other.meta)

    def render(self) -> str:
        if not self.findings:
            return "analysis: clean (0 findings)"
        lines = [f"analysis: {len(self.findings)} finding(s)"]
        lines += [f"  {f}" for f in self.findings]
        return "\n".join(lines)

    def to_json(self) -> Dict[str, Any]:
        return {
            "findings": [
                {"rule": f.rule, "severity": f.severity, "site": f.site,
                 "detail": f.detail}
                for f in self.findings
            ],
            "meta": {k: v for k, v in self.meta.items()},
        }

    def __len__(self) -> int:
        return len(self.findings)


def exit_code(report: AnalysisReport, strict: bool = False) -> int:
    """Shared CLI exit convention (``repro.analysis``/``repro.staticcheck``):
    0 clean, 1 findings, 2 under strict when any finding is an error."""
    if strict and report.errors():
        return 2
    return 0 if report.ok else 1


class AnalysisError(RuntimeError):
    """Raised by BPasteRuntime under ``analysis="strict"`` on error findings."""

    def __init__(self, report: AnalysisReport):
        self.report = report
        super().__init__(report.render())


# ======================================================================
# footprint pattern helpers
# ======================================================================

def _covered(key: str, patterns: Iterable[str]) -> bool:
    """Does the namespaced state key match any declared glob pattern?"""
    return any(fnmatchcase(key, p) for p in patterns)


def _is_exact(pattern: str) -> bool:
    """A declared pattern with no glob metacharacters names ONE key."""
    return not any(c in pattern for c in "*?[")


def _glob_prefix(pattern: str) -> str:
    """Literal prefix of a glob pattern (up to the first metacharacter)."""
    for i, c in enumerate(pattern):
        if c in "*?[":
            return pattern[:i]
    return pattern


def _patterns_overlap(a: str, b: str) -> bool:
    """Conservative may-overlap test between two declared patterns: their
    literal prefixes must be prefix-comparable.  Exact vs exact degenerates
    to equality; exact vs glob to fnmatch."""
    if _is_exact(a) and _is_exact(b):
        return a == b
    if _is_exact(a):
        return fnmatchcase(a, b)
    if _is_exact(b):
        return fnmatchcase(b, a)
    pa, pb = _glob_prefix(a), _glob_prefix(b)
    return pa.startswith(pb) or pb.startswith(pa)


# ======================================================================
# R1: policy–footprint consistency
# ======================================================================

def check_footprints(policy: EligibilityPolicy,
                     report: Optional[AnalysisReport] = None) -> AnalysisReport:
    """Dry-run every registered tool through ``StateFacade`` tracking and
    diff the observed per-call footprint against the ToolSpec declaration.

    An undeclared WRITE by a tool whose effective level is PREP_ONLY or
    READ_ONLY is an **error**: the runtime may execute it speculatively
    outside any sandbox-commit discipline (READ_ONLY results serve
    "direct"), so a hidden side effect leaks.  An undeclared staged write is
    a **warn** (sandbox + barrier still contain it, but R3's race matrix is
    blind to it).  Undeclared reads are **warn** at any level: the memo
    store keys validity on reads, so a stale declaration misdescribes what
    an entry depends on."""
    report = report if report is not None else AnalysisReport()
    for name, spec in sorted(policy.tools.items()):
        try:
            reads, write_values = dry_run_footprint(name)
        except KeyError:
            report.add("R1-footprint", "info", name,
                       "no executor implementation; declared footprint unchecked")
            continue
        lvl = policy.level(name)
        for nk in sorted(write_values):
            if _covered(nk, spec.writes):
                continue
            sev = "error" if lvl <= SafetyLevel.READ_ONLY else "warn"
            report.add(
                "R1-footprint", sev, name,
                f"undeclared write to {nk!r} (effective level {lvl.name}, "
                f"declared writes {list(spec.writes)!r})")
        for nk in sorted(reads):
            if _covered(nk, spec.reads) or _covered(nk, spec.writes):
                continue
            report.add(
                "R1-footprint", "warn", name,
                f"undeclared read of {nk!r} (declared reads "
                f"{list(spec.reads)!r})")
    return report


# ======================================================================
# R2: non-speculative reachability
# ======================================================================

def _reachable_tools(engine) -> List[str]:
    """Tools reachable in hypothesis interiors: every mined pattern tuple's
    target tool (the builder grows trees exclusively from these)."""
    pats = getattr(engine, "patterns", None) or []
    return sorted({pt.tool for pt in pats})


def check_nonspec_reachability(policy: EligibilityPolicy, engine,
                               report: Optional[AnalysisReport] = None
                               ) -> AnalysisReport:
    """NON_SPECULATIVE tools (no usable transform) reachable in the mined
    pattern tables.  Tree assembly happily inserts such a node into a
    hypothesis interior, where it bounds its whole subtree — every
    descendant silently stops speculating.  A tool the pattern tables
    reference but the registry doesn't know is an error (assembly would
    KeyError at build time)."""
    report = report if report is not None else AnalysisReport()
    for tool in _reachable_tools(engine):
        if tool not in policy.tools:
            report.add("R2-nonspec-reach", "error", tool,
                       "pattern tables reference a tool missing from the "
                       "registry; hypothesis assembly would fail")
            continue
        if policy.level(tool) != SafetyLevel.NON_SPECULATIVE:
            continue
        if policy.speculative_form(tool) is not None:
            continue
        report.add(
            "R2-nonspec-reach", "warn", tool,
            "NON_SPECULATIVE without a usable transform, yet reachable in "
            "mined patterns: hypothesis interiors containing it stall "
            "speculation for every descendant")
    return report


# ======================================================================
# R3: cross-branch write–write race matrix
# ======================================================================

def check_write_races(policy: EligibilityPolicy, engine,
                      report: Optional[AnalysisReport] = None
                      ) -> AnalysisReport:
    """Static conflict matrix over co-admittable speculative writers.

    Candidate set: the *run forms* of pattern-reachable tools (transforms
    included — the transform target is what actually executes).  Two
    distinct run tools conflict when their declared write footprints
    may overlap; the full may-overlap matrix lands in
    ``report.meta["write_conflicts"]``.  Only an **exact-key** collision
    (both patterns literal and equal) is a finding: both tools staging
    writes to the same key in one shared admission pass genuinely race,
    while a glob-level overlap (two tools under ``F:*``) usually writes
    distinct keys.  Same-tool pairs are excluded — identical invocations
    dedup through the result store, and a deterministic tool rewrites the
    same value."""
    report = report if report is not None else AnalysisReport()
    run_forms: Dict[str, ToolSpec] = {}
    for tool in _reachable_tools(engine):
        form = policy.speculative_form(tool)
        if form is None:
            continue
        run_tool, _ = form
        spec = policy.tools.get(run_tool)
        if spec is not None and spec.writes:
            run_forms[run_tool] = spec
    conflicts: List[List[str]] = []
    names = sorted(run_forms)
    for i, t1 in enumerate(names):
        for t2 in names[i + 1:]:
            for p1 in run_forms[t1].writes:
                for p2 in run_forms[t2].writes:
                    if not _patterns_overlap(p1, p2):
                        continue
                    conflicts.append([t1, t2, p1, p2])
                    if _is_exact(p1) and _is_exact(p2):
                        report.add(
                            "R3-write-race", "warn", f"{t1}+{t2}",
                            f"both declare the exact write key {p1!r} and "
                            f"are co-admittable in one shared admission "
                            f"pass: staged writes race across branches")
    report.meta["write_conflicts"] = conflicts
    return report


# ======================================================================
# R4: commit-barrier placement on real beams
# ======================================================================

def check_barriers(hyps: Iterable[BranchHypothesis],
                   report: Optional[AnalysisReport] = None) -> AnalysisReport:
    """Verify hypothesis.py's insertion invariant on assembled trees: every
    Level-2+ TOOL node's immediate parent is a BARRIER node."""
    report = report if report is not None else AnalysisReport()
    n = 0
    for h in hyps:
        n += 1
        for idx in barrier_violations(h):
            node = next(nd for nd in h.nodes if nd.idx == idx)
            report.add(
                "R4-barrier", "error", f"hyp {h.hid} node {idx}",
                f"STAGED_WRITE tool {node.tool!r} has no BARRIER parent: "
                f"staged effects could commit past an unconfirmed prefix")
    report.meta["barrier_checked_hyps"] = n
    return report


def analyze_static(policy: EligibilityPolicy, engine=None,
                   hyps: Optional[Iterable[BranchHypothesis]] = None
                   ) -> AnalysisReport:
    """The full static pass: R1 always; R2/R3 when a pattern engine is
    supplied; R4 when assembled beams are supplied (the CLI builds beams
    from real workload trace prefixes; the runtime constructor skips R4 —
    beams do not exist yet and building them would consume hypothesis ids)."""
    report = AnalysisReport()
    check_footprints(policy, report)
    if engine is not None:
        check_nonspec_reachability(policy, engine, report)
        check_write_races(policy, engine, report)
    if hyps is not None:
        check_barriers(hyps, report)
    return report


# ======================================================================
# Runtime sanitizer (RuntimeConfig.sanitize=True)
# ======================================================================

class RuntimeSanitizer:
    """Per-tick cross-checker for a live ``BPasteRuntime``.

    Every ``every``-th tick (after the phase loop) it recomputes, from
    scratch and side-effect-free, the values the event scheduler serves from
    caches — and records a finding for every divergence.  Execution-time
    footprint checks (S4) are event-driven: the runtime calls
    :meth:`check_footprint` from its execution completion hooks.

    The sanitizer never mutates runtime state: dirty sets, epochs, caches,
    the store, and the simulator are read-only here, so ``sanitize=True``
    changes wall time but not one scheduling decision."""

    def __init__(self, rt, every: int = 7):
        self.rt = rt
        self.every = max(1, int(every))
        self.report = AnalysisReport()
        self._tick_no = 0

    @property
    def findings(self) -> List[Finding]:
        return self.report.findings

    def _add(self, rule: str, severity: str, site: str, detail: str) -> None:
        self.report.add(rule, severity, site, detail)
        self.rt.metrics.sanitize_findings += 1

    # -- tick entry point ----------------------------------------------
    def on_tick(self) -> None:
        self._tick_no += 1
        if self._tick_no % self.every:
            return
        self.check_all()

    def check_all(self) -> None:
        self.check_epoch_caches()
        self.check_dirty_sets()
        self.check_demand_counters()
        self.check_store_integrity()

    # -- S1: epoch-guarded caches --------------------------------------
    def check_epoch_caches(self) -> None:
        rt = self.rt
        memo_on = rt._memo_on
        tool_pubs = rt.store.tool_pubs
        inval = rt.store.invalidations
        for es in rt.episodes:
            epoch = es.epoch
            for hr in es.hyp_runs:
                if hr.status != "active":
                    continue
                for i, nr in enumerate(hr.node_runs):
                    site = f"e{es.ep.eid} h{hr.hyp.hid} n{i}"
                    fresh_args = None
                    if nr.args_epoch == epoch and nr.args_cache is not None:
                        fresh_args = rt._resolve_node_args(es, hr, i)
                        if fresh_args != nr.args_cache:
                            self._add(
                                "S1-stale-cache", "error", site,
                                f"args cache {nr.args_cache!r} != fresh "
                                f"resolution {fresh_args!r} at epoch {epoch}")
                    if nr.mkey_epoch == epoch and nr.mkey_cache is not None:
                        if nr.node.bindings:
                            if fresh_args is None:
                                fresh_args = rt._resolve_node_args(es, hr, i)
                            args = fresh_args
                        else:
                            args = nr.resolved_args
                        if memo_key(nr.run_tool, args) != nr.mkey_cache:
                            self._add(
                                "S1-stale-cache", "error", site,
                                f"memo-key cache {nr.mkey_cache!r} diverged "
                                f"from fresh key at epoch {epoch}")
                    if memo_on and nr.serv_epoch == epoch:
                        tp = tool_pubs.get(nr.run_tool, 0)
                        guard = (nr.serv_pubs == tp
                                 and (not nr.serv_ok or nr.serv_inval == inval))
                        if guard and self._fresh_servable(es, hr, i,
                                                          fresh_args) != nr.serv_ok:
                            self._add(
                                "S1-stale-cache", "error", site,
                                f"servability verdict cache {nr.serv_ok} "
                                f"contradicts fresh validation at epoch "
                                f"{epoch}")

    def _fresh_servable(self, es, hr, i, fresh_args) -> bool:
        """Recompute the _memo_terms pass-1 verdict side-effect-free."""
        rt = self.rt
        nr = hr.node_runs[i]
        if not rt.store.has_tool(nr.run_tool):
            return False
        if nr.node.bindings:
            args = (fresh_args if fresh_args is not None
                    else rt._resolve_node_args(es, hr, i))
            if len(args) < len(nr.node.bindings):
                return False
        else:
            args = nr.resolved_args
        entry = rt.store.entries.get(memo_key(nr.run_tool, args))
        if entry is None or not entry.valid:
            return False
        return rt.store.validate(entry, hr.sandbox, track=False)

    # -- S2: dirty-set completeness ------------------------------------
    def check_dirty_sets(self) -> None:
        """Recompute every NON-dirty episode's phase-4 caches with a
        side-effect-free frontier walk.  A divergence on an episode the
        scheduler believes clean is the hard bug class the dirty-set design
        defends against: some state change skipped its ``_mark_dirty`` and
        admission is consuming a stale frontier.  Dirty episodes are
        legitimately stale (their rebuild is pending) and are skipped."""
        rt = self.rt
        if not rt._event:
            return
        for es in rt.episodes:
            i = es.idx
            if i < 0 or i in rt._dirty:
                continue
            frs: List[Tuple[Any, List[int]]] = []
            contrib = []
            nact = 0
            if es.phase in ("reasoning", "executing") and es.history:
                for hr in es.hyp_runs:
                    if hr.status != "active":
                        continue
                    nact += 1
                    fr = rt._launch_frontier(es, hr, settle_warm=False)
                    if not fr:
                        continue
                    frs.append((hr, fr))
                    if not any(nr.status == "running" for nr in hr.node_runs):
                        contrib.append((es, hr, fr))
            site = f"e{es.ep.eid}"
            if nact != rt._nact.get(i, 0):
                self._add("S2-dirty-set", "error", site,
                          f"active-branch count drifted: cached "
                          f"{rt._nact.get(i, 0)} != fresh {nact} on a "
                          f"non-dirty episode")
            cached_frs = rt._frontiers.get(i, [])
            if ([(id(hr), fr) for hr, fr in frs]
                    != [(id(hr), fr) for hr, fr in cached_frs]):
                self._add("S2-dirty-set", "error", site,
                          f"launch frontiers drifted: cached "
                          f"{[(hr.hyp.hid, fr) for hr, fr in cached_frs]} != "
                          f"fresh {[(hr.hyp.hid, fr) for hr, fr in frs]} on "
                          f"a non-dirty episode")
            cached_con = rt._contrib.get(i, [])
            if ([(id(hr), fr) for _, hr, fr in contrib]
                    != [(id(hr), fr) for _, hr, fr in cached_con]):
                self._add("S2-dirty-set", "error", site,
                          "admission-pool contribution drifted on a "
                          "non-dirty episode")

    # -- S3: counter-group demand / slack ------------------------------
    def check_demand_counters(self) -> None:
        sim = self.rt.sim
        for spec in (None, True, False):
            fast = sim.running_demand(speculative=spec)
            dense = sim.dense_running_demand(speculative=spec)
            if not np.allclose(fast, dense, rtol=1e-9, atol=1e-6):
                self._add(
                    "S3-slack-drift", "error", f"running_demand({spec})",
                    f"counter-group demand {fast.tolist()} != dense re-sum "
                    f"{dense.tolist()}")
        slack = sim.slack()
        dense_slack = np.maximum(sim.cap - sim.dense_running_demand(), 0.0)
        if not np.allclose(slack, dense_slack, rtol=1e-9, atol=1e-6):
            self._add("S3-slack-drift", "error", "slack",
                      f"slack {slack.tolist()} != dense recompute "
                      f"{dense_slack.tolist()}")

    # -- S4: execution-time footprint contract -------------------------
    def check_footprint(self, tool: str, fac, site: str) -> None:
        """Called by the runtime after every real ``execute_tool``
        (authoritative, speculative, commit replay) with the call's tracked
        facade: the dry-run contract of R1, enforced on live traffic (live
        args can reach state R1's samples never touched)."""
        spec = self.rt.tools.get(tool)
        if spec is None:
            return
        for nk in fac.write_values:
            if not _covered(nk, spec.writes):
                sev = ("error" if spec.level <= SafetyLevel.READ_ONLY
                       else "warn")
                self._add("S4-footprint", sev, f"{tool} @ {site}",
                          f"runtime write to {nk!r} outside declared "
                          f"footprint {list(spec.writes)!r}")
        for nk in fac.reads:
            if not (_covered(nk, spec.reads) or _covered(nk, spec.writes)):
                self._add("S4-footprint", "warn", f"{tool} @ {site}",
                          f"runtime read of {nk!r} outside declared "
                          f"footprint {list(spec.reads)!r}")

    # -- S5: result-store index integrity ------------------------------
    def check_store_integrity(self) -> None:
        for problem in self.rt.store.check_integrity():
            self._add("S5-store-index", "error", "ResultStore", problem)
