"""Admission control (paper Eq. 5–6): pick A* ⊆ beam maximizing Σ EU(H|A)
subject to Σρ ≤ min(R_slack, B).

Primary policy is the paper's greedy (Algorithm 1 line 20): repeatedly admit
the highest-marginal-EU prefix that still fits, re-scoring interference
after each admission (EU is conditioned on the admitted set, so marginals
change).

``fused_admit`` is the production path: the whole greedy selection —
score → pick the argmax-EU candidate that fits → add its ρ to the admitted
demand → re-score — runs inside one jitted ``jax.lax.while_loop`` over the
padded PackedBeam tables, so an admission pass is a single XLA dispatch
(the scheduler must not eat the slack it exploits; see DESIGN.md).  The
admitted-set-invariant terms ΔO/ΔU are hoisted out of the loop; only ΔI is
re-evaluated per admission.  Beams wider than ``k_max`` are padded up to the
next ``k_max`` multiple (bucketed shapes → bounded jit cache) instead of
being truncated.

``greedy_admit`` is kept as the reference oracle — a numpy greedy loop
around the jitted scorer, dispatching per iteration (equivalence tests in
tests/test_admission_fused.py; the only dispatch-free implementation is
``_admit_numpy``, the small-beam fast path).  ``exact_admit`` enumerates
all subsets (K ≤ ~14) and is used by tests to bound the greedy gap and by
the benchmark to report solution quality.

Paper anchor: Eq. 5–6 (admission under min(R_slack, B)), Algorithm 1
line 20 (greedy re-scoring).  Upstream: scoring.py (shared estimators,
PackedBeam), hypothesis.py (candidates).  Downstream: runtime Phase 4
(``_admit_shared`` is the only production caller).
"""
from __future__ import annotations

import functools
import itertools
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import RESOURCE_DIMS
from repro.core.hypothesis import BranchHypothesis
from repro.core.scoring import (
    PackedBeam, Scorer, eu_given_admitted, finish_static_terms, pack_beam,
    prefix_rho, static_gain_terms, static_raw_terms,
)


# capacity-fit tolerance, shared by every admission path (reference, exact,
# fused kernel, numpy fast path) so they agree at the constraint boundary
_FIT_EPS = 1e-6


def _fit_limit(limit):
    """Per-dimension fit threshold: relative + absolute slop so the jitted
    kernel's f32 accumulation (error ∝ magnitude) can't flip a boundary
    decision against the f64 paths."""
    return limit + _FIT_EPS * (1.0 + limit)


# concurrency-aware prefix demand shared with pack_beam, so the reference
# greedy, the exact oracle, and the fused kernel agree on ρ exactly
_prefix_rho = prefix_rho


@dataclass
class AdmissionResult:
    admitted: List[BranchHypothesis]
    eu: dict                     # hid -> EU at admission time
    rejected: List[BranchHypothesis]


def greedy_admit(
    hyps: Sequence[BranchHypothesis],
    scorer: Scorer,
    slack: np.ndarray,           # R_slack (R,)
    budget: np.ndarray,          # B (R,)
    authoritative_rho: np.ndarray,
    idle_window: float = 10.0,
    weights: Optional[np.ndarray] = None,
    memo_masks: Optional[np.ndarray] = None,
    memo_rho: Optional[np.ndarray] = None,
    model_delay: float = 0.0,
    spec_costs: Optional[np.ndarray] = None,
    shed_penalty: float = 0.0,
) -> AdmissionResult:
    """Reference greedy: scoring dispatches (one per k_max chunk) + numpy
    re-pack PER admission iteration.  Semantics oracle for ``fused_admit``;
    prefer the fused path in hot loops.

    ``weights`` (len(hyps),) are per-hypothesis fairness multipliers (shared
    cross-episode beams weight each tenant's candidates by its current
    speculative share).  EU is linear in q, so weighting EU post-score is
    exactly weighting q — the greedy order, the eu>0 admission threshold
    (weights are positive), and the recorded EU-at-admit all see q·w.

    ``memo_masks`` (len(hyps), n_max) / ``memo_rho`` (len(hyps), R) carry
    the result-store reuse term (see scoring.static_gain_terms): memoized
    prefix nodes contribute EU at zero demand, so both the scoring AND the
    capacity-fit check use the memo-excluded prefix ρ.

    ``model_delay`` is the model-step service's expected queue+batch-window
    delay, discounting every candidate's ΔU (scoring.static_gain_terms).

    ``spec_costs`` (len(hyps),) is the slot-marginal model-step cost of each
    candidate's speculative MODEL step (see scoring.score_beam); None means
    zeros (bit-identical no-op).

    ``shed_penalty`` is the scalar load-shedding ΔO tax under open-loop
    backlog (see scoring.score_beam); 0 is a bit-identical no-op."""
    limit = np.minimum(slack, budget)
    admitted: List[BranchHypothesis] = []
    admitted_demand = np.zeros(RESOURCE_DIMS)
    eu_at_admit: dict = {}
    remaining = list(hyps)
    idx_of = {id(h): i for i, h in enumerate(hyps)}
    w_by_hid = (
        {h.hid: float(weights[i]) for i, h in enumerate(hyps)}
        if weights is not None else None
    )
    while remaining:
        # score_all chunks beams wider than scorer.k_max — every remaining
        # hypothesis gets a real EU, not the padded-table truncation
        rows = [idx_of[id(h)] for h in remaining]
        eu = scorer.score_all(
            remaining, authoritative_rho + admitted_demand, idle_window,
            memo_masks=None if memo_masks is None else memo_masks[rows],
            memo_rho=None if memo_rho is None else memo_rho[rows],
            model_delay=model_delay,
            spec_costs=None if spec_costs is None else spec_costs[rows],
            shed_penalty=shed_penalty,
        )
        if w_by_hid is not None:
            eu = eu * np.array([w_by_hid[h.hid] for h in remaining])
        order = np.argsort(-eu)
        picked = None
        for oi in order:
            if eu[oi] <= 0:
                break
            cand = remaining[oi]
            if memo_rho is not None:
                rho = memo_rho[idx_of[id(cand)]]
            else:
                rho = _prefix_rho(cand)
            if np.all(admitted_demand + rho <= _fit_limit(limit)):
                picked = (oi, cand, float(eu[oi]), rho)
                break
        if picked is None:
            break
        oi, cand, val, rho = picked
        admitted.append(cand)
        eu_at_admit[cand.hid] = val
        admitted_demand = admitted_demand + rho
        remaining.pop(oi)
    return AdmissionResult(admitted, eu_at_admit, remaining)


def bucket_k(n: int, k_max: int) -> int:
    """Smallest bucket ≥ n hypotheses: multiples of k_max up to 2·k_max,
    then GEOMETRIC (k_max · 2^j) above.

    Bucketing keeps the fused kernel's compiled-shape set bounded while
    never dropping candidates (padded rows carry k_valid=0 and are inert):
    a 12-wide beam with k_max=8 packs at K=16.  Geometric growth matters
    under c≫1 tenants, where the pooled beam width moves every tick —
    linear buckets gave one XLA compile per multiple (each ~100s of ms,
    paid inside the tick loop), log₂ buckets cap the shape set."""
    km = max(k_max, 1)
    if n <= 2 * km:
        return max(km, km * math.ceil(n / km))
    b = 2 * km
    while b < n:
        b *= 2
    return b


def admission_signature(hids, slack, budget, auth_rho, weights, memo_masks,
                        memo_rho, model_delay, spec_costs=None,
                        shed_penalty=0.0) -> tuple:
    """Byte-exact signature of every input one shared-admission pass is a
    function of.  ``greedy_admit``/``fused_admit`` are deterministic in
    (candidate hypotheses, slack, budget, conditioning demand, fairness
    weights, memo terms, model delay) — hypotheses are immutable after
    build and globally numbered, so the ordered hid tuple pins them.  Two
    passes with equal signatures therefore produce identical admitted
    sets and EU values, which is what lets the runtime's warm-start
    (``RuntimeConfig.warm_admit``) replay last tick's decision instead of
    re-running the kernel, with ANY deviation falling back to the full
    pass."""
    return (
        tuple(hids),
        slack.tobytes(), budget.tobytes(), auth_rho.tobytes(),
        None if weights is None else weights.tobytes(),
        None if memo_masks is None else memo_masks.tobytes(),
        None if memo_rho is None else memo_rho.tobytes(),
        float(model_delay),
        None if spec_costs is None else spec_costs.tobytes(),
        float(shed_penalty),
    )


@functools.partial(jax.jit, static_argnames=("n_nodes",))
def admit_beam(
    node_lat, node_prob, node_mask, prefix_mask, adj, q, rho, k_valid,
    w, memo_mask, auth_rho, cap, limit, lam, mu, idle_window, model_delay,
    spec_cost, shed_penalty, n_nodes: int,
):
    """Entire greedy admission pass as ONE jitted kernel.

    State of the ``while_loop``: (remaining mask, admitted mask, admitted
    demand, EU-at-admit, continue flag).  Each iteration scores every
    still-remaining hypothesis against the current admitted demand, picks
    the argmax-EU candidate with positive EU whose prefix ρ fits under
    ``limit``, and folds its demand in.  Terminates when nothing eligible
    remains — at most K+1 iterations, all inside XLA.

    ΔO/ΔU are loop-invariant (they depend only on the hypothesis graph), so
    they are computed once up front; the loop re-evaluates only ΔI.

    ``w`` (K,) are positive per-hypothesis fairness weights; EU is linear in
    q so multiplying EU by w is identical to scoring with q·w.

    ``memo_mask`` (K, N) marks result-store-memoized prefix nodes (the reuse
    term): they are excluded from the interference-exposed latency here, and
    the caller passes ``rho`` already excluding them — memoized nodes
    contribute EU at zero demand.

    ``model_delay`` (traced scalar — it changes every tick without
    recompiling, like the demand vectors) discounts every ΔU by the
    model-step service's expected queue+batch-window delay; it is
    loop-invariant, so it folds into the hoisted static terms.

    ``spec_cost`` (K,) is the slot-marginal model-step cost of each
    candidate's speculative MODEL step (scoring.score_beam) — also
    loop-invariant, folded into the hoisted static terms with the SAME
    operation order as every other admission path so zeros stay an
    IEEE-exact no-op and decisions stay equivalence-testable.

    ``shed_penalty`` (traced scalar) is the load-shedding ΔO tax under
    open-loop backlog (scoring.score_beam) — loop-invariant, folded at the
    same point as ``spec_cost`` in every path; 0 is an IEEE-exact no-op.

    Returns (admitted_mask (K,), eu_at_admit (K,), admitted_demand (R,)).
    """
    l_solo, l_exec, delta_o, delta_u = static_gain_terms(
        node_lat, node_prob, node_mask, prefix_mask, adj, idle_window,
        n_nodes, memo_mask=memo_mask, model_delay=model_delay,
    )
    delta_o = delta_o - mu * spec_cost - shed_penalty
    fit_lim = _fit_limit(limit)
    K = q.shape[0]

    def cond(state):
        return state[4]

    def body(state):
        remaining, admitted, demand, eu_adm, _ = state
        eu, _ = eu_given_admitted(
            l_exec, delta_o, delta_u, q, rho, k_valid,
            auth_rho + demand, cap, lam, mu, idle_window,
        )
        eu = eu * w
        fits = jnp.all(demand[None, :] + rho <= fit_lim[None, :], axis=1)
        elig = (remaining > 0) & fits & (eu > 0.0)
        any_elig = jnp.any(elig)
        pick = jnp.argmax(jnp.where(elig, eu, -jnp.inf))
        onehot = (jnp.arange(K) == pick) & any_elig
        remaining = jnp.where(onehot, 0.0, remaining)
        admitted = jnp.where(onehot, 1.0, admitted)
        eu_adm = jnp.where(onehot, eu, eu_adm)
        demand = demand + (onehot[:, None] * rho).sum(axis=0)
        return (remaining, admitted, demand, eu_adm, any_elig)

    init = (
        k_valid,
        jnp.zeros((K,)),
        jnp.zeros_like(auth_rho),
        jnp.zeros((K,)),
        jnp.array(True),
    )
    _, admitted, demand, eu_adm, _ = jax.lax.while_loop(cond, body, init)
    return admitted, eu_adm, demand


def _admit_numpy(packed: PackedBeam, auth_rho, cap, limit, lam, mu,
                 idle_window, w=None, memo_mask=None,
                 rho=None, model_delay=0.0, spec_cost=None,
                 shed_penalty=0.0,
                 static_terms=None) -> Tuple[np.ndarray, np.ndarray]:
    """The ``admit_beam`` algorithm on the same PackedBeam tables in pure
    numpy — the host-side fast path for tiny beams, where a single XLA
    dispatch (~1 ms on CPU) dwarfs the actual arithmetic.  The Eq. 3
    estimator is the shared ``eu_given_admitted``/``static_gain_terms``
    (with ``xp=np``), so there is exactly one implementation of every term.
    ``memo_mask``/``rho`` carry the result-store reuse term (``rho``
    overrides the packed prefix demand with the memo-excluded one).
    Returns (admitted_mask (K,), eu_at_admit (K,))."""
    lat, prob = packed.node_lat, packed.node_prob
    mask, pmask, adj = packed.node_mask, packed.prefix_mask, packed.adj
    q, k_valid = packed.q, packed.k_valid
    if rho is None:
        rho = packed.rho
    K, N = lat.shape
    rho = np.asarray(rho, float)
    auth_rho = np.asarray(auth_rho, float)
    cap = np.asarray(cap, float)
    fit_lim = _fit_limit(limit)
    if w is None:
        w = np.ones(K)
    admitted = np.zeros(K)
    eu_adm = np.zeros(K)
    # Rows that can never be admitted are dropped before any scoring:
    # padding / invalid rows (k_valid 0 → eu 0, never > 0) and rows whose
    # prefix demand alone exceeds the limit (the admitted demand only
    # GROWS, so an initial non-fit stays a non-fit).  Every per-row term
    # below is independent of the other rows, so compaction changes no
    # value and — because np.argmax keeps first-index tie-breaks and
    # compaction preserves order — no decision.
    act = np.flatnonzero((k_valid > 0)
                         & np.all(rho <= fit_lim[None, :], axis=1))
    if not len(act):
        return admitted, eu_adm
    lat, prob, mask, pmask, adj = (
        lat[act], prob[act], mask[act], pmask[act], adj[act])
    q, k_valid, rho, w = q[act], k_valid[act], rho[act], w[act]
    if memo_mask is not None:
        memo_mask = memo_mask[act]
    if spec_cost is not None:
        spec_cost = spec_cost[act]
    if static_terms is None:
        l_solo, l_exec, delta_o, delta_u = static_gain_terms(
            lat, prob, mask, pmask, adj, idle_window, N,
            memo_mask=memo_mask, model_delay=model_delay, xp=np,
        )
    else:
        # warm-cached raw terms (full-K arrays, see _cached_static_terms):
        # only the per-tick memo mask / model delay still need folding in
        s_solo, s_pref, s_raw = static_terms
        l_solo, l_exec, delta_o, delta_u = finish_static_terms(
            s_solo[act], s_pref[act], s_raw[act], idle_window,
            memo_mask=memo_mask, model_delay=model_delay,
        )
    if spec_cost is not None:
        # slot-marginal model-step cost — same point and operation order as
        # score_beam/admit_beam so zeros are an IEEE-exact no-op
        delta_o = delta_o - mu * spec_cost
    # load-shedding ΔO tax — folded at the same point as the jitted paths
    # ((ΔO − μ·spec) − shed); subtracting the 0.0 default is IEEE-exact
    delta_o = delta_o - shed_penalty
    # Second prune: ΔI ≥ 0 only ever subtracts, so q·(ΔO+λΔU)·k_valid·w
    # is a static per-row EU ceiling — rows at/below 0 can never clear the
    # eu > 0 eligibility bar.  (spec_cost and shed_penalty are already
    # folded into ΔO above, so the ceiling remains valid.)
    static_gain = delta_o + lam * delta_u
    pos = np.flatnonzero(q * static_gain * k_valid * w > 0.0)
    if not len(pos):
        return admitted, eu_adm
    if len(pos) < len(act):
        act = act[pos]
        l_exec, static_gain = l_exec[pos], static_gain[pos]
        q, k_valid, rho, w = q[pos], k_valid[pos], rho[pos], w[pos]
    remaining = k_valid.copy()
    demand = np.zeros_like(auth_rho)
    adm_c = np.zeros(len(act))
    eu_c = np.zeros(len(act))
    # The greedy loop below is ``eu_given_admitted`` inlined with its
    # loop-invariant subexpressions hoisted (same operations, same order —
    # bit-identical row values, verified by the kernel-equivalence suite).
    # Beams of dozens-to-hundreds run this every admission pass with ~1
    # pick per iteration, so per-iteration ufunc dispatch — not the (K,R)
    # arithmetic — is the cost; hoisting ``rho > 0``, the static-gain
    # combination, and the duplicated ``maximum(util, 1)`` roughly halves
    # it.
    rho_pos = rho > 0
    while True:
        admitted_rho = auth_rho + demand
        util = (admitted_rho[None, :] + rho) / cap[None, :]
        u1 = np.maximum(util, 1.0)
        stretch = np.where(rho_pos, u1, 1.0).max(axis=1)
        self_pen = l_exec * (stretch - 1.0)
        adm_util = admitted_rho / cap
        adm_stretch_before = np.maximum(adm_util, 1.0).max()
        adm_stretch_after = np.where(
            admitted_rho[None, :] > 0, u1, 1.0).max(axis=1)
        inflicted = np.maximum(
            adm_stretch_after - adm_stretch_before, 0.0) * idle_window
        delta_i = self_pen + inflicted
        eu = q * (static_gain - mu * delta_i) * k_valid
        eu = eu * w
        fits = np.all(demand[None, :] + rho <= fit_lim[None, :], axis=1)
        elig = (remaining > 0) & fits & (eu > 0.0)
        # Zero-demand picks (fully memo-served prefixes) leave ``demand``
        # — and therefore every term above — untouched, so consecutive
        # ones resolve against the SAME eu/fits without a rescore: just
        # retire the picked row from eligibility, exactly what the full
        # recompute would have done.
        while True:
            if not elig.any():
                admitted[act] = adm_c
                eu_adm[act] = eu_c
                return admitted, eu_adm
            pick = int(np.argmax(np.where(elig, eu, -np.inf)))
            remaining[pick] = 0.0
            adm_c[pick] = 1.0
            eu_c[pick] = eu[pick]
            if rho[pick].any():
                demand = demand + rho[pick]
                break
            elig[pick] = False


def _cached_static_terms(hyps, packed: PackedBeam, n_nodes: int,
                         cache: dict):
    """Assemble full-K ``(l_solo, lat_pref, raw_delta_u)`` arrays for the
    host admission path from a caller-owned per-hid cache (caller-bounded,
    like pack_beam's row_cache): rows already seen replay their cached
    ``static_raw_terms`` values, unseen rows are computed in one sub-batch
    and recorded.  Sound because the raw terms are hypothesis-intrinsic and
    row-independent (see static_raw_terms) — this is what lets the admission
    warm-start pay even while the pool's MEMBERSHIP churns every tick and
    the full-signature replay misses.  Padding rows (k ≥ len(hyps)) stay
    zero; _admit_numpy's k_valid compaction drops them before use."""
    K, N = packed.node_lat.shape
    l_solo = np.zeros(K)
    lat_pref = np.zeros((K, N))
    raw_du = np.zeros(K)
    miss = [k for k, h in enumerate(hyps) if h.hid not in cache]
    if miss:
        idx = np.asarray(miss)
        ms, mp, mr = static_raw_terms(
            packed.node_lat[idx], packed.node_prob[idx],
            packed.node_mask[idx], packed.prefix_mask[idx],
            packed.adj[idx], n_nodes)
        for j, k in enumerate(miss):
            cache[hyps[k].hid] = (ms[j], mp[j], mr[j])
    for k, h in enumerate(hyps):
        s, p, r = cache[h.hid]
        l_solo[k] = s
        lat_pref[k] = p
        raw_du[k] = r
    return l_solo, lat_pref, raw_du


def fused_admit(
    hyps: Sequence[BranchHypothesis],
    scorer: Scorer,
    slack: np.ndarray,
    budget: np.ndarray,
    authoritative_rho: np.ndarray,
    idle_window: float = 10.0,
    packed: Optional[PackedBeam] = None,
    small_beam_threshold: int = 2,
    weights: Optional[np.ndarray] = None,
    memo_masks: Optional[np.ndarray] = None,
    memo_rho: Optional[np.ndarray] = None,
    model_delay: float = 0.0,
    spec_costs: Optional[np.ndarray] = None,
    shed_penalty: float = 0.0,
    static_cache: Optional[dict] = None,
) -> AdmissionResult:
    """Greedy admission via the fused ``admit_beam`` kernel: one XLA dispatch
    per admission pass (vs. one scoring dispatch per *iteration* in
    ``greedy_admit``).  Beams of ≤ ``small_beam_threshold`` hypotheses take
    an equivalent pure-numpy path instead — below that size the fixed cost
    of any device dispatch exceeds the whole computation.  ``packed`` lets
    callers reuse a cached PackedBeam (see BPasteRuntime incremental
    packing); it must have been packed from exactly these ``hyps`` at a
    bucketed K ≥ len(hyps).  ``weights`` (len(hyps),) are the per-hypothesis
    fairness multipliers (see ``greedy_admit``) — NOT part of the packed
    tables, so the PackedBeam cache stays valid as tenant shares move.
    ``memo_masks`` (len(hyps), n_max) / ``memo_rho`` (len(hyps), R) carry
    the result-store reuse term and ride alongside the pack for the same
    reason (store contents change every tick; the pack does not).
    ``model_delay`` (the model-step service's expected unlock delay) also
    rides alongside — a traced scalar, so the jit cache is untouched as the
    batch window moves.  ``spec_costs`` (len(hyps),) is the slot-marginal
    model-step cost term (scoring.score_beam), riding alongside for the
    same reason; None means zeros, a bit-identical no-op.
    ``shed_penalty`` is the scalar load-shedding ΔO tax (scoring.score_beam)
    — another alongside-rider (a traced scalar); 0 is a bit-identical no-op.
    ``static_cache`` (caller-owned {hid: raw terms},
    host path only) replays hypothesis-intrinsic static gain terms across
    passes — see ``_cached_static_terms``."""
    if not len(hyps):
        return AdmissionResult([], {}, [])
    limit = np.minimum(slack, budget)
    if packed is None or packed.q.shape[0] < len(hyps):
        packed = pack_beam(hyps, bucket_k(len(hyps), scorer.k_max), scorer.n_max)
    cap = scorer.machine.cap_array()
    K = packed.q.shape[0]
    w_pad = np.ones(K)
    if weights is not None:
        w_pad[: len(hyps)] = np.asarray(weights, float)
    mm_pad = np.zeros((K, packed.node_lat.shape[1]))
    sc_pad = np.zeros(K)
    rho = packed.rho
    if memo_masks is not None:
        mm_pad[: len(hyps), :] = np.asarray(memo_masks, float)
    if spec_costs is not None:
        sc_pad[: len(hyps)] = np.asarray(spec_costs, float)
    if memo_rho is not None:
        rho = rho.copy()
        rho[: len(hyps), :] = np.asarray(memo_rho, float)
    if len(hyps) <= small_beam_threshold:
        static_terms = None
        if static_cache is not None:
            static_terms = _cached_static_terms(
                hyps, packed, scorer.n_max, static_cache)
        admitted_mask, eu_adm = _admit_numpy(
            packed, np.asarray(authoritative_rho, float), cap,
            np.asarray(limit, float), scorer.lam, scorer.mu, idle_window,
            w=w_pad, memo_mask=mm_pad, rho=rho, model_delay=model_delay,
            spec_cost=sc_pad, shed_penalty=shed_penalty,
            static_terms=static_terms,
        )
    else:
        admitted_mask, eu_adm, _ = admit_beam(
            packed.node_lat, packed.node_prob, packed.node_mask,
            packed.prefix_mask, packed.adj, packed.q, rho, packed.k_valid,
            jnp.asarray(w_pad), jnp.asarray(mm_pad),
            jnp.asarray(authoritative_rho),
            jnp.asarray(cap), jnp.asarray(limit), scorer.lam, scorer.mu,
            idle_window, model_delay, jnp.asarray(sc_pad), shed_penalty,
            n_nodes=scorer.n_max,
        )
        admitted_mask = np.asarray(admitted_mask)
        eu_adm = np.asarray(eu_adm)
    admitted, rejected, eu = [], [], {}
    for i, h in enumerate(hyps):
        if admitted_mask[i] > 0:
            admitted.append(h)
            eu[h.hid] = float(eu_adm[i])
        else:
            rejected.append(h)
    return AdmissionResult(admitted, eu, rejected)


def exact_admit(
    hyps: Sequence[BranchHypothesis],
    scorer: Scorer,
    slack: np.ndarray,
    budget: np.ndarray,
    authoritative_rho: np.ndarray,
    idle_window: float = 10.0,
) -> Tuple[List[BranchHypothesis], float]:
    """Brute-force Eq. 5 (for tests / quality reporting).  O(2^K)."""
    limit = np.minimum(slack, budget)
    best: Tuple[float, Tuple[int, ...]] = (0.0, ())
    n = len(hyps)
    rhos = [_prefix_rho(h) for h in hyps]
    for r in range(1, n + 1):
        for subset in itertools.combinations(range(n), r):
            demand = np.sum([rhos[i] for i in subset], axis=0)
            if not np.all(demand <= _fit_limit(limit)):
                continue
            # EU of each member conditioned on the OTHERS in the subset
            total = 0.0
            for i in subset:
                others = np.sum(
                    [rhos[j] for j in subset if j != i], axis=0,
                ) if r > 1 else np.zeros(RESOURCE_DIMS)
                eu, _, _ = scorer.score(
                    [hyps[i]], authoritative_rho + others, idle_window
                )
                total += float(eu[0])
            if total > best[0]:
                best = (total, subset)
    return [hyps[i] for i in best[1]], best[0]
