"""Admission control (paper Eq. 5–6): pick A* ⊆ beam maximizing Σ EU(H|A)
subject to Σρ ≤ min(R_slack, B).

Primary policy is the paper's greedy (Algorithm 1 line 20): repeatedly admit
the highest-marginal-EU prefix that still fits, re-scoring interference
after each admission (EU is conditioned on the admitted set, so marginals
change).  ``exact_admit`` enumerates all subsets (K ≤ ~14) and is used by
tests to bound the greedy gap and by the benchmark to report solution
quality.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import RESOURCE_DIMS
from repro.core.hypothesis import BranchHypothesis
from repro.core.interference import Machine
from repro.core.scoring import Scorer


def _prefix_rho(h: BranchHypothesis) -> np.ndarray:
    agg = np.zeros(RESOURCE_DIMS)
    for n in h.safe_prefix():
        agg = np.maximum(agg, n.rho.as_array())
    return agg


@dataclass
class AdmissionResult:
    admitted: List[BranchHypothesis]
    eu: dict                     # hid -> EU at admission time
    rejected: List[BranchHypothesis]


def greedy_admit(
    hyps: Sequence[BranchHypothesis],
    scorer: Scorer,
    slack: np.ndarray,           # R_slack (R,)
    budget: np.ndarray,          # B (R,)
    authoritative_rho: np.ndarray,
    idle_window: float = 10.0,
) -> AdmissionResult:
    limit = np.minimum(slack, budget)
    admitted: List[BranchHypothesis] = []
    admitted_demand = np.zeros(RESOURCE_DIMS)
    eu_at_admit: dict = {}
    remaining = list(hyps)
    while remaining:
        eu, pb, _ = scorer.score(
            remaining, authoritative_rho + admitted_demand, idle_window
        )
        order = np.argsort(-eu[: len(remaining)])
        picked = None
        for oi in order:
            if eu[oi] <= 0:
                break
            cand = remaining[oi]
            rho = _prefix_rho(cand)
            if np.all(admitted_demand + rho <= limit + 1e-9):
                picked = (oi, cand, float(eu[oi]), rho)
                break
        if picked is None:
            break
        oi, cand, val, rho = picked
        admitted.append(cand)
        eu_at_admit[cand.hid] = val
        admitted_demand = admitted_demand + rho
        remaining.pop(oi)
    return AdmissionResult(admitted, eu_at_admit, remaining)


def exact_admit(
    hyps: Sequence[BranchHypothesis],
    scorer: Scorer,
    slack: np.ndarray,
    budget: np.ndarray,
    authoritative_rho: np.ndarray,
    idle_window: float = 10.0,
) -> Tuple[List[BranchHypothesis], float]:
    """Brute-force Eq. 5 (for tests / quality reporting).  O(2^K)."""
    limit = np.minimum(slack, budget)
    best: Tuple[float, Tuple[int, ...]] = (0.0, ())
    n = len(hyps)
    rhos = [_prefix_rho(h) for h in hyps]
    for r in range(1, n + 1):
        for subset in itertools.combinations(range(n), r):
            demand = np.sum([rhos[i] for i in subset], axis=0)
            if not np.all(demand <= limit + 1e-9):
                continue
            # EU of each member conditioned on the OTHERS in the subset
            total = 0.0
            for i in subset:
                others = np.sum(
                    [rhos[j] for j in subset if j != i], axis=0,
                ) if r > 1 else np.zeros(RESOURCE_DIMS)
                eu, _, _ = scorer.score(
                    [hyps[i]], authoritative_rho + others, idle_window
                )
                total += float(eu[0])
            if total > best[0]:
                best = (total, subset)
    return [hyps[i] for i in best[1]], best[0]
