"""PrefixSpan: prefix-projected sequential pattern mining (Pei et al., ICDE'01).

Mines frequent short-horizon motifs from event-signature streams (paper §3:
"Sequential pattern mining methods such as PrefixSpan naturally fit the
offline mining phase").  Items are hashable event signatures; sequences are
per-request traces.  We mine *contiguous-gap-bounded* patterns: agent motifs
like edit→test→read are near-adjacent, so a max_gap keeps patterns causal
and the search bounded.

Paper anchor: §3 (offline mining phase).  Upstream: events.py signature
streams (via workload traces).  Downstream: patterns.py
(``conditional_next`` feeds the conditional next-tool tables).
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, Hashable, List, Sequence, Tuple


@dataclass(frozen=True)
class Pattern:
    items: Tuple[Hashable, ...]
    support: int                 # number of sequences containing the pattern

    def __len__(self) -> int:
        return len(self.items)


def prefixspan(
    sequences: Sequence[Sequence[Hashable]],
    min_support: int = 2,
    max_len: int = 5,
    max_gap: int = 2,
) -> List[Pattern]:
    """Mine frequent sequential patterns.

    Returns patterns sorted by (length desc, support desc).  ``max_gap``
    bounds the number of skipped events between CONSECUTIVE pattern items
    (gap=1 means strictly contiguous); the first item may occur anywhere.

    Projections track EVERY in-window occurrence position per sequence
    (standard gap-constrained pseudo-projection).  Keeping only the
    earliest occurrence undercounts: in ``[a b a c]`` with ``max_gap=2``
    the pattern ``(a, c)`` is supported by the second ``a`` (adjacent to
    ``c``) even though the window after the first ``a`` contains no ``c``.
    """
    # projected database: (seq_idx, next_start_pos) — possibly several
    # positions per sequence, one per valid occurrence of the prefix
    def project(db: List[Tuple[int, int]], item: Hashable,
                anchored: bool) -> List[Tuple[int, int]]:
        out = []
        seen = set()
        for si, pos in db:
            seq = sequences[si]
            end = min(len(seq), pos + max_gap) if anchored else len(seq)
            for j in range(pos, end):
                if seq[j] == item and (si, j + 1) not in seen:
                    seen.add((si, j + 1))
                    out.append((si, j + 1))
        return out

    results: List[Pattern] = []

    def grow(prefix: Tuple[Hashable, ...], db: List[Tuple[int, int]]):
        if len(prefix) >= max_len:
            return
        # count candidate next items: gap-windowed after a non-empty prefix,
        # anywhere in the sequence for the pattern's first item
        anchored = bool(prefix)
        counts: Dict[Hashable, set] = defaultdict(set)
        for si, pos in db:
            seq = sequences[si]
            end = min(len(seq), pos + max_gap) if anchored else len(seq)
            for j in range(pos, end):
                counts[seq[j]].add(si)
        for item, seqs in sorted(counts.items(), key=lambda kv: repr(kv[0])):
            sup = len(seqs)
            if sup < min_support:
                continue
            new_prefix = prefix + (item,)
            results.append(Pattern(new_prefix, sup))
            grow(new_prefix, project(db, item, anchored))

    root_db = [(i, 0) for i in range(len(sequences))]
    grow((), root_db)
    results.sort(key=lambda p: (-len(p.items), -p.support, repr(p.items)))
    return results


def conditional_next(
    sequences: Sequence[Sequence[Hashable]],
    context_len: int = 2,
    min_count: int = 2,
) -> Dict[Tuple[Hashable, ...], Dict[Hashable, float]]:
    """Empirical P(next item | last `context_len` items) tables — the (C, p)
    part of PASTE pattern tuples, for every context length 1..context_len."""
    counts: Dict[Tuple, Dict[Hashable, int]] = defaultdict(lambda: defaultdict(int))
    for seq in sequences:
        for i in range(1, len(seq)):
            for cl in range(1, context_len + 1):
                if i - cl < 0:
                    continue
                ctx = tuple(seq[i - cl : i])
                counts[ctx][seq[i]] += 1
    tables: Dict[Tuple, Dict[Hashable, float]] = {}
    for ctx, nxt in counts.items():
        total = sum(nxt.values())
        if total < min_count:
            continue
        tables[ctx] = {k: v / total for k, v in nxt.items()}
    return tables
