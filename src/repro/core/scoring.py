"""Expected critical-path reduction scoring (paper Eq. 3–4), JAX-vectorized.

    EU(H_i | S) = q_i · ( ΔO_i(S) + λ·ΔU_i(S) − μ·ΔI_i(S) )

Instantiation (the paper defines the terms semantically; these are our
concrete estimators, documented in DESIGN.md):

  ΔO_i — overlap gain: the solo latency of the admitted *prefix*, i.e. the
        serial time hidden if the agent follows this branch (capped by the
        expected idle window when provided).
  ΔU_i — downstream unlock gain: the critical-path length of the subgraph
        *behind* the prefix (longest path over G_i restricted to post-prefix
        nodes, each weighted by its conditional probability).  Early prefix
        completion lets this chain start earlier, so its critical path is
        the unlockable latency.
  ΔI_i — interference penalty: bottleneck-model stretch of the candidate
        prefix under the currently-admitted demand, plus the stretch it
        inflicts on the admitted set (Eq. 4: L^co − L^solo).

The whole beam is scored in one jit call over padded (K, N) tables — the
scheduler itself must not eat the slack it is trying to exploit.

Three per-tick terms ride ALONGSIDE the packed tables (never inside them,
so pack caches survive): tenant fairness weights, the result-store reuse
term (memo masks + memo-excluded ρ), and the model-step service's
queue-delay discount on ΔU (``model_delay``).

Paper anchor: Eq. 3 (EU objective), Eq. 4 (ΔI interference term).
Upstream: hypothesis.py (beams), interference.py (Machine/stretch model),
model_service.py (expected unlock delay).  Downstream: admission.py
(shares ``static_gain_terms``/``eu_given_admitted``), runtime Phase 4.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import RESOURCE_DIMS
from repro.core.hypothesis import BranchHypothesis, NodeKind
from repro.core.interference import Machine


@dataclass
class PackedBeam:
    """Padded arrays for a beam of K hypotheses, Nmax nodes each.

    ``prefix_mask`` marks the speculatively-executable FRONTIER region of
    each subgraph (``BranchHypothesis.safe_prefix``): for tree-shaped
    hypotheses a blocked branch bounds only its own subtree, so the mask is
    a set of root-connected nodes, not a contiguous list prefix.  The DAG
    adjacency drives ΔU's critical path either way."""
    node_lat: np.ndarray      # (K, N)
    node_prob: np.ndarray     # (K, N) conditional probs
    node_mask: np.ndarray     # (K, N)
    prefix_mask: np.ndarray   # (K, N)
    adj: np.ndarray           # (K, N, N)  adj[k, i, j] = edge i->j
    q: np.ndarray             # (K,)
    rho: np.ndarray           # (K, R) prefix aggregate demand
    k_valid: np.ndarray       # (K,) hypothesis mask


def prefix_rho(h: BranchHypothesis, exclude: frozenset = frozenset()) -> np.ndarray:
    """Worst-case concurrent demand of the safe-prefix frontier region.

    Nodes on one root path run serially (ancestor gating), but sibling
    branches of a tree-shaped prefix may run CONCURRENTLY, so the
    element-wise max over prefix nodes (exact for linear chains) would
    understate a branchy prefix.  Per-dimension DP over the prefix
    sub-forest: conc(v) = max(rho_v, Σ_children conc(child)); disconnected
    prefix roots co-run, so their conc sums.  Reduces to the element-wise
    max for chains.

    ``exclude`` holds node idxs that demand NOTHING (memoized nodes — the
    result store serves them without execution); they stay in the tree
    structure so serial parent->child paths remain connected."""
    prefix = {n.idx: n for n in h.safe_prefix()}
    if not prefix:
        return np.zeros(RESOURCE_DIMS)
    # effective parent = nearest ANCESTOR in the prefix: BARRIER nodes are
    # prefix-transparent (passed but not emitted), so serial parent->barrier
    # ->child paths must stay connected here or the child would be summed
    # as a bogus concurrent root
    parents = h.parent_map()
    children: dict = {}
    roots = []
    for idx in prefix:
        ps = parents.get(idx, ())
        anc = ps[0] if ps else None
        while anc is not None and anc not in prefix:
            ps = parents.get(anc, ())
            anc = ps[0] if ps else None
        if anc is None:
            roots.append(idx)
        else:
            children.setdefault(anc, []).append(idx)

    def conc(i: int) -> np.ndarray:
        own = (np.zeros(RESOURCE_DIMS) if i in exclude
               else prefix[i].rho.as_array())
        kids = children.get(i)
        if not kids:
            return own
        return np.maximum(own, np.sum([conc(j) for j in kids], axis=0))

    return np.sum([conc(i) for i in roots], axis=0)


def pack_rows(h: BranchHypothesis, n_max: int) -> tuple:
    """One hypothesis's packed row set — the per-row slice of every
    PackedBeam table.  Hypotheses are immutable after build (node statuses
    live on NodeRun, never read here), so rows keyed by hid are cacheable
    forever: re-packing a pooled beam then costs an array copy per row
    instead of the safe-prefix/parent-map/rho DP per node."""
    N = n_max
    node_lat = np.zeros(N)
    node_prob = np.ones(N)
    node_mask = np.zeros(N)
    prefix_mask = np.zeros(N)
    adj = np.zeros((N, N))
    prefix_ids = {n.idx for n in h.safe_prefix()}
    for n in h.nodes[:N]:
        node_lat[n.idx] = n.est_latency
        node_prob[n.idx] = n.cond_prob
        node_mask[n.idx] = 1.0
        if n.idx in prefix_ids:
            prefix_mask[n.idx] = 1.0
    for i, j in h.edges:
        if i < N and j < N:
            adj[i, j] = 1.0
    return (node_lat, node_prob, node_mask, prefix_mask, adj, h.q,
            prefix_rho(h))


def pack_beam(hyps: Sequence[BranchHypothesis], k_max: int, n_max: int,
              row_cache: Optional[dict] = None) -> PackedBeam:
    """Pack a candidate beam into the fused-admission tables.  With a
    ``row_cache`` ({hid: pack_rows(...)}, caller-owned and caller-bounded)
    the per-hypothesis Python DP runs once per hid ever — incremental
    re-packing for pooled cross-episode beams whose membership churns by
    one episode at a time."""
    K, N = k_max, n_max
    node_lat = np.zeros((K, N))
    node_prob = np.ones((K, N))
    node_mask = np.zeros((K, N))
    prefix_mask = np.zeros((K, N))
    adj = np.zeros((K, N, N))
    q = np.zeros((K,))
    rho = np.zeros((K, RESOURCE_DIMS))
    k_valid = np.zeros((K,))
    for k, h in enumerate(hyps[:K]):
        if row_cache is None:
            rows = pack_rows(h, N)
        else:
            rows = row_cache.get(h.hid)
            if rows is None:
                rows = row_cache[h.hid] = pack_rows(h, N)
        k_valid[k] = 1.0
        (node_lat[k], node_prob[k], node_mask[k], prefix_mask[k], adj[k],
         q[k], rho[k]) = rows
    return PackedBeam(node_lat, node_prob, node_mask, prefix_mask, adj, q, rho, k_valid)


@functools.partial(jax.jit, static_argnames=("n_iters",))
def _critical_path(adj, lat, mask, n_iters: int):
    """Longest path (per hypothesis) over masked DAG.  adj (K,N,N), lat (K,N)."""
    lat = lat * mask

    def body(_, dist):
        # dist[k, j] = max_i adj[i,j] * (dist[i] + lat[j])
        via = jnp.max(adj * (dist[:, :, None] + lat[:, None, :]), axis=1)
        return jnp.maximum(dist, via * (mask > 0))

    dist0 = lat
    dist = jax.lax.fori_loop(0, n_iters, body, dist0)
    return dist.max(axis=1)


def static_raw_terms(node_lat, node_prob, node_mask, prefix_mask, adj,
                     n_nodes: int):
    """The hypothesis-INTRINSIC half of ``static_gain_terms`` (host/numpy):
    everything computable from the packed rows alone, before the two
    per-tick inputs (``memo_mask``, ``model_delay``) are applied.  Rows are
    independent — no term mixes hypotheses — so values computed for a row in
    any batch are bit-identical to the same row in any other batch, which is
    what makes the per-hid admission warm cache sound (hids are globally
    unique and BranchHypothesis is immutable after build).

    Returns ``(l_solo, lat_pref, raw_delta_u)`` where ``lat_pref`` is the
    per-node prefix latency row (``node_lat * prefix_mask``, kept unreduced
    so ``finish_static_terms`` can apply a fresh memo mask) and
    ``raw_delta_u`` is the post-prefix critical path BEFORE the model-delay
    clamp."""
    lat_pref = node_lat * prefix_mask
    l_solo = lat_pref.sum(axis=1)
    post_mask = node_mask * (1.0 - prefix_mask)
    elp = node_lat * node_prob * post_mask
    dist = elp.copy()
    for _ in range(n_nodes):               # masked longest-path relaxation
        via = np.max(adj * (dist[:, :, None] + elp[:, None, :]), axis=1)
        dist = np.maximum(dist, via * (post_mask > 0))
    return l_solo, lat_pref, dist.max(axis=1)


def finish_static_terms(l_solo, lat_pref, raw_delta_u, idle_window,
                        memo_mask=None, model_delay=0.0):
    """Fold the per-tick inputs into cached raw terms (host/numpy): the memo
    mask drops store-served prefix nodes from the interference-exposed
    latency, and the model delay clamps ΔU — the only two places per-tick
    state enters the static terms.  Same arithmetic, same order as the
    un-cached path, so results are bit-identical by construction."""
    if memo_mask is None:
        l_exec = l_solo
    else:
        l_exec = (lat_pref * (1.0 - memo_mask)).sum(axis=1)
    delta_o = np.minimum(l_solo, idle_window)
    delta_u = np.maximum(raw_delta_u - model_delay, 0.0)
    return l_solo, l_exec, delta_o, delta_u


def static_gain_terms(node_lat, node_prob, node_mask, prefix_mask, adj,
                      idle_window, n_nodes: int, memo_mask=None,
                      model_delay=0.0, xp=jnp):
    """Per-hypothesis terms independent of the admitted set: prefix solo
    latency, the prefix's EXECUTED latency, ΔO (idle-window-capped), and ΔU
    (post-prefix critical path).

    ``memo_mask`` (K, N) marks prefix nodes whose results the cross-episode
    store already holds (the reuse term): they still contribute their
    latency to ΔO — the agent is served the hidden serial time either way —
    but they need no execution, so they drop out of ``l_exec`` (the latency
    exposed to interference in ΔI) exactly as they drop out of the prefix ρ
    the caller passes alongside (``prefix_rho(h, exclude=...)``).

    ``model_delay`` is the model-step service's expected queue+batch-window
    delay (``ModelStepService.expected_unlock_delay``).  Every hypothesis's
    post-prefix chain is headed by the terminal MODEL join — the next
    reasoning boundary — so the downstream unlock cannot start earlier than
    the batch admission window lets that model step start: a branch whose
    unlock would land inside an already-forming batch is worth less
    critical-path reduction, hence ``ΔU ← max(ΔU − model_delay, 0)``.
    0 (the ``max_batch=1`` baseline) leaves ΔU bit-identical.

    Traceable helper shared by ``score_beam`` and the fused admission kernel
    — the latter hoists these out of its while_loop since only ΔI depends on
    the admitted demand.  Returns (l_solo, l_exec, delta_o, delta_u)."""
    if xp is not jnp:
        # host-side fast path: the raw/finish split is THE implementation
        # (the admission warm cache replays static_raw_terms results per
        # hid, so both cached and uncached passes must go through it)
        l_solo, lat_pref, raw_du = static_raw_terms(
            node_lat, node_prob, node_mask, prefix_mask, adj, n_nodes)
        return finish_static_terms(l_solo, lat_pref, raw_du, idle_window,
                                   memo_mask=memo_mask,
                                   model_delay=model_delay)
    l_solo = (node_lat * prefix_mask).sum(axis=1)
    if memo_mask is None:
        l_exec = l_solo
    else:
        l_exec = (node_lat * prefix_mask * (1.0 - memo_mask)).sum(axis=1)
    delta_o = xp.minimum(l_solo, idle_window)
    post_mask = node_mask * (1.0 - prefix_mask)
    exp_lat = node_lat * node_prob
    delta_u = _critical_path(adj, exp_lat, post_mask, n_iters=n_nodes)
    delta_u = xp.maximum(delta_u - model_delay, 0.0)
    return l_solo, l_exec, delta_o, delta_u


def eu_given_admitted(l_exec, delta_o, delta_u, q, rho, k_valid,
                      admitted_rho, cap, lam, mu, idle_window, xp=jnp):
    """EU (Eq. 3) for every hypothesis conditioned on the admitted demand.

    Only ΔI varies with the admitted set; the static terms come from
    ``static_gain_terms``.  ``l_exec`` is the prefix latency that actually
    EXECUTES (memoized nodes excluded — they are served, not run, so no
    interference touches them).  ``xp`` selects the array backend — jnp
    inside the jitted kernels, np for the host-side small-beam fast path —
    so the estimator has exactly one implementation.  Returns (eu (K,),
    delta_i (K,))."""
    # ΔI: bottleneck stretch of prefix under admitted demand + inflicted
    util = (admitted_rho[None, :] + rho) / cap[None, :]          # (K,R)
    stretch = xp.where(rho > 0, xp.maximum(util, 1.0), 1.0).max(axis=1)
    self_pen = l_exec * (stretch - 1.0)
    # inflicted on admitted set: admitted work stretched by new util
    adm_util = admitted_rho / cap
    adm_stretch_before = xp.maximum(adm_util, 1.0).max()
    adm_stretch_after = xp.where(
        admitted_rho[None, :] > 0, xp.maximum(util, 1.0), 1.0
    ).max(axis=1)
    inflicted = xp.maximum(adm_stretch_after - adm_stretch_before, 0.0) * idle_window
    delta_i = self_pen + inflicted
    eu = q * (delta_o + lam * delta_u - mu * delta_i) * k_valid
    return eu, delta_i


@functools.partial(jax.jit, static_argnames=("n_nodes",))
def score_beam(
    node_lat, node_prob, node_mask, prefix_mask, adj, q, rho, k_valid,
    memo_mask, admitted_rho, cap, lam, mu, idle_window, model_delay,
    spec_cost, shed_penalty, n_nodes: int,
):
    """Vectorized EU for every hypothesis given the admitted demand.

    ``memo_mask`` (K, N) marks store-memoized prefix nodes (zero execution,
    zero interference exposure); ``rho`` must already exclude them.
    ``model_delay`` discounts ΔU by the model-step service's expected
    queue+batch-window delay (see ``static_gain_terms``).
    ``spec_cost`` (K,) is the slot-marginal model-step cost of the
    hypothesis's speculative MODEL step: ~0 when it would ride an idle slot
    of a forming under-full batch, the full dispatch latency when it would
    have to open a new batch.  It enters the objective as an interference
    term (μ-scaled, subtracted from the gain) BEFORE ΔI — zeros are an
    IEEE-exact no-op, keeping non-speculative scoring bit-identical.
    ``shed_penalty`` (traced scalar ≥ 0) is the load-shedding tax under
    open-loop overload: arrived-but-unlaunched tenants will claim the idle
    window the candidate's ΔO counts on, so every candidate's overlap gain
    is discounted by the backlog pressure — the lowest-EU speculation sheds
    first, and at high load the whole beam prices itself out before any
    authoritative work queues behind it.  Folded at the SAME point as
    ``spec_cost`` in every admission path; 0 (closed loop / shedding off)
    is an IEEE-exact no-op.

    Returns (eu (K,), delta_o, delta_u, delta_i)."""
    l_solo, l_exec, delta_o, delta_u = static_gain_terms(
        node_lat, node_prob, node_mask, prefix_mask, adj, idle_window,
        n_nodes, memo_mask=memo_mask, model_delay=model_delay,
    )
    delta_o = delta_o - mu * spec_cost - shed_penalty
    eu, delta_i = eu_given_admitted(
        l_exec, delta_o, delta_u, q, rho, k_valid, admitted_rho, cap,
        lam, mu, idle_window,
    )
    return eu, delta_o, delta_u, delta_i


def tenant_fairness_weights(
    spec_share: dict, alpha: float = 1.0
) -> dict:
    """Per-tenant multiplier for the shared cross-episode beam's EU objective.

    ``spec_share[eid]`` is tenant eid's current in-flight speculative demand,
    bottleneck-normalized (max over dimensions of demand/cap, summed over the
    tenant's running speculative jobs).  The weight

        w_e = 1 / (1 + α · share_e)

    discounts candidates from tenants already holding speculative capacity,
    so one episode's deep tree cannot monopolize the shared beam round after
    round while other tenants' candidates starve.  EU is linear in q, so
    applying w_e to EU equals scoring with q·w_e (admission.py threads the
    weights through every admission path identically).  Weights are positive
    and ≤ 1; with a single tenant — or α=0 — every weight is a common
    positive factor, which leaves the greedy order and the eu>0 threshold
    unchanged (single-episode admissions are bit-identical to unweighted)."""
    return {eid: 1.0 / (1.0 + alpha * max(float(s), 0.0))
            for eid, s in spec_share.items()}


@dataclass
class Scorer:
    machine: Machine
    lam: float = 0.5
    mu: float = 1.0
    k_max: int = 8
    n_max: int = 12

    def score(
        self,
        hyps: Sequence[BranchHypothesis],
        admitted_rho: np.ndarray,
        idle_window: float = 10.0,
        memo_masks: Optional[np.ndarray] = None,
        memo_rho: Optional[np.ndarray] = None,
        model_delay: float = 0.0,
        spec_costs: Optional[np.ndarray] = None,
        shed_penalty: float = 0.0,
    ) -> Tuple[np.ndarray, PackedBeam, dict]:
        """``memo_masks`` (len(hyps), N) / ``memo_rho`` (len(hyps), R) carry
        the store-reuse term: per-node memoized flags and the matching
        memo-excluded prefix demand.  They ride ALONGSIDE the packed tables
        (like fairness weights) — the PackedBeam stays store-agnostic, so
        runtime pack caches remain valid as the store fills.  ``model_delay``
        is the model-step service's expected unlock delay (a traced scalar:
        it changes every tick without recompiling).  ``spec_costs``
        (len(hyps),) is the per-hypothesis slot-marginal model-step cost
        (see ``score_beam``); None means zeros (bit-identical no-op).
        ``shed_penalty`` is the scalar load-shedding ΔO tax (see
        ``score_beam``); 0 (the default) is a bit-identical no-op."""
        pb = pack_beam(hyps, self.k_max, self.n_max)
        K = pb.q.shape[0]
        mm = np.zeros((K, self.n_max))
        sc = np.zeros(K)
        rho = pb.rho
        if memo_masks is not None:
            mm[: len(hyps), :] = np.asarray(memo_masks, float)
        if spec_costs is not None:
            sc[: len(hyps)] = np.asarray(spec_costs, float)
        if memo_rho is not None:
            rho = rho.copy()
            rho[: len(hyps), :] = np.asarray(memo_rho, float)
        eu, do, du, di = score_beam(
            pb.node_lat, pb.node_prob, pb.node_mask, pb.prefix_mask, pb.adj,
            pb.q, rho, pb.k_valid, jnp.asarray(mm),
            jnp.asarray(admitted_rho), jnp.asarray(self.machine.cap_array()),
            self.lam, self.mu, idle_window, model_delay, jnp.asarray(sc),
            shed_penalty, n_nodes=self.n_max,
        )
        detail = {
            "delta_o": np.asarray(do), "delta_u": np.asarray(du),
            "delta_i": np.asarray(di),
        }
        return np.asarray(eu), pb, detail

    def score_all(
        self,
        hyps: Sequence[BranchHypothesis],
        admitted_rho: np.ndarray,
        idle_window: float = 10.0,
        memo_masks: Optional[np.ndarray] = None,
        memo_rho: Optional[np.ndarray] = None,
        model_delay: float = 0.0,
        spec_costs: Optional[np.ndarray] = None,
        shed_penalty: float = 0.0,
    ) -> np.ndarray:
        """EU for EVERY hypothesis, chunked over ``k_max``-sized beams.

        ``score`` silently truncates beams wider than ``k_max`` (the padded
        tables only hold the first K rows); this scores len(hyps) entries by
        chunking.  Exact: EU has no cross-hypothesis coupling — ΔI depends
        only on the candidate's own ρ and the (shared) admitted demand."""
        if not len(hyps):
            return np.zeros(0)
        out = []
        for i in range(0, len(hyps), self.k_max):
            chunk = hyps[i:i + self.k_max]
            eu, _, _ = self.score(
                chunk, admitted_rho, idle_window,
                memo_masks=None if memo_masks is None
                else memo_masks[i:i + self.k_max],
                memo_rho=None if memo_rho is None
                else memo_rho[i:i + self.k_max],
                model_delay=model_delay,
                spec_costs=None if spec_costs is None
                else spec_costs[i:i + self.k_max],
                shed_penalty=shed_penalty,
            )
            out.append(eu[: len(chunk)])
        return np.concatenate(out)
