"""Pallas TPU flash-decode: one query token vs a long KV cache.

Decode attention is memory-bound (every step streams the whole cache from
HBM).  Design:
  * grid (B, KV, nS): per (batch, kv-head) the cache is streamed in
    (block_k, head_dim) VMEM tiles; the sequence axis is innermost and
    sequential so the running-softmax scratch carries across tiles.
  * the whole GQA head-group (grp = H/KV queries) rides along in one
    (grp, head_dim) VMEM tile, amortizing each KV byte over grp queries —
    the kernel's arithmetic intensity is 2·grp FLOPs/byte.
  * fp32 scratch; out-of-length positions masked with the `lengths` scalar
    prefetch.
  * variable lengths + optional sliding window (rolling-buffer caches pass
    an effective length instead).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(
    lengths_ref,                      # scalar prefetch (B,)
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, window: Optional[int], block_k: int,
    partials: bool = False, m_out=None, l_out=None,
):
    bi = pl.program_id(0)
    si = pl.program_id(2)
    ns = pl.num_programs(2)
    length = lengths_ref[bi]

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = si * block_k
    run = k_start < length
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1 >= length - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (grp, d)
        k = k_ref[0, 0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                      # (grp, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < length
        if window is not None:
            mask = jnp.logical_and(mask, kpos >= length - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                    # (bk, d)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        if partials:
            o_ref[0, 0] = acc_scr[...].astype(o_ref.dtype)
        else:
            o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _kernel_partials(
    lengths_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, window: Optional[int], block_k: int,
):
    _kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            scale=scale, window=window, block_k=block_k, partials=True)
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == ns - 1)
    def _emit_stats():
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l_scr[...]


def decode_attention_pallas(
    q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
    lengths: jnp.ndarray, *, scale: float, window: Optional[int],
    block_k: int = 512, interpret: bool = False,
) -> jnp.ndarray:
    """q (B,H,D), cache (B,Smax,KV,D), lengths (B,) -> (B,H,D)."""
    b, h, d = q.shape
    smax, kv = k_cache.shape[1], k_cache.shape[2]
    grp = h // kv
    block_k = min(block_k, smax)
    ns = -(-smax // block_k)
    pad = ns * block_k - smax
    kt = jnp.moveaxis(k_cache, 2, 1)          # (B,KV,S,D)
    vt = jnp.moveaxis(v_cache, 2, 1)
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qg = q.reshape(b, kv, grp, d)

    grid = (b, kv, ns)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, block_k=block_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, grp, d), lambda bi, hi, si, *_: (bi, hi, 0, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, si, *_: (bi, hi, si, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, si, *_: (bi, hi, si, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, grp, d), lambda bi, hi, si, *_: (bi, hi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((grp, 1), jnp.float32),
                pltpu.VMEM((grp, 1), jnp.float32),
                pltpu.VMEM((grp, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, grp, d), q.dtype),
        compiler_params=compat.pltpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kt, vt)
    return out.reshape(b, h, d)


def decode_attention_partials_pallas(
    q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
    lengths: jnp.ndarray, *, scale: float, window: Optional[int],
    block_k: int = 512, interpret: bool = False,
):
    """Flash-decode PARTIALS for distributed split-KV combination: returns
    (acc (B,KV,G,D) fp32 unnormalized, m (B,KV,G) fp32, l (B,KV,G) fp32)
    over the LOCAL cache slice.  The caller pmax/psum-combines across
    shards (models/attention.attn_decode_sharded)."""
    b, h, d = q.shape
    smax, kv = k_cache.shape[1], k_cache.shape[2]
    grp = h // kv
    block_k = min(block_k, smax)
    ns = -(-smax // block_k)
    pad = ns * block_k - smax
    kt = jnp.moveaxis(k_cache, 2, 1)
    vt = jnp.moveaxis(v_cache, 2, 1)
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qg = q.reshape(b, kv, grp, d)
    grid = (b, kv, ns)
    acc, m, l = pl.pallas_call(
        functools.partial(_kernel_partials, scale=scale, window=window,
                          block_k=block_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, grp, d), lambda bi, hi, si, *_: (bi, hi, 0, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, si, *_: (bi, hi, si, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, si, *_: (bi, hi, si, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, grp, d), lambda bi, hi, si, *_: (bi, hi, 0, 0)),
                pl.BlockSpec((1, 1, grp, 1), lambda bi, hi, si, *_: (bi, hi, 0, 0)),
                pl.BlockSpec((1, 1, grp, 1), lambda bi, hi, si, *_: (bi, hi, 0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((grp, 1), jnp.float32),
                pltpu.VMEM((grp, 1), jnp.float32),
                pltpu.VMEM((grp, d), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, grp, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, grp, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, grp, 1), jnp.float32),
        ],
        compiler_params=compat.pltpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kt, vt)
    return acc, m[..., 0], l[..., 0]
