"""Pallas TPU Mamba2 SSD chunked scan.

Single-kernel design exploiting TPU sequential grid semantics: grid
(B, H, nChunks) with the chunk axis innermost and "arbitrary" (sequential),
so a VMEM scratch carries the recurrent inter-chunk state (N, P) across
chunks of the same (batch, head) — the TPU-native replacement for the
multi-kernel Triton decomposition (chunk_state / state_passing /
chunk_scan) used on GPU.

Per (b, h, c) the kernel computes, entirely in VMEM:
  * inclusive decay cumsum  cs = cumsum(dt·A)               (Q,)
  * inter-chunk:  Y_inter = exp(cs)·(C @ S_prev)            (Q,P)
  * intra-chunk:  scores  = (C @ Bᵀ) ⊙ L ⊙ dtⱼ, L = exp(csᵢ−csⱼ)·causal
                  Y_intra = scores @ X                      (Q,P)
  * state update: S = exp(cs[Q−1])·S_prev + (decay_to_end·dt·B)ᵀ @ X
Chunk Q defaults to 128 (MXU-aligned); head_dim P and state N are 64/128.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
    y_ref, hout_ref,
    state_scr,
    *, chunk: int, use_h0: bool,
):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        if use_h0:
            state_scr[...] = h0_ref[0, 0].astype(jnp.float32)   # (N, P)
        else:
            state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)[:, 0]  # (Q,)
    a = a_ref[0, 0]                             # scalar
    bmat = b_ref[0, 0, 0].astype(jnp.float32)   # (Q, N)
    cmat = c_ref[0, 0, 0].astype(jnp.float32)   # (Q, N)
    dcoef = d_ref[0, 0]                         # scalar

    da = dt * a                                 # (Q,)
    cs = jnp.cumsum(da)                         # inclusive (Q,)

    s_prev = state_scr[...]                     # (N, P)
    # inter-chunk contribution
    y_inter = jnp.exp(cs)[:, None] * jax.lax.dot_general(
        cmat, s_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # (Q, P)
    # intra-chunk quadratic part
    li = cs[:, None]
    lj = cs[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(iota_i >= iota_j, jnp.exp(li - lj), 0.0)
    cb = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # (Q, Q)
    scores = cb * lmat * dt[None, :]
    y = y_inter + jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y = y + dcoef * x
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update: S_new = exp(cs[-1]) * S_prev + sum_j w_j * B_j (outer) X_j
    w = jnp.exp(cs[-1] - cs) * dt               # (Q,)
    s_new = jnp.exp(cs[-1]) * s_prev + jax.lax.dot_general(
        bmat * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                           # (N, P)
    state_scr[...] = s_new

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = s_new


def ssd_scan_pallas(
    x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
    C: jnp.ndarray, D: jnp.ndarray, *, chunk: int = 128,
    initial_state: Optional[jnp.ndarray] = None, interpret: bool = False,
):
    """Shapes as ops.ssd_scan: x (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,G,N),
    D (H,) -> (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    reps = h // g
    nc = -(-s // chunk)
    pad = nc * chunk - s

    def pad_seq(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    # layouts: per-(b,h) tiles
    xt = jnp.moveaxis(pad_seq(x), 2, 1).reshape(b, h, nc, chunk, p)
    dtt = jnp.moveaxis(pad_seq(dt), 2, 1).reshape(b, h, nc, chunk, 1)
    bt = jnp.repeat(jnp.moveaxis(pad_seq(B), 2, 1), reps, axis=1).reshape(b, h, nc, chunk, n)
    ct = jnp.repeat(jnp.moveaxis(pad_seq(C), 2, 1), reps, axis=1).reshape(b, h, nc, chunk, n)
    a2 = A.reshape(h, 1).astype(jnp.float32)
    d2 = D.reshape(h, 1).astype(jnp.float32)
    use_h0 = initial_state is not None
    h0 = (
        initial_state.transpose(0, 1, 3, 2).astype(jnp.float32)  # (B,H,N,P)
        if use_h0
        else jnp.zeros((b, h, n, p), jnp.float32)
    )

    grid = (b, h, nc)
    y, hout = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, use_h0=use_h0),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, chunk, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=compat.pltpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xt, dtt, a2, bt, ct, d2, h0)
    y = y.reshape(b, h, nc * chunk, p)[:, :, :s]
    y = jnp.moveaxis(y, 1, 2)                    # (B,S,H,P)
    return y.astype(x.dtype), hout.transpose(0, 1, 3, 2)  # (B,H,P,N)
