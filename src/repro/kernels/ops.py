"""Public jit'd kernel wrappers with implementation dispatch.

impl selection:
  'auto'             -> pallas on TPU backend, memory-efficient jnp otherwise
  'pallas'           -> pl.pallas_call, TPU lowering
  'pallas_interpret' -> pl.pallas_call(interpret=True)  (CPU validation)
  'jnp'              -> chunked, memory-efficient pure-jnp (dry-run / CPU path)
  'ref'              -> the naive oracle from ref.py

The jnp implementations are written flash-style (lax.scan over KV / SSD
chunks with streaming softmax / state) so that the *dry-run* HLO has
realistic peak-memory behaviour — materializing (S, S) score matrices at
32k would make ``memory_analysis()`` meaningless.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

NEG_INF = -1e30


def _backend() -> str:
    return jax.default_backend()


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _backend() == "tpu" else "jnp"
    return impl


# ======================================================================
# Flash attention (prefill / training)
# ======================================================================

def _jnp_flash_attention(
    q, k, v, *, causal: bool, window: Optional[int], scale: float,
    block_k: int = 512,
):
    """Streaming-softmax attention: lax.scan over KV blocks. q (B,S,H,D)."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    grp = h // kv
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, kv, grp, d)
    nblk = -(-sk // block_k)
    pad = nblk * block_k - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kp.reshape(b, nblk, block_k, kv, d)
    vb = vp.reshape(b, nblk, block_k, kv, d)
    qpos = jnp.arange(sq) + (sk - sq)  # right-aligned

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, blk_idx = inp  # (B,bk,KV,D) x2, scalar
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kblk.astype(jnp.float32))
        kpos = blk_idx * block_k + jnp.arange(block_k)
        mask = kpos[None, :] < sk
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, sq, kv, grp), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, grp), jnp.float32)
    a0 = jnp.zeros((b, sq, kv, grp, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nblk)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, d).astype(q.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "impl", "block_q", "block_k")
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    impl: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    """Multi-head GQA attention. q (B,S,H,D), k/v (B,S,KV,D) -> (B,S,H,D)."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.mha_reference(q, k, v, causal=causal, window=window, scale=scale)
    if impl == "jnp":
        return _jnp_flash_attention(q, k, v, causal=causal, window=window, scale=scale)
    from repro.kernels import flash_attention as _fa

    return _fa.flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k,
        interpret=(impl == "pallas_interpret"),
    )


# ======================================================================
# Decode attention (single new token vs KV cache)
# ======================================================================

def _jnp_decode_attention(
    q, k_cache, v_cache, lengths, *, scale: float, window: Optional[int],
    block_k: int = 1024,
):
    """Streaming decode attention: scan over cache blocks. q (B,H,D)."""
    b, h, d = q.shape
    smax, kv = k_cache.shape[1], k_cache.shape[2]
    grp = h // kv
    qf = (q.astype(jnp.float32) * scale).reshape(b, kv, grp, d)
    nblk = -(-smax // block_k)
    pad = nblk * block_k - smax
    kp = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kp.reshape(b, nblk, block_k, kv, d)
    vb = vp.reshape(b, nblk, block_k, kv, d)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, blk = inp
        s = jnp.einsum("bhgd,bkhd->bhgk", qf, kblk.astype(jnp.float32))
        kpos = blk * block_k + jnp.arange(block_k)
        mask = kpos[None, :] < lengths[:, None]
        if window is not None:
            mask &= kpos[None, :] >= (lengths[:, None] - window)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgk,bkhd->bhgd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, kv, grp), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, grp), jnp.float32)
    a0 = jnp.zeros((b, kv, grp, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nblk)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, h, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "impl", "block_k"))
def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    impl: str = "auto",
    block_k: int = 512,
) -> jnp.ndarray:
    """Flash-decode. q (B,H,D), cache (B,Smax,KV,D), lengths (B,) -> (B,H,D)."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.decode_attention_reference(
            q, k_cache, v_cache, lengths, scale=scale, window=window
        )
    if impl == "jnp":
        return _jnp_decode_attention(
            q, k_cache, v_cache, lengths, scale=scale, window=window
        )
    from repro.kernels import decode_attention as _da

    return _da.decode_attention_pallas(
        q, k_cache, v_cache, lengths, scale=scale, window=window,
        block_k=block_k, interpret=(impl == "pallas_interpret"),
    )


# ======================================================================
# Mamba2 SSD chunked scan
# ======================================================================

def _segsum_chunk(dA: jnp.ndarray) -> jnp.ndarray:
    """Inclusive within-chunk cumsum of dt*A.  dA (..., Q) -> (..., Q)."""
    return jnp.cumsum(dA, axis=-1)


def _jnp_ssd_chunked(x, dt, A, B, C, D, *, chunk: int, initial_state=None):
    """Chunked SSD (state-space dual) in pure jnp.  Shapes as ref.ssd_reference."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    reps = h // g
    nc = -(-s // chunk)
    pad = nc * chunk - s

    def pad_t(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    xf = pad_t(x.astype(jnp.float32)).reshape(b, nc, chunk, h, p)
    dtf = pad_t(dt.astype(jnp.float32)).reshape(b, nc, chunk, h)
    Bf = jnp.repeat(pad_t(B.astype(jnp.float32)), reps, axis=2).reshape(b, nc, chunk, h, n)
    Cf = jnp.repeat(pad_t(C.astype(jnp.float32)), reps, axis=2).reshape(b, nc, chunk, h, n)

    dA = dtf * A[None, None, None, :]              # (b,nc,Q,h)
    cs = jnp.cumsum(dA, axis=2)                    # inclusive cumsum within chunk
    # --- intra-chunk (quadratic, attention-like) ---
    # L[i,j] = exp(cs[i]-cs[j]) for i>=j else 0
    li = cs[:, :, :, None, :]                      # (b,nc,Q,1,h)
    lj = cs[:, :, None, :, :]                      # (b,nc,1,Q,h)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(li - lj), 0.0)   # (b,nc,Q,Q,h)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cf, Bf) * L * dtf[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xf)
    # --- per-chunk end states ---
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)  # (b,nc,Q,h)
    S_c = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp", decay_to_end * dtf, Bf, xf)
    dA_sum = cs[:, :, -1, :]                       # (b,nc,h)
    # --- inter-chunk state passing (sequential over nc) ---
    h0 = (
        initial_state.astype(jnp.float32).transpose(0, 1, 3, 2)  # (b,h,n,p)
        if initial_state is not None
        else jnp.zeros((b, h, n, p), jnp.float32)
    )

    def pass_state(hprev, inp):
        sc, da = inp                               # (b,h,n,p), (b,h)
        hnew = jnp.exp(da)[..., None, None] * hprev + sc
        return hnew, hprev                         # emit state *entering* the chunk

    h_final, h_in = jax.lax.scan(
        pass_state, h0, (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(dA_sum, 1, 0))
    )
    h_in = jnp.moveaxis(h_in, 0, 1)                # (b,nc,h,n,p)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp", Cf * jnp.exp(cs)[..., None], h_in)
    y = (y_intra + y_inter).reshape(b, nc * chunk, h, p)[:, :s]
    y = y + D[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h_final.transpose(0, 1, 3, 2)  # (b,h,p,n)


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray,
    *,
    chunk: int = 128,
    impl: str = "auto",
    initial_state: Optional[jnp.ndarray] = None,
):
    """Mamba2 SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.ssd_reference(x, dt, A, B, C, D, initial_state=initial_state)
    if impl == "jnp":
        return _jnp_ssd_chunked(x, dt, A, B, C, D, chunk=chunk, initial_state=initial_state)
    from repro.kernels import ssd_scan as _ssd

    return _ssd.ssd_scan_pallas(
        x, dt, A, B, C, D, chunk=chunk,
        initial_state=initial_state,
        interpret=(impl == "pallas_interpret"),
    )


def ssm_decode_step(x, dt, A, B, C, D, state):
    """One recurrent SSM step (decode).  x (B,H,P), dt (B,H), B/C (B,G,N),
    state (B,H,P,N) -> (y (B,H,P), new_state)."""
    b, h, p = x.shape
    g = B.shape[1]
    Bh = jnp.repeat(B, h // g, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C, h // g, axis=1).astype(jnp.float32)
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None, :])
    upd = dtf[..., None, None] * xf[..., :, None] * Bh[:, :, None, :]
    new_state = decay[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch) + D[None, :, None] * xf
    return y.astype(x.dtype), new_state


def decode_attention_partials(
    q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
    lengths: jnp.ndarray, *, scale: Optional[float] = None,
    window: Optional[int] = None, impl: str = "auto", block_k: int = 512,
):
    """Split-KV flash-decode partials over a local cache slice:
    (acc (B,KV,G,D) f32 unnormalized, m (B,KV,G), l (B,KV,G)).

    `lengths` here is the EFFECTIVE length measured against THIS slice's
    global positions — masking against absolute positions is the caller's
    job (it passes position-offset-adjusted lengths or pre-masked caches).
    Used inside shard_map by models.attention.attn_decode_sharded; on TPU
    the Pallas kernel streams the slice through VMEM, on CPU the jnp path
    mirrors it exactly."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import decode_attention as _da

        return _da.decode_attention_partials_pallas(
            q, k_cache, v_cache, lengths, scale=scale, window=window,
            block_k=block_k, interpret=(impl == "pallas_interpret"),
        )
    # jnp path (CPU / dry-run)
    b, h, d = q.shape
    smax, kv = k_cache.shape[1], k_cache.shape[2]
    grp = h // kv
    qf = (q.astype(jnp.float32) * scale).reshape(b, kv, grp, d)
    s_ = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    kpos = jnp.arange(smax)[None, :]
    mask = kpos < lengths[:, None]
    if window is not None:
        mask &= kpos >= (lengths[:, None] - window)
    s_ = jnp.where(mask[:, None, None, :], s_, NEG_INF)
    m = s_.max(axis=-1)
    p_ = jnp.exp(s_ - m[..., None]) * mask[:, None, None, :]
    l = p_.sum(axis=-1)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p_, v_cache.astype(jnp.float32))
    return acc, m, l
