"""Pallas TPU flash attention (prefill / training).

TPU-native design:
  * grid (B, H, nQ, nK); the K axis is innermost and sequential
    ("arbitrary" dimension semantics) so the running-softmax scratch
    carries across K blocks for a fixed Q block.
  * BlockSpec VMEM tiling: Q block (block_q, head_dim), K/V blocks
    (block_k, head_dim) — block sizes default to 128, matching the MXU
    systolic tile (128×128) and VPU lane width.
  * fp32 running max / sum / accumulator scratch in VMEM.
  * GQA: the kv-head index map folds the head-group (no HBM repeat).
  * causal / sliding-window blocks that are fully masked are skipped via
    pl.when (the index map still runs, but no FLOPs are issued).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref,          # VMEM refs
    m_scr, l_scr, acc_scr,               # scratch
    *, scale: float, causal: bool, window: Optional[int],
    block_q: int, block_k: int, seq_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # visibility test for the whole block (skip fully-masked blocks)
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(run, q_start - (k_start + block_k - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                    # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool, window: Optional[int], scale: float,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
) -> jnp.ndarray:
    """q (B,S,H,D), k/v (B,S,KV,D) -> (B,S,H,D).  S padded to block size."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    grp = h // kv
    # BHSD layout inside the kernel
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    nq = -(-s // block_q)
    nk = -(-s // block_k)
    pad_q = nq * block_q - s
    pad_k = nk * block_k - s
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k, seq_len=s,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, qi, ki, grp=grp: (bi, hi // grp, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, qi, ki, grp=grp: (bi, hi // grp, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=compat.pltpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :s]
    return jnp.moveaxis(out, 1, 2)
