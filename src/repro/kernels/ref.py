"""Pure-jnp reference oracles for every Pallas kernel.

These are deliberately the *simplest correct* implementations (materialized
score matrices, sequential recurrences).  Kernel tests assert_allclose
against these; they are never used on the hot path.

Layout conventions (public API, shared with ops.py):
  q, k, v : (B, S, H, D) / (B, S, KV, D)   -- "BSHD"
  decode q: (B, H, D), cache: (B, Smax, KV, D)
  SSD     : x (B, S, H, P), dt (B, S, H), A (H,), B/C (B, S, G, N), D (H,)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_expand(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, S, KV, D) -> (B, S, H, D) by repeating each kv head."""
    b, s, kv, d = k.shape
    if kv == n_heads:
        return k
    assert n_heads % kv == 0
    return jnp.repeat(k, n_heads // kv, axis=2)


def mha_reference(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Plain softmax attention oracle.  q (B,S,H,D), k/v (B,S,KV,D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    k = _gqa_expand(k, h)
    v = _gqa_expand(v, h)
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # right-aligned (prefill: sk==sq)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def decode_attention_reference(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    scale: Optional[float] = None,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Single-token decode attention oracle.

    q (B, H, D); k_cache/v_cache (B, Smax, KV, D); lengths (B,) = #valid
    tokens (the query attends to positions [0, lengths)).
    """
    b, h, d = q.shape
    smax = k_cache.shape[1]
    kk = _gqa_expand(k_cache, h)
    vv = _gqa_expand(v_cache, h)
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhd,bkhd->bhk", q, kk).astype(jnp.float32) * scale
    kpos = jnp.arange(smax)[None, None, :]
    mask = kpos < lengths[:, None, None]
    if window is not None:
        mask &= kpos >= (lengths[:, None, None] - window)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", probs.astype(vv.dtype), vv)


def ssd_reference(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray,
    *,
    initial_state: Optional[jnp.ndarray] = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential Mamba2 SSD recurrence oracle.

    x (B,S,H,P), dt (B,S,H) (post-softplus), A (H,) (negative), B/C (B,S,G,N),
    D (H,).  Heads are grouped: group g = h * G // H.
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    reps = h // g
    Bh = jnp.repeat(B, reps, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(C, reps, axis=2)

    def step(h_state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * A[None, :])  # (B,H)
        upd = dtt[..., None, None] * xt[..., :, None] * bt[..., None, :]  # (B,H,P,N)
        h_state = decay[..., None, None] * h_state + upd
        y = jnp.einsum("bhpn,bhn->bhp", h_state, ct)
        return h_state, y

    h0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((b, h, p, n), dtype=jnp.float32)
    )
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Ch, 1, 0).astype(jnp.float32),
    )
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1) + D[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h_final
