"""Training driver: data pipeline -> train_step -> checkpoint, with
fault-tolerance (resume-from-latest, async checkpointing, step-time
watchdog for straggler detection, elastic re-mesh hook).

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
      --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt_mod
from repro.configs import get_config
from repro.data.pipeline import DataConfig, batch_at
from repro.launch.mesh import make_local_mesh, mesh_axes, mesh_counts
from repro.launch import shardings as sh
from repro.models import model as model_mod
from repro.models.model import MeshContext
from repro.training import optimizer as opt_mod
from repro.training import steps as steps_mod


class StepWatchdog:
    """Straggler detector: flags steps slower than `factor` × the trailing
    median (on real pods this triggers hot-spare swap / re-mesh)."""

    def __init__(self, factor: float = 3.0, window: int = 20):
        self.times = []
        self.factor = factor
        self.window = window
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:-1]
        if len(hist) >= 5 and dt > self.factor * float(np.median(hist)):
            self.flagged += 1
            return True
        return False


def train(
    arch: str, *, reduced: bool = True, steps: int = 20, seq_len: int = 128,
    global_batch: int = 4, ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
    use_mesh: bool = False, microbatches: int = 1, log_every: int = 5,
    seed: int = 0, lr: float = 3e-4,
):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = mi = None
    if use_mesh:
        mesh = make_local_mesh()
        batch_axes, model_axis = mesh_axes(mesh)
        nb, nm = mesh_counts(mesh)
        mi = MeshContext(mesh, batch_axes, model_axis, nm, nb)
    oc = opt_mod.AdamWConfig(lr=lr, total_steps=max(steps, 10),
                             warmup_steps=max(2, steps // 10))
    dc = DataConfig(seed=seed, seq_len=seq_len + 1, global_batch=global_batch)

    start = 0
    params = opt_state = None
    if ckpt_dir:
        latest = ckpt_mod.latest_step(ckpt_dir)
        if latest is not None:
            like_p = jax.eval_shape(lambda: model_mod.init_params(jax.random.key(seed), cfg))
            like_o = jax.eval_shape(opt_mod.init_opt_state, like_p)
            state = ckpt_mod.restore(ckpt_dir, latest,
                                     {"params": like_p, "opt": like_o})
            params, opt_state = state["params"], state["opt"]
            start = latest
            print(f"[train] resumed from step {latest}")
    if params is None:
        params = model_mod.init_params(jax.random.key(seed), cfg)
        opt_state = opt_mod.init_opt_state(params)

    step_fn = functools.partial(
        steps_mod.train_step, cfg=cfg, opt_cfg=oc, mesh_info=mi,
        microbatches=microbatches,
    )
    jit_step = jax.jit(step_fn)

    saver = ckpt_mod.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    wd = StepWatchdog()
    losses = []
    for step in range(start, steps):
        t0 = time.time()
        batch = {k: jax.numpy.asarray(v) for k, v in batch_at(cfg, dc, step).items()}
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        if wd.observe(dt):
            print(f"[watchdog] step {step} straggled ({dt:.2f}s)")
        if log_every and step % log_every == 0:
            print(f"[train] step={step} loss={loss:.4f} gnorm={float(metrics['grad_norm']):.3f} dt={dt:.2f}s")
        if saver and ckpt_every and (step + 1) % ckpt_every == 0:
            saver.save({"params": params, "opt": opt_state}, step + 1,
                       extra={"arch": arch, "loss": loss})
    if saver:
        saver.save({"params": params, "opt": opt_state}, steps,
                   extra={"arch": arch, "loss": losses[-1]})
        saver.wait()
    return params, opt_state, losses


import os  # noqa: E402  (used in resume path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--use-mesh", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    _, _, losses = train(
        args.arch, reduced=args.reduced, steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, use_mesh=args.use_mesh,
        microbatches=args.microbatches,
    )
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
