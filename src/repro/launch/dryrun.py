import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

__doc__ = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the sharding config is coherent on the production
mesh (16×16 single-pod / 2×16×16 multi-pod) and extracts the roofline
inputs: memory_analysis, cost_analysis, and the HLO-derived FLOPs / HBM
traffic / collective bytes (see launch/hlo.py — XLA's flat cost analysis
does not scale while-loop bodies, ours does).

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out-dir results/dryrun
"""

import argparse
import functools
import json
import math
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES_BY_NAME, cell_supported, get_config
from repro.launch import hlo as hlo_mod
from repro.launch import roofline as roofline_mod
from repro.launch import shardings as sh
from repro.launch.input_specs import cache_structs, input_specs, opt_structs, param_structs
from repro.launch.mesh import make_production_mesh, mesh_axes, mesh_counts
from repro.models.model import MeshContext
from repro.training import optimizer as opt_mod
from repro.training import steps

# v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s/link


def _shard_bytes(struct, spec: P, mesh) -> int:
    """Per-device bytes of one array under a PartitionSpec."""
    n = struct.dtype.itemsize
    for i, d in enumerate(struct.shape):
        parts = 1
        if i < len(spec) and spec[i] is not None:
            axes = spec[i] if isinstance(spec[i], tuple) else (spec[i],)
            for a in axes:
                parts *= mesh.shape[a]
        n *= math.ceil(d / parts)
    return n


def tree_device_bytes(structs, specs, mesh) -> int:
    total = [0]

    def acc(s, sp):
        total[0] += _shard_bytes(s, sp, mesh)

    jax.tree.map(acc, structs, specs, is_leaf=lambda x: isinstance(x, P))
    return total[0]


def build_cell(arch: str, shape_name: str, mesh, *, zero_opt: bool = False,
               extra: Optional[Dict[str, Any]] = None,
               overrides: Optional[Dict[str, Any]] = None,
               fsdp: bool = False):
    """Returns (jitted_fn, arg_structs_tuple, meta) for one cell."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"unsupported cell: {why}")
    batch_axes, model_axis = mesh_axes(mesh)
    nb, nm = mesh_counts(mesh)
    mi = MeshContext(mesh, batch_axes, model_axis, nm, nb)
    pspecs = sh.fsdp_param_specs(cfg, mesh) if fsdp else sh.param_specs(cfg, mesh)
    p_structs = param_structs(cfg)
    ns = functools.partial(sh.to_named, mesh=mesh)
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind}

    if shape.kind == "train":
        if fsdp:
            ospecs = {"m": pspecs, "v": pspecs, "step": P()}
            bspecs = sh.fsdp_batch_specs(cfg, mesh, "train", shape.global_batch)
        else:
            ospecs = sh.opt_specs(cfg, mesh, zero=zero_opt)
            bspecs = sh.batch_specs(cfg, mesh, "train")
        o_structs = opt_structs(cfg)
        b_structs = input_specs(cfg, shape)["batch"]
        oc = opt_mod.AdamWConfig()
        fn = functools.partial(steps.train_step, cfg=cfg, opt_cfg=oc, mesh_info=mi)
        jitted = jax.jit(
            fn,
            in_shardings=(ns(pspecs), ns(ospecs), ns(bspecs)),
            out_shardings=(ns(pspecs), ns(ospecs), None),
        )
        args = (p_structs, o_structs, b_structs)
        meta["param_bytes_per_device"] = tree_device_bytes(p_structs, pspecs, mesh)
        meta["state_bytes_per_device"] = (
            meta["param_bytes_per_device"] + tree_device_bytes(o_structs, ospecs, mesh)
        )
        meta["batch_bytes_per_device"] = tree_device_bytes(b_structs, bspecs, mesh)
    elif shape.kind == "prefill":
        bspecs = sh.batch_specs(cfg, mesh, "prefill")
        b_structs = input_specs(cfg, shape)["batch"]
        cspecs = sh.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        fn = functools.partial(
            steps.prefill_step, cfg=cfg, max_len=shape.seq_len, mesh_info=mi
        )
        jitted = jax.jit(
            fn,
            in_shardings=(ns(pspecs), ns(bspecs)),
            out_shardings=(None, ns(cspecs)),
        )
        args = (p_structs, b_structs)
        meta["param_bytes_per_device"] = tree_device_bytes(p_structs, pspecs, mesh)
        meta["state_bytes_per_device"] = meta["param_bytes_per_device"]
        c_structs = cache_structs(cfg, shape.global_batch, shape.seq_len)
        meta["cache_bytes_per_device"] = tree_device_bytes(c_structs, cspecs, mesh)
    else:  # decode
        ispec = input_specs(cfg, shape)
        cspecs = sh.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        tok_spec = sh.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)["lengths"]
        fn = functools.partial(steps.serve_step, cfg=cfg, mesh_info=mi)
        jitted = jax.jit(
            fn,
            in_shardings=(ns(pspecs), ns(cspecs), NamedSharding(mesh, tok_spec)),
            out_shardings=(None, None, ns(cspecs)),
        )
        args = (p_structs, ispec["cache"], ispec["tokens"])
        meta["param_bytes_per_device"] = tree_device_bytes(p_structs, pspecs, mesh)
        meta["state_bytes_per_device"] = meta["param_bytes_per_device"]
        meta["cache_bytes_per_device"] = tree_device_bytes(ispec["cache"], cspecs, mesh)
    if extra:
        meta.update(extra)
    return jitted, args, meta


def model_flops(cfg, shape) -> float:
    """Analytic 'useful' FLOPs for the cell (6·N·D train, 2·N·B decode)."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Optional[str],
             zero_opt: bool = False, overrides: Optional[Dict[str, Any]] = None,
             variant: str = "", fsdp: bool = False) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    t0 = time.time()
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "devices": int(n_dev),
        "variant": variant,
    }
    try:
        jitted, args, meta = build_cell(arch, shape_name, mesh, zero_opt=zero_opt,
                                        overrides=overrides, fsdp=fsdp)
        lowered = jitted.lower(*args)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        stats = hlo_mod.analyze(compiled.as_text())
        cfg = get_config(arch)
        shape = SHAPES_BY_NAME[shape_name]
        mf = model_flops(cfg, shape)
        perdev_flops = stats["flops"]
        record.update(meta)
        from repro.launch.mesh import mesh_counts as _mc
        nb, nm = _mc(mesh)
        traffic = roofline_mod.traffic_model(
            cfg, shape, nb, nm,
            meta.get("param_bytes_per_device", 0),
            meta.get("cache_bytes_per_device", 0),
        )
        record.update(
            status="ok",
            lower_s=round(t_lower - t0, 2),
            compile_s=round(t_compile - t_lower, 2),
            memory_analysis={
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            },
            xla_cost={k: cost.get(k) for k in ("flops", "bytes accessed") if k in cost},
            hlo_flops_per_device=perdev_flops,
            hlo_bytes_per_device=stats["bytes"],   # diagnostic only (CPU f32-legalized)
            analytic_bytes_per_device=traffic["total"],
            traffic_breakdown={k: v for k, v in traffic.items() if k != "total"},
            collective_bytes_per_device=stats["collective_bytes"],
            collectives=stats["collectives"],
            model_flops=mf,
            compute_term_s=perdev_flops / PEAK_FLOPS,
            memory_term_s=traffic["total"] / HBM_BW,
            collective_term_s=stats["collective_bytes"] / ICI_BW,
            useful_flops_ratio=(mf / (perdev_flops * n_dev)) if perdev_flops else 0.0,
        )
        terms = {
            "compute": record["compute_term_s"],
            "memory": record["memory_term_s"],
            "collective": record["collective_term_s"],
        }
        record["bottleneck"] = max(terms, key=terms.get)
        record["roofline_fraction"] = (
            max(terms.values()) and record["compute_term_s"] / max(terms.values())
        )
    except Exception as e:
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"__{variant}" if variant else ""
        path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}{tag}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=2, default=str)
    return record


def iter_cells():
    for arch, cfg in ARCHS.items():
        for shape_name, shape in SHAPES_BY_NAME.items():
            ok, why = cell_supported(cfg, shape)
            yield arch, shape_name, ok, why


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--zero-opt", action="store_true")
    ap.add_argument("--head-pad", type=int, default=0)
    ap.add_argument("--sharded-decode", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--variant", default="")
    args = ap.parse_args()
    overrides = {}
    if args.head_pad:
        overrides["head_pad_multiple"] = args.head_pad
    if args.sharded_decode:
        overrides["sharded_decode_attn"] = True
    if args.fsdp:
        overrides["fsdp_act_constraint"] = True
    if args.kv_int8:
        overrides["kv_cache_dtype"] = "int8"
    if args.no_remat:
        overrides["remat"] = False
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    cells = []
    if args.all:
        for arch, shape_name, ok, why in iter_cells():
            if ok:
                cells.append((arch, shape_name))
            else:
                print(f"SKIP {arch} {shape_name}: {why}")
    else:
        cells.append((args.arch, args.shape))

    for arch, shape_name in cells:
        for mk in meshes:
            rec = run_cell(arch, shape_name, mk, args.out_dir, zero_opt=args.zero_opt,
                           overrides=overrides, variant=args.variant, fsdp=args.fsdp)
            if rec["status"] == "ok":
                print(
                    f"OK {arch} {shape_name} {mk}: compile={rec['compile_s']}s "
                    f"compute={rec['compute_term_s']:.3f}s mem={rec['memory_term_s']:.3f}s "
                    f"coll={rec['collective_term_s']:.3f}s bottleneck={rec['bottleneck']}"
                )
            else:
                print(f"FAIL {arch} {shape_name} {mk}: {rec['error']}")


if __name__ == "__main__":
    main()
