"""Analytic per-device HBM-traffic model for the roofline memory term.

Why analytic: the CPU backend legalizes bf16 to f32 (a convert storm and 2×
buffer sizes that do not exist on TPU), so HLO-parsed byte traffic from the
CPU-compiled module overstates the TPU memory term by >10×.  The compute
and collective terms come from the compiled HLO (dtype-independent dot
FLOPs; explicit collective ops); the memory term comes from this model.
The HLO-parsed bytes are still recorded as a diagnostic.

Traffic model (per device, bytes):
  train:
    weights      3·P                  (fwd + remat-refwd + bwd reads)
    optimizer    13·P                 (grad w/r fp32, m/v r+w fp32, param w)
    activations  24·L·H_act           (fwd 8 r/w + bwd/refwd 16; H_act =
                                       B_loc·S·D·2B; MoE adds dispatch bufs)
    attention    L·(S/block_q)·KV_loc·S·hd·2·2   (flash KV re-streaming)
    head         4·B_loc·S·V_loc·4    (logits fp32 r/w in xent + bwd)
  prefill: weights P + activations 8·L·H_act + attention stream + cache write
  decode:  weights P + cache read (the step streams the whole cache) +
           cache write (1 token) + activations (S=1) + logits
All arrays are the per-device shards (already divided by mesh extents).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig

BLOCK_Q = 128  # flash attention q-block used for KV re-stream accounting


def _div(n: int, k: int) -> float:
    return n / k if k else n


def traffic_model(
    cfg: ModelConfig,
    shape: ShapeConfig,
    n_batch: int,
    n_model: int,
    param_bytes: int,
    cache_bytes: int = 0,
) -> Dict[str, float]:
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, cfg.n_layers
    b_loc = max(1.0, _div(B, n_batch))
    v_loc = _div(cfg.padded_vocab, n_model)
    kv_loc = max(1.0, _div(cfg.n_kv_heads, n_model)) if cfg.n_kv_heads else 0.0
    hd = cfg.resolved_head_dim
    h_act = b_loc * S * D * 2.0

    out: Dict[str, float] = {}
    if shape.kind == "train":
        out["weights"] = 3.0 * param_bytes
        out["optimizer"] = 13.0 * param_bytes
        act = 24.0 * L * h_act
        if cfg.moe is not None:
            act += 6.0 * L * (cfg.moe.top_k * cfg.moe.capacity_factor) * h_act
        out["activations"] = act
        if not cfg.is_attention_free:
            n_attn = L if not cfg.attn_every else cfg.n_layers // cfg.attn_every
            window = min(cfg.sliding_window or S, S)
            out["attention_stream"] = (
                n_attn * (S / BLOCK_Q) * b_loc * kv_loc * min(window, S) * hd * 2.0 * 2.0
            )
        out["head"] = 4.0 * b_loc * S * v_loc * 4.0
    elif shape.kind == "prefill":
        out["weights"] = 1.0 * param_bytes
        out["activations"] = 8.0 * L * h_act
        if not cfg.is_attention_free:
            n_attn = L if not cfg.attn_every else cfg.n_layers // cfg.attn_every
            window = min(cfg.sliding_window or S, S)
            out["attention_stream"] = (
                n_attn * (S / BLOCK_Q) * b_loc * kv_loc * min(window, S) * hd * 2.0 * 2.0
            )
        out["cache_write"] = float(cache_bytes)
        out["head"] = 2.0 * b_loc * v_loc * 4.0
    else:  # decode
        out["weights"] = 1.0 * param_bytes
        out["cache_read"] = float(cache_bytes)
        out["cache_write"] = 2.0 * b_loc * (kv_loc * hd * 2.0) * (
            L if cfg.family in ("dense", "vlm", "audio", "moe") else
            (cfg.n_layers // cfg.attn_every if cfg.attn_every else 0)
        )
        out["activations"] = 8.0 * L * b_loc * D * 2.0
        out["head"] = 2.0 * b_loc * v_loc * 4.0
    out["total"] = sum(out.values())
    return out
