"""ShapeDtypeStruct stand-ins for every model input (no device allocation)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as model_mod
from repro.training import optimizer as opt_mod


def param_structs(cfg: ModelConfig):
    return jax.eval_shape(lambda: model_mod.init_params(jax.random.key(0), cfg))


def opt_structs(cfg: ModelConfig):
    params = param_structs(cfg)
    return jax.eval_shape(opt_mod.init_opt_state, params)


def cache_structs(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        functools.partial(model_mod.init_cache, cfg, batch, max_len)
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Returns the kwargs-tree of ShapeDtypeStructs for the step function of
    this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    if shape.kind == "train":
        if cfg.frontend == "tokens":
            batch = {
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        else:
            batch = {
                "embeds": jax.ShapeDtypeStruct((B, S, D), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
        return {"batch": batch}
    if shape.kind == "prefill":
        if cfg.frontend == "tokens":
            return {"batch": {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}}
        return {"batch": {"embeds": jax.ShapeDtypeStruct((B, S, D), jnp.bfloat16)}}
    if shape.kind == "decode":
        return {
            "cache": cache_structs(cfg, B, S),
            "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        }
    raise ValueError(shape.kind)
