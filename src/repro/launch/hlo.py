"""Optimized-HLO text analyzer: FLOPs / HBM traffic / collective bytes.

Why not ``compiled.cost_analysis()``: XLA's flat cost analysis does NOT
multiply while-loop bodies by their trip count, and our models are
scan-over-layers — a single-body count would undercount an 80-layer model
by 80×.  This analyzer parses ``compiled.as_text()`` (post-SPMD, so shapes
are per-device shards and cross-device collectives are explicit HLO ops),
propagates multiplicities through the call graph using the
``known_trip_count`` backend_config on while ops, and accumulates:

  * ``flops``        — 2·M·N·K for every ``dot`` (MXU FLOPs; elementwise
                        ignored, consistent with MXU-roofline accounting)
  * ``bytes``        — HBM traffic model: Σ (operand + result bytes) over
                        top-level ops, skipping fusion-internal ops,
                        parameters/constants/tuple plumbing
  * ``collective_bytes`` — Σ operand bytes of all-reduce / all-gather /
                        reduce-scatter / all-to-all / collective-permute
                        (per-device; ×n_devices gives the fleet total)
  * per-collective detail (opcode, bytes, replica-group size, count)

All values are per-device (SPMD module = one device's program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\("
)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_CALLED_RE = re.compile(r"(?:body|condition|calls|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r"known_trip_count[^}0-9]*(\d+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_elems(type_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclass
class OpInfo:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[OpInfo] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)   # op name -> type str


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.startswith(" ") and _COMP_RE.match(line):
            m = _COMP_RE.match(line)
            cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.group(1), m.group(2), m.group(3)
        # operand segment: inside the first (...) after opcode
        rest = line[m.end():]
        depth = 1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str, attrs = rest[:i], rest[i + 1:]
        operands = _OPERAND_RE.findall(operand_str)
        cur.ops.append(OpInfo(name, type_str, opcode, operands, attrs))
        cur.symbols[name] = type_str
    return comps


_SKIP_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    out = shape_elems(op.type_str)
    n_out = 1
    for d in out:
        n_out *= d
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    if not mm or not op.operands:
        return 0.0
    lhs_type = comp.symbols.get(op.operands[0])
    if lhs_type is None:
        return 0.0
    lhs = shape_elems(lhs_type)
    k = 1
    if mm.group(1):
        for d in mm.group(1).split(","):
            k *= lhs[int(d)]
    return 2.0 * n_out * k


def _group_size(attrs: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return 0


def analyze(text: str) -> Dict[str, object]:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # multiplicity propagation + fusion-body marking
    mult: Dict[str, float] = defaultdict(float)
    fusion_body: Dict[str, bool] = defaultdict(bool)
    stack = [(entry.name, 1.0)]
    seen_edges = set()
    while stack:
        cname, m = stack.pop()
        mult[cname] += m
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            called = list(_CALLED_RE.findall(op.attrs))
            for grp in _BRANCHES_RE.findall(op.attrs):
                called.extend(g.strip().lstrip("%") for g in grp.split(",") if g.strip())
            if not called:
                continue
            scale = 1.0
            if op.opcode == "while":
                tm = _TRIP_RE.search(op.attrs)
                scale = float(tm.group(1)) if tm else 1.0
            for cal in called:
                if op.opcode == "fusion" or op.opcode in ("reduce", "scatter", "sort",
                                                          "reduce-window", "select-and-scatter",
                                                          "all-reduce", "reduce-scatter"):
                    fusion_body[cal] = True
                edge = (cname, op.name, cal)
                if edge in seen_edges:
                    continue
                seen_edges.add(edge)
                stack.append((cal, m * scale))

    flops = 0.0
    bytes_traffic = 0.0
    coll_bytes = 0.0
    coll_detail: Dict[str, Dict[str, float]] = defaultdict(lambda: {"bytes": 0.0, "count": 0.0})
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        body_only = fusion_body.get(cname, False)
        for op in comp.ops:
            if op.opcode == "dot":
                flops += m * _dot_flops(op, comp)
            if body_only:
                continue
            if op.opcode in _SKIP_TRAFFIC:
                continue
            op_bytes = shape_bytes(op.type_str) + sum(
                shape_bytes(comp.symbols.get(o, "")) for o in op.operands
            )
            bytes_traffic += m * op_bytes
            if op.opcode in COLLECTIVES or any(op.opcode == c + "-start" for c in COLLECTIVES):
                # transmitted bytes ≈ max(operand, result): all-reduce/
                # reduce-scatter/all-to-all move ~operand bytes, all-gather
                # moves ~result bytes — counting the max keeps AR-based and
                # AG-based (FSDP) shardings comparable.
                opnd = sum(shape_bytes(comp.symbols.get(o, "")) for o in op.operands)
                opnd = max(opnd, shape_bytes(op.type_str))
                # bf16-normalization: the CPU backend legalizes bf16 to f32,
                # so f32 collectives here would be bf16 on TPU (params,
                # grads, and activations are all bf16 in our dtype policy).
                norm = opnd
                if "f32[" in op.type_str and "f64" not in op.type_str:
                    norm = opnd / 2.0
                base = op.opcode.replace("-start", "")
                coll_bytes += m * norm
                coll_detail[base]["bytes"] += m * norm
                coll_detail[base]["bytes_raw"] = coll_detail[base].get("bytes_raw", 0.0) + m * opnd
                coll_detail[base]["count"] += m
                g = _group_size(op.attrs)
                coll_detail[base]["group"] = float(g)
    return {
        "flops": flops,
        "bytes": bytes_traffic,
        "collective_bytes": coll_bytes,
        "collectives": {k: dict(v) for k, v in coll_detail.items()},
    }
