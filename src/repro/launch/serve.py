"""Serving driver: B-PASTE speculative agent serving on the batched engine.

Runs a reduced model on CPU end-to-end: an agent loop whose reasoning steps
decode on the ServingEngine while tool calls run on the host; B-PASTE
speculates future branches into free batch slots.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --episodes 3
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.patterns import PatternEngine
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.spec_serving import SlotSpeculator, render_observation


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--episodes", type=int, default=3)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=160)
    ap.add_argument("--spec-slots", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = model_mod.init_params(jax.random.key(0), cfg)
    engine = ServingEngine(cfg, params, max_batch=args.max_batch, max_len=args.max_len)
    spec = SlotSpeculator(engine, budget_slots=args.spec_slots)

    # mine patterns offline
    train_eps = make_episodes(WorkloadConfig(seed=1, n_episodes=40))
    pe = PatternEngine(context_len=2, min_support=3).fit(episodes_to_traces(train_eps))

    eps = make_episodes(WorkloadConfig(seed=9, n_episodes=args.episodes))
    t0 = time.time()
    total_steps = 0
    for ep in eps:
        prompt = [2, 3, 4]
        slot = engine.add_request(prompt, request_id=ep.eid)
        # decode a few reasoning tokens per agent step; tools interleave
        for step in ep.steps[: 4]:
            for _ in range(6):
                out = engine.step()
                total_steps += 1
            obs = render_observation(step.tool, step.args, "auth", cfg.vocab_size)
            promoted = spec.match_and_promote(obs, ep.eid)
            if promoted is None and engine.slack() > 0:
                pass  # authoritative continues in its own slot
        for s in engine.slots:
            if s.request_id == ep.eid:
                s.active = False
                s.request_id = None
    dt = time.time() - t0
    print(f"served {args.episodes} episodes, {total_steps} decode steps in {dt:.1f}s "
          f"({total_steps/max(dt,1e-9):.1f} steps/s), "
          f"promotions={spec.promotions} preemptions={spec.preemptions}")


if __name__ == "__main__":
    main()
