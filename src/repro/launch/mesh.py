"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The single-pod mesh is a
16×16 = 256-chip pod (v5e-class); the multi-pod mesh stacks 2 pods on a
leading ``pod`` (DCN) axis = 512 chips.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_local_mesh(model_parallel: Optional[int] = None):
    """Mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    mp = model_parallel or 1
    assert n % mp == 0
    return compat.make_mesh((n // mp, mp), ("data", "model"))


def mesh_axes(mesh) -> Tuple[Tuple[str, ...], str]:
    """(batch_axes, model_axis) for a production-style mesh."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"


def mesh_counts(mesh) -> Tuple[int, int]:
    """(n_batch, n_model)."""
    batch_axes, model_axis = mesh_axes(mesh)
    nb = 1
    for a in batch_axes:
        nb *= mesh.shape[a]
    return nb, mesh.shape[model_axis]
