"""PartitionSpec trees for params / optimizer state / batches / caches.

Baseline layout (per DESIGN.md §7):
  * batch dims over ("pod","data") [training] or ("data",) [serving]
  * TP over "model": attention head-projections, MLP d_ff, vocab,
    SSM heads (d_inner / nh), MoE experts (EP) or expert-d_ff (TP).
  * a dim is sharded only when exactly divisible (GSPMD rejects
    shard_count > dim; uneven padding is avoided for cleanliness).
  * KV caches: batch over data; kv-heads over model when divisible,
    else the sequence axis when divisible (else replicated).

``zero_shard_opt`` additionally shards AdamW m/v over the batch axes
(ZeRO-1 style) — a hillclimb lever for the large archs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as model_mod


def _maybe(axis, dim: int, n: int):
    return axis if (n > 1 and dim % n == 0 and dim >= n) else None


def attn_specs(cfg: ModelConfig, model_axis: str, nm: int, stacked: bool) -> Dict[str, Any]:
    H, KV, hd = cfg.eff_n_heads, cfg.eff_n_kv_heads, cfg.resolved_head_dim
    m_q = _maybe(model_axis, H * hd, nm)
    m_kv = _maybe(model_axis, KV * hd, nm)
    L = (None,) if stacked else ()
    s = {
        "wq": P(*L, None, m_q),
        "wk": P(*L, None, m_kv),
        "wv": P(*L, None, m_kv),
        "wo": P(*L, m_q, None),
    }
    if cfg.qkv_bias:
        s["bq"] = P(*L, m_q)
        s["bk"] = P(*L, m_kv)
        s["bv"] = P(*L, m_kv)
    return s


def ssm_specs(cfg: ModelConfig, model_axis: str, nm: int) -> Dict[str, Any]:
    ss = cfg.ssm
    di = ss.d_inner(cfg.d_model)
    nh = ss.n_heads(cfg.d_model)
    gn = ss.n_groups * ss.d_state
    m_di = _maybe(model_axis, di, nm)
    m_nh = _maybe(model_axis, nh, nm)
    return {
        "w_z": P(None, None, m_di),
        "w_x": P(None, None, m_di),
        "w_B": P(None, None, None),
        "w_C": P(None, None, None),
        "w_dt": P(None, None, m_nh),
        "conv_x_w": P(None, None, m_di),
        "conv_x_b": P(None, m_di),
        "conv_B_w": P(None, None, None),
        "conv_B_b": P(None, None),
        "conv_C_w": P(None, None, None),
        "conv_C_b": P(None, None),
        "A_log": P(None, m_nh),
        "D": P(None, m_nh),
        "dt_bias": P(None, m_nh),
        "norm_w": P(None, m_di),
        "out_proj": P(None, m_di, None),
    }


def param_specs(cfg: ModelConfig, mesh) -> Dict[str, Any]:
    from repro.launch.mesh import mesh_axes, mesh_counts

    batch_axes, model_axis = mesh_axes(mesh)
    nb, nm = mesh_counts(mesh)
    Vp, D, F = cfg.padded_vocab, cfg.d_model, cfg.d_ff
    m_v = _maybe(model_axis, Vp, nm)
    specs: Dict[str, Any] = {
        "embed": P(m_v, None),
        "final_norm": P(None),
        "lm_head": P(None, m_v),
    }
    if cfg.frontend != "tokens":
        specs["frontend_proj"] = P(None, None)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        blocks: Dict[str, Any] = {
            "ln1": P(None, None),
            "ln2": P(None, None),
            "attn": attn_specs(cfg, model_axis, nm, stacked=True),
        }
        if cfg.family == "moe":
            E, Fe = cfg.moe.n_experts, cfg.moe.d_ff_expert
            if E % nm == 0:
                blocks["moe"] = {
                    "router": P(None, None, None),
                    "w_gate": P(None, model_axis, None, None),
                    "w_up": P(None, model_axis, None, None),
                    "w_down": P(None, model_axis, None, None),
                }
            else:
                m_f = _maybe(model_axis, Fe, nm)
                blocks["moe"] = {
                    "router": P(None, None, None),
                    "w_gate": P(None, None, None, m_f),
                    "w_up": P(None, None, None, m_f),
                    "w_down": P(None, None, m_f, None),
                }
        else:
            m_f = _maybe(model_axis, F, nm)
            blocks["mlp"] = {
                "w_gate": P(None, None, m_f),
                "w_up": P(None, None, m_f),
                "w_down": P(None, m_f, None),
            }
        specs["blocks"] = blocks
    elif cfg.family == "ssm":
        specs["blocks"] = {"ln": P(None, None), "ssm": ssm_specs(cfg, model_axis, nm)}
    elif cfg.family == "hybrid":
        specs["blocks"] = {"ln": P(None, None), "ssm": ssm_specs(cfg, model_axis, nm)}
        specs["shared_attn"] = {
            "ln": P(None),
            "attn": attn_specs(cfg, model_axis, nm, stacked=False),
        }
    return specs


def opt_specs(cfg: ModelConfig, mesh, *, zero: bool = False) -> Dict[str, Any]:
    """AdamW state specs.  zero=True also shards m/v over the batch axes on
    the largest (first shardable) unsharded dim (ZeRO-1-style)."""
    from repro.launch.mesh import mesh_axes, mesh_counts

    pspecs = param_specs(cfg, mesh)
    if not zero:
        mv = pspecs
    else:
        batch_axes, _ = mesh_axes(mesh)
        nb, _ = mesh_counts(mesh)

        def zero_one(spec: P):
            # leading L axis (index 0 for stacked) stays; try to add batch
            # axes on the first None dim — divisibility is checked at use
            # site via eval_shape, so here we only transform the spec tree.
            parts = list(spec)
            for i, p in enumerate(parts):
                if i == 0:
                    continue  # keep L / leading dim for scan slicing
                if p is None:
                    parts[i] = batch_axes
                    break
            return P(*parts)

        mv = jax.tree.map(zero_one, pspecs, is_leaf=lambda x: isinstance(x, P))
    return {"m": mv, "v": mv, "step": P()}


def batch_specs(cfg: ModelConfig, mesh, kind: str) -> Dict[str, Any]:
    from repro.launch.mesh import mesh_axes

    batch_axes, _ = mesh_axes(mesh)
    ba = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    if kind == "train":
        if cfg.frontend == "tokens":
            return {"tokens": P(ba, None), "labels": P(ba, None)}
        return {"embeds": P(ba, None, None), "labels": P(ba, None)}
    if kind == "prefill":
        if cfg.frontend == "tokens":
            return {"tokens": P(ba, None)}
        return {"embeds": P(ba, None, None)}
    raise ValueError(kind)


def cache_specs(cfg: ModelConfig, mesh, batch: int, max_len: int) -> Dict[str, Any]:
    from repro.launch.mesh import mesh_axes, mesh_counts

    batch_axes, model_axis = mesh_axes(mesh)
    nb, nm = mesh_counts(mesh)
    ba = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    b_ax = ba if (batch % nb == 0 and batch >= nb) else None
    KV = cfg.eff_n_kv_heads
    smax = model_mod._kv_smax(cfg, max_len)
    kv_ax, seq_ax = _maybe(model_axis, KV, nm), None
    if kv_ax is None:
        seq_ax = _maybe(model_axis, smax, nm)
    specs: Dict[str, Any] = {"lengths": P(b_ax)}
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        if cfg.kv_cache_dtype == "int8":
            specs["k"] = (P(None, b_ax, seq_ax, kv_ax, None),
                          P(None, b_ax, seq_ax, kv_ax))
            specs["v"] = (P(None, b_ax, seq_ax, kv_ax, None),
                          P(None, b_ax, seq_ax, kv_ax))
        else:
            specs["k"] = P(None, b_ax, seq_ax, kv_ax, None)
            specs["v"] = P(None, b_ax, seq_ax, kv_ax, None)
    if cfg.family in ("ssm", "hybrid"):
        ss = cfg.ssm
        di = ss.d_inner(cfg.d_model)
        nh = ss.n_heads(cfg.d_model)
        m_di = _maybe(model_axis, di, nm)
        m_nh = _maybe(model_axis, nh, nm)
        specs["ssm_state"] = (
            P(None, b_ax, None, m_di),   # conv_x
            P(None, b_ax, None, None),   # conv_B
            P(None, b_ax, None, None),   # conv_C
            P(None, b_ax, m_nh, None, None),  # ssm
        )
    if cfg.family == "hybrid":
        kv_ax2 = _maybe(model_axis, KV, nm)
        seq_ax2 = None if kv_ax2 is not None else _maybe(model_axis, max_len, nm)
        specs["k"] = P(None, b_ax, seq_ax2, kv_ax2, None)
        specs["v"] = P(None, b_ax, seq_ax2, kv_ax2, None)
    return specs


def to_named(tree, mesh):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def fsdp_param_specs(cfg: ModelConfig, mesh) -> Dict[str, Any]:
    """ZeRO-3/FSDP layout: every parameter fully sharded over ALL mesh axes
    on its largest divisible dim; batch also over all axes (1+ seq/chip).
    GSPMD then all-gathers params per layer and reduce-scatters grads —
    trading O(passes·P) gathers for the 6-per-layer activation all-reduces
    of 1D TP.  See EXPERIMENTS.md §Perf (qwen2-7b train_4k iteration 2)."""
    from repro.launch.input_specs import param_structs

    axes = tuple(mesh.axis_names)
    n_all = 1
    for a in axes:
        n_all *= mesh.shape[a]
    structs = param_structs(cfg)

    def spec_for(path_struct):
        shape = path_struct.shape
        # skip dim 0 for stacked block params (scan slices on it)
        start = 1 if len(shape) >= 2 else 0
        best = None
        for i in range(len(shape) - 1, start - 1, -1):
            if shape[i] % n_all == 0 and shape[i] >= n_all:
                best = i
                break
        parts = [None] * len(shape)
        if best is not None:
            parts[best] = axes
        return P(*parts)

    return jax.tree.map(spec_for, structs)


def fsdp_batch_axes(mesh, batch: int) -> tuple:
    """Largest suffix of mesh axes whose size product divides the batch
    (multi-pod: batch 256 < 512 chips -> shard over (data, model) only)."""
    axes = tuple(mesh.axis_names)
    for start in range(len(axes)):
        sub = axes[start:]
        n = 1
        for a in sub:
            n *= mesh.shape[a]
        if n and batch % n == 0 and batch >= n:
            return sub
    return axes[-1:]


def fsdp_batch_specs(cfg: ModelConfig, mesh, kind: str, batch: int) -> Dict[str, Any]:
    axes = fsdp_batch_axes(mesh, batch)
    if kind == "train":
        if cfg.frontend == "tokens":
            return {"tokens": P(axes, None), "labels": P(axes, None)}
        return {"embeds": P(axes, None, None), "labels": P(axes, None)}
    raise ValueError(kind)
