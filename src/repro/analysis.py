"""``python -m repro.analysis`` — the speculation-safety static analyzer CLI.

Runs the full static pass from :mod:`repro.core.analysis` against a real
configuration: the eligibility policy, the DEFAULT_TOOLS registry, a seeded
synthetic workload, the pattern tables mined from it, and — unlike the
runtime-constructor pass — commit-barrier placement (R4) on beams actually
assembled from that workload's trace prefixes.  CI runs this on every push
with the default policy/workload and fails on ANY finding; operators run it
against their own policy overrides before enabling speculation.

Exit status: 0 when the report is clean, 1 when it has findings (2 under
``--strict`` if any finding is an *error*, so pipelines can distinguish).

``--sanitize-smoke`` additionally executes a small seeded serving run with
``RuntimeConfig.sanitize=True`` and folds any runtime-sanitizer findings
(S1–S5) into the same report — a seconds-scale end-to-end cross-check of the
event scheduler's caches, dirty sets, and counter groups.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.analysis import AnalysisReport, analyze_static, exit_code
from repro.core.hypothesis import HypothesisBuilder
from repro.core.patterns import PatternEngine
from repro.core.runtime import BPasteRuntime, RuntimeConfig
from repro.core.safety import (
    FULL_POLICY,
    PREP_ONLY_POLICY,
    READ_ONLY_POLICY,
    EligibilityPolicy,
)
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes

POLICIES = {
    "full": FULL_POLICY,
    "read_only": READ_ONLY_POLICY,
    "prep_only": PREP_ONLY_POLICY,
}


def _build_beams(engine: PatternEngine, traces, max_hyps: int = 200):
    """Assemble beams from every trace prefix (the states the runtime would
    actually build at) until ``max_hyps`` hypotheses are collected — R4 wants
    REAL assembled trees, not synthetic fixtures."""
    builder = HypothesisBuilder(engine=engine)
    hyps = []
    for trace in traces:
        for cut in range(1, len(trace)):
            hyps.extend(builder.build(trace[:cut]))
            if len(hyps) >= max_hyps:
                return hyps
    return hyps


def _sanitize_smoke(policy: EligibilityPolicy, engine: PatternEngine,
                    report: AnalysisReport, seed: int) -> None:
    """Seconds-scale serving run with the runtime sanitizer on: S1–S5 checks
    fire on the sampled tick schedule, findings fold into ``report``."""
    eps = make_episodes(WorkloadConfig(
        seed=seed, n_episodes=8, arrival_stagger=2.0,
        shared_frac=0.5, shared_pool=2))
    rt = BPasteRuntime(
        eps, engine, policy=policy,
        rcfg=RuntimeConfig(seed=7, max_concurrent_episodes=4,
                           model_max_batch=4, sanitize=True,
                           sanitize_every=3, analysis="off"))
    rt.run()
    assert rt.sanitizer is not None
    report.extend(rt.sanitizer.report)
    report.meta["sanitize_smoke_ticks"] = rt.metrics.sched_ticks


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static speculation-safety analysis (rules R1-R4).")
    ap.add_argument("--policy", choices=sorted(POLICIES), default="full",
                    help="eligibility policy preset to analyze")
    ap.add_argument("--seed", type=int, default=1,
                    help="workload seed for mining + beam assembly")
    ap.add_argument("--episodes", type=int, default=20,
                    help="synthetic episodes to mine patterns from")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the report as JSON ('-' for stdout)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 2 when any finding is an error")
    ap.add_argument("--sanitize-smoke", action="store_true",
                    help="also run a small serving workload under "
                         "RuntimeConfig.sanitize=True (checks S1-S5)")
    args = ap.parse_args(argv)

    policy = POLICIES[args.policy]
    eps = make_episodes(WorkloadConfig(seed=args.seed,
                                       n_episodes=args.episodes))
    traces = episodes_to_traces(eps)
    engine = PatternEngine(context_len=2, min_support=3).fit(traces)
    hyps = _build_beams(engine, traces)

    report = analyze_static(policy, engine, hyps)
    if args.sanitize_smoke:
        _sanitize_smoke(policy, engine, report, args.seed)

    print(report.render())
    print(f"(policy={args.policy}, {len(engine.patterns)} patterns, "
          f"{report.meta.get('barrier_checked_hyps', 0)} beams checked, "
          f"{len(report.meta.get('write_conflicts', []))} may-overlap "
          f"write pairs)")
    if args.json:
        payload = json.dumps(report.to_json(), indent=2)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w") as fh:
                fh.write(payload + "\n")
    return exit_code(report, strict=args.strict)


if __name__ == "__main__":
    sys.exit(main())
