"""Top-level model: init / train-forward / prefill / decode for all families.

Design notes
------------
* **scan-over-layers**: block params are stacked along a leading L axis and
  the forward is a single `jax.lax.scan`, so XLA compiles one block body
  regardless of depth (critical for the 80×-cell dry-run matrix).
* **hybrid (zamba2)**: the backbone is G groups of `attn_every` Mamba2 layers
  followed by ONE shared attention block (shared weights, fresh KV per
  application) plus a tail of `n_layers % attn_every` Mamba2 layers.
* **frontend stubs** (vlm/audio): prefill/train consume precomputed
  patch/frame embeddings (B,S,D) through a learned adapter; decode consumes
  token ids through the LM embedding table (text / EnCodec codes).
* caches are dicts of stacked per-layer arrays so decode is also a scan.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import embed_init, rms_norm, split_keys

MeshContext = moe_mod.MoEMeshInfo  # (mesh, batch_axes, model_axis, n_model, n_batch)


# ======================================================================
# Init
# ======================================================================

def _stack_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    D, Vp, L = cfg.d_model, cfg.padded_vocab, cfg.n_layers
    ks = split_keys(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], (Vp, D), dtype=dtype),
        "final_norm": jnp.ones((D,), dtype),
        "lm_head": embed_init(ks[1], (D, Vp), dtype=dtype),
    }
    if cfg.frontend != "tokens":
        params["frontend_proj"] = embed_init(ks[7], (D, D), dtype=dtype)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def blk(k):
            k1, k2 = jax.random.split(k)
            b = {
                "ln1": jnp.ones((D,), dtype),
                "ln2": jnp.ones((D,), dtype),
                "attn": attn_mod.init_attn_params(k1, cfg, dtype),
            }
            if cfg.family == "moe":
                b["moe"] = moe_mod.init_moe_params(k2, cfg, dtype)
            else:
                b["mlp"] = mlp_mod.init_mlp_params(k2, cfg, dtype)
            return b

        params["blocks"] = _stack_init(blk, ks[2], L)
    elif cfg.family == "ssm":
        def blk(k):
            return {"ln": jnp.ones((D,), dtype), "ssm": ssm_mod.init_ssm_params(k, cfg, dtype)}

        params["blocks"] = _stack_init(blk, ks[2], L)
    elif cfg.family == "hybrid":
        def blk(k):
            return {"ln": jnp.ones((D,), dtype), "ssm": ssm_mod.init_ssm_params(k, cfg, dtype)}

        params["blocks"] = _stack_init(blk, ks[2], L)
        params["shared_attn"] = {
            "ln": jnp.ones((D,), dtype),
            "attn": attn_mod.init_attn_params(ks[3], cfg, dtype),
        }
    else:
        raise ValueError(cfg.family)
    return params


def hybrid_split(cfg: ModelConfig):
    """(n_groups, layers_per_group, n_tail)."""
    g = cfg.n_layers // cfg.attn_every
    return g, cfg.attn_every, cfg.n_layers - g * cfg.attn_every


def _tree_slice(tree, start, stop):
    return jax.tree.map(lambda a: a[start:stop], tree)


def _tree_reshape_groups(tree, g, k):
    return jax.tree.map(lambda a: a.reshape((g, k) + a.shape[1:]), tree)


# ======================================================================
# Embedding / head
# ======================================================================

def embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """batch has 'tokens' (B,S) int32 or 'embeds' (B,S,D)."""
    if "embeds" in batch:
        return batch["embeds"].astype(jnp.dtype(cfg.dtype)) @ params["frontend_proj"]
    return params["embed"][batch["tokens"]]


def lm_logits(params, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    """Final norm + LM head; padded vocab tail masked to -inf.  fp32 out."""
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (h @ params["lm_head"]).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


# ======================================================================
# Block bodies (full-sequence)
# ======================================================================

def _attn_block(blk, h, cfg, impl, mesh_info):
    h = h + attn_mod.attn_forward(blk["attn"], rms_norm(h, blk["ln1"], cfg.norm_eps), cfg, impl=impl)
    if cfg.family == "moe":
        y, aux = moe_mod.moe_forward(blk["moe"], rms_norm(h, blk["ln2"], cfg.norm_eps), cfg, mesh_info)
        return h + y, aux["lb_loss"]
    h = h + mlp_mod.mlp_forward(blk["mlp"], rms_norm(h, blk["ln2"], cfg.norm_eps))
    return h, jnp.float32(0.0)


def _ssm_block(blk, h, cfg, impl):
    return h + ssm_mod.ssm_forward(blk["ssm"], rms_norm(h, blk["ln"], cfg.norm_eps), cfg, impl=impl)


def _resolve_impl(cfg: ModelConfig) -> str:
    return cfg.attn_impl if cfg.attn_impl != "auto" else "auto"


# ======================================================================
# Train / full-sequence forward
# ======================================================================

def _act_constraint(h, cfg, mesh_info):
    """FSDP mode: pin activations batch-sharded over every mesh axis so the
    partitioner gathers weights instead of re-sharding activations."""
    if not (cfg.fsdp_act_constraint and mesh_info is not None):
        return h
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.shardings import fsdp_batch_axes
    axes = fsdp_batch_axes(mesh_info.mesh, h.shape[0])
    return jax.lax.with_sharding_constraint(
        h, NamedSharding(mesh_info.mesh, P(axes, None, None)))


def forward(
    params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
    mesh_info: Optional[MeshContext] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (logits (B,S,Vp) fp32, aux_loss scalar)."""
    impl = _resolve_impl(cfg)
    h = embed_inputs(params, cfg, batch)
    h = _act_constraint(h, cfg, mesh_info)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def body(carry, blk):
            h, lb = carry
            h = _act_constraint(h, cfg, mesh_info)
            h, lb_i = _attn_block(blk, h, cfg, impl, mesh_info)
            h = _act_constraint(h, cfg, mesh_info)
            return (h, lb + lb_i), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (h, lb), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), params["blocks"])
        return lm_logits(params, cfg, h), lb / cfg.n_layers

    if cfg.family == "ssm":
        def body(h, blk):
            h = _act_constraint(h, cfg, mesh_info)
            return _ssm_block(blk, h, cfg, impl), None

        if cfg.remat:
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["blocks"])
        return lm_logits(params, cfg, h), jnp.float32(0.0)

    # hybrid: groups of (attn_every mamba layers + shared attention) + tail
    g, kpg, tail = hybrid_split(cfg)
    shared = params["shared_attn"]

    def inner(h, blk):
        h = _act_constraint(h, cfg, mesh_info)
        return _ssm_block(blk, h, cfg, impl), None

    if cfg.remat:
        inner = jax.checkpoint(inner)

    main = _tree_reshape_groups(_tree_slice(params["blocks"], 0, g * kpg), g, kpg)

    def outer(h, grp_blocks):
        h, _ = jax.lax.scan(inner, h, grp_blocks)
        h = h + attn_mod.attn_forward(
            shared["attn"], rms_norm(h, shared["ln"], cfg.norm_eps), cfg, impl=impl
        )
        return h, None

    h, _ = jax.lax.scan(outer, h, main)
    if tail:
        tail_blocks = _tree_slice(params["blocks"], g * kpg, cfg.n_layers)
        h, _ = jax.lax.scan(inner, h, tail_blocks)
    return lm_logits(params, cfg, h), jnp.float32(0.0)


# ======================================================================
# Cache init / prefill / decode
# ======================================================================

def _kv_smax(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    KV, hd = cfg.eff_n_kv_heads, cfg.resolved_head_dim
    cache: Dict[str, Any] = {"lengths": jnp.zeros((batch,), jnp.int32)}
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        smax = _kv_smax(cfg, max_len)
        if cfg.kv_cache_dtype == "int8":
            cache["k"] = (jnp.zeros((cfg.n_layers, batch, smax, KV, hd), jnp.int8),
                          jnp.zeros((cfg.n_layers, batch, smax, KV), jnp.float32))
            cache["v"] = (jnp.zeros((cfg.n_layers, batch, smax, KV, hd), jnp.int8),
                          jnp.zeros((cfg.n_layers, batch, smax, KV), jnp.float32))
        else:
            cache["k"] = jnp.zeros((cfg.n_layers, batch, smax, KV, hd), dtype)
            cache["v"] = jnp.zeros((cfg.n_layers, batch, smax, KV, hd), dtype)
    elif cfg.family == "ssm":
        st = ssm_mod.init_ssm_state(cfg, batch)
        cache["ssm_state"] = tuple(
            jnp.broadcast_to(a, (cfg.n_layers,) + a.shape) for a in st
        )
    elif cfg.family == "hybrid":
        g, kpg, tail = hybrid_split(cfg)
        st = ssm_mod.init_ssm_state(cfg, batch)
        cache["ssm_state"] = tuple(
            jnp.broadcast_to(a, (cfg.n_layers,) + a.shape) for a in st
        )
        cache["k"] = jnp.zeros((g, batch, max_len, KV, hd), dtype)
        cache["v"] = jnp.zeros((g, batch, max_len, KV, hd), dtype)
    return cache


def prefill(
    params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], max_len: int,
    mesh_info: Optional[MeshContext] = None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Run the prompt; returns (last-position logits (B,Vp), filled cache)."""
    impl = _resolve_impl(cfg)
    h = embed_inputs(params, cfg, batch)
    b, s, _ = h.shape
    cache: Dict[str, Any] = {"lengths": jnp.full((b,), s, jnp.int32)}

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        smax = _kv_smax(cfg, max_len)

        def body(carry, blk):
            h, lb = carry
            x = rms_norm(h, blk["ln1"], cfg.norm_eps)
            o, kc, vc = attn_mod.attn_prefill(blk["attn"], x, cfg, smax, impl=impl)
            h = h + o
            if cfg.family == "moe":
                y, aux = moe_mod.moe_forward(blk["moe"], rms_norm(h, blk["ln2"], cfg.norm_eps), cfg, mesh_info)
                h = h + y
                lb = lb + aux["lb_loss"]
            else:
                h = h + mlp_mod.mlp_forward(blk["mlp"], rms_norm(h, blk["ln2"], cfg.norm_eps))
            return (h, lb), (kc, vc)

        if cfg.remat:
            body = jax.checkpoint(body)
        (h, _), (kc, vc) = jax.lax.scan(body, (h, jnp.float32(0.0)), params["blocks"])
        cache["k"], cache["v"] = kc, vc
        return lm_logits(params, cfg, h[:, -1]), cache

    if cfg.family == "ssm":
        def body(h, blk):
            x = rms_norm(h, blk["ln"], cfg.norm_eps)
            o, st = ssm_mod.ssm_forward(blk["ssm"], x, cfg, impl=impl, return_state=True)
            return h + o, st

        if cfg.remat:
            body = jax.checkpoint(body)
        h, st = jax.lax.scan(body, h, params["blocks"])
        cache["ssm_state"] = st
        return lm_logits(params, cfg, h[:, -1]), cache

    # hybrid
    g, kpg, tail = hybrid_split(cfg)
    shared = params["shared_attn"]

    def inner(h, blk):
        x = rms_norm(h, blk["ln"], cfg.norm_eps)
        o, st = ssm_mod.ssm_forward(blk["ssm"], x, cfg, impl=impl, return_state=True)
        return h + o, st

    if cfg.remat:
        inner = jax.checkpoint(inner)
    main = _tree_reshape_groups(_tree_slice(params["blocks"], 0, g * kpg), g, kpg)

    def outer(h, grp_blocks):
        h, states = jax.lax.scan(inner, h, grp_blocks)
        x = rms_norm(h, shared["ln"], cfg.norm_eps)
        o, kc, vc = attn_mod.attn_prefill(shared["attn"], x, cfg, max_len, impl=impl)
        return h + o, (states, kc, vc)

    h, (main_states, kc, vc) = jax.lax.scan(outer, h, main)
    main_states = tuple(a.reshape((g * kpg,) + a.shape[2:]) for a in main_states)
    if tail:
        h, tail_states = jax.lax.scan(inner, h, _tree_slice(params["blocks"], g * kpg, cfg.n_layers))
        main_states = tuple(
            jnp.concatenate([m, t], axis=0) for m, t in zip(main_states, tail_states, strict=True)
        )
    cache["ssm_state"] = main_states
    cache["k"], cache["v"] = kc, vc
    return lm_logits(params, cfg, h[:, -1]), cache


def decode_step(
    params, cfg: ModelConfig, cache: Dict[str, Any], tokens: jnp.ndarray,
    mesh_info: Optional[MeshContext] = None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step.  tokens (B,) int32 -> (logits (B,Vp) fp32, new cache)."""
    impl = _resolve_impl(cfg)
    lengths = cache["lengths"]
    h = params["embed"][tokens][:, None, :]            # (B,1,D)
    new_cache: Dict[str, Any] = {"lengths": lengths + 1}

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def body(h, xs):
            blk, kc, vc = xs
            x = rms_norm(h, blk["ln1"], cfg.norm_eps)
            o, kc, vc = attn_mod.attn_decode_dispatch(
                blk["attn"], x, kc, vc, lengths, cfg, mesh_info, impl=impl)
            h = h + o
            if cfg.family == "moe":
                y, _ = moe_mod.moe_forward(blk["moe"], rms_norm(h, blk["ln2"], cfg.norm_eps), cfg, mesh_info)
                h = h + y
            else:
                h = h + mlp_mod.mlp_forward(blk["mlp"], rms_norm(h, blk["ln2"], cfg.norm_eps))
            return h, (kc, vc)

        h, (kc, vc) = jax.lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = kc, vc
        return lm_logits(params, cfg, h[:, 0]), new_cache

    if cfg.family == "ssm":
        def body(h, xs):
            blk, st = xs
            x = rms_norm(h, blk["ln"], cfg.norm_eps)
            o, st = ssm_mod.ssm_decode(blk["ssm"], x, st, cfg)
            return h + o, st

        h, st = jax.lax.scan(body, h, (params["blocks"], cache["ssm_state"]))
        new_cache["ssm_state"] = st
        return lm_logits(params, cfg, h[:, 0]), new_cache

    # hybrid
    g, kpg, tail = hybrid_split(cfg)
    shared = params["shared_attn"]

    def inner(h, xs):
        blk, st = xs
        x = rms_norm(h, blk["ln"], cfg.norm_eps)
        o, st = ssm_mod.ssm_decode(blk["ssm"], x, st, cfg)
        return h + o, st

    main_blocks = _tree_reshape_groups(_tree_slice(params["blocks"], 0, g * kpg), g, kpg)
    main_st = tuple(
        a[: g * kpg].reshape((g, kpg) + a.shape[1:]) for a in cache["ssm_state"]
    )

    def outer(h, xs):
        grp_blocks, st_g, kc, vc = xs
        h, st_g = jax.lax.scan(inner, h, (grp_blocks, st_g))
        x = rms_norm(h, shared["ln"], cfg.norm_eps)
        o, kc, vc = attn_mod.attn_decode_dispatch(
            shared["attn"], x, kc, vc, lengths, cfg, mesh_info, impl=impl)
        return h + o, (st_g, kc, vc)

    h, (st_g, kc, vc) = jax.lax.scan(
        outer, h, (main_blocks, main_st, cache["k"], cache["v"])
    )
    new_st = tuple(a.reshape((g * kpg,) + a.shape[2:]) for a in st_g)
    if tail:
        h, st_t = jax.lax.scan(
            inner, h,
            (_tree_slice(params["blocks"], g * kpg, cfg.n_layers),
             tuple(a[g * kpg :] for a in cache["ssm_state"])),
        )
        new_st = tuple(jnp.concatenate([m, t], axis=0) for m, t in zip(new_st, st_t, strict=True))
    new_cache["ssm_state"] = new_st
    new_cache["k"], new_cache["v"] = kc, vc
    return lm_logits(params, cfg, h[:, 0]), new_cache
