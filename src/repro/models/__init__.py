from repro.models.model import (
    MeshContext,
    decode_step,
    embed_inputs,
    forward,
    hybrid_split,
    init_cache,
    init_params,
    lm_logits,
    prefill,
)
