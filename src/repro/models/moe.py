"""Mixture-of-Experts block.

Dispatch is sort-based (GShard-style group-local capacity, no (T,E,C) one-hot
tensors): tokens are routed to experts via a stable sort over expert ids,
positions within each expert come from segment arithmetic, and the dispatch /
combine are a scatter / gather pair.  Expert compute is a batched einsum with
the *active* FLOPs only (2·T·k·cf·D·F per matmul).

Distribution: executed inside ``jax.shard_map`` over the ``model`` mesh axis.
  * E % n_model == 0  -> expert parallelism (each shard owns E/n_model experts,
    computes partial token outputs, one psum over 'model' combines)
  * otherwise         -> expert tensor parallelism (experts replicated, d_ff
    sliced over 'model'; identical single psum)
Both lower to exactly one all-reduce of (B, S, D) per MoE layer — the same
collective shape as a TP MLP, which keeps the collective roofline clean.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.models.common import dense_init, split_keys


class MoEMeshInfo(NamedTuple):
    mesh: object                 # jax.sharding.Mesh
    batch_axes: tuple            # e.g. ('data',) or ('pod','data')
    model_axis: str              # 'model'
    n_model: int
    n_batch: int


def init_moe_params(key, cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    E, F = cfg.moe.n_experts, cfg.moe.d_ff_expert
    ks = split_keys(key, 4)
    return {
        "router": dense_init(ks[0], (D, E), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, D, F), in_axis=1, dtype=dtype),
        "w_up": dense_init(ks[2], (E, D, F), in_axis=1, dtype=dtype),
        "w_down": dense_init(ks[3], (E, F, D), in_axis=1, dtype=dtype),
    }


def _route(x2d: jnp.ndarray, router: jnp.ndarray, cfg: ModelConfig):
    """x2d (T, D) -> (gates (T,k) f32, experts (T,k) i32, router_probs (T,E))."""
    k = cfg.moe.top_k
    logits = (x2d.astype(jnp.float32) @ router).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, k)                      # (T,k)
    if cfg.moe.renorm_gate:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    return gate_vals, experts, probs


def _positions_in_expert(e_flat: jnp.ndarray, n_experts: int):
    """Stable-sort segment positions.  e_flat (M,) -> pos (M,) with pos[i] =
    rank of i among slots routed to the same expert (arrival order)."""
    m = e_flat.shape[0]
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    idx = jnp.arange(m)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    seg_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    pos_sorted = idx - seg_start
    pos = jnp.zeros((m,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    return pos


def _expert_ffn(xd: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    """xd (E, C, D) -> (E, C, D)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xd, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", xd, w_up
    )
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_local(p, x: jnp.ndarray, cfg: ModelConfig, n_local_experts: int,
               expert_offset: jnp.ndarray | int = 0):
    """Single-shard MoE over local experts [offset, offset+n_local).

    x (B, S, D) -> (partial y (B, S, D), aux metrics dict).  Tokens routed to
    non-local experts contribute zero (the cross-shard psum completes them).
    """
    b, s, d = x.shape
    k = cfg.moe.top_k
    E = cfg.moe.n_experts
    t = b * s
    x2d = x.reshape(t, d)
    gates, experts, probs = _route(x2d, p["router"], cfg)

    cap = max(1, int(math.ceil(t * k * cfg.moe.capacity_factor / E)))
    e_flat = experts.reshape(t * k)
    local = (e_flat >= expert_offset) & (e_flat < expert_offset + n_local_experts)
    e_local = jnp.where(local, e_flat - expert_offset, n_local_experts)  # overflow bin
    pos = _positions_in_expert(e_local, n_local_experts + 1)
    keep = local & (pos < cap)
    dump = n_local_experts * cap                       # scratch row for drops
    dest = jnp.where(keep, e_local * cap + pos, dump)

    tok_idx = jnp.arange(t * k) // k
    x_rep = x2d[tok_idx]                               # (T*k, D)
    disp = jnp.zeros((n_local_experts * cap + 1, d), x.dtype).at[dest].set(x_rep)
    xd = disp[: n_local_experts * cap].reshape(n_local_experts, cap, d)

    yd = _expert_ffn(xd, p["w_gate"], p["w_up"], p["w_down"])

    y_rep = yd.reshape(n_local_experts * cap, d)[jnp.minimum(dest, dump - 1)]
    y_rep = jnp.where(keep[:, None], y_rep, 0.0)
    w = (gates.reshape(t * k) * keep).astype(jnp.float32)
    y = jnp.zeros((t, d), jnp.float32).at[tok_idx].add(
        y_rep.astype(jnp.float32) * w[:, None]
    )

    # Switch-style load-balance aux loss terms (computed on full router probs).
    me = probs.mean(axis=0)                            # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[e_flat].add(1.0) / (t * k)
    aux = {"lb_loss": E * jnp.sum(me * ce), "kept": keep.sum().astype(jnp.float32),
           "slots": jnp.float32(t * k)}
    return y.reshape(b, s, d).astype(x.dtype), aux


def _moe_tp_local(p, x: jnp.ndarray, cfg: ModelConfig):
    """Expert-TP shard body: all experts, sliced d_ff (weights pre-sliced)."""
    return _moe_local(p, x, cfg, cfg.moe.n_experts, 0)


def moe_forward(
    p, x: jnp.ndarray, cfg: ModelConfig, mesh_info: Optional[MoEMeshInfo]
):
    """MoE block.  x (B,S,D) -> (y (B,S,D), aux dict)."""
    if mesh_info is None:
        y, aux = _moe_local(p, x, cfg, cfg.moe.n_experts, 0)
        return y, {"lb_loss": aux["lb_loss"],
                   "drop_frac": 1.0 - aux["kept"] / aux["slots"]}

    E = cfg.moe.n_experts
    nm = mesh_info.n_model
    P = jax.sharding.PartitionSpec
    ma = mesh_info.model_axis
    # shard batch only when divisible (e.g. long_500k decodes with B=1)
    shardable = x.shape[0] % mesh_info.n_batch == 0 and x.shape[0] >= mesh_info.n_batch
    batch = mesh_info.batch_axes if shardable else None
    x_spec = P(batch, None, None)

    if E % nm == 0:
        w_spec = {
            "router": P(None, None),
            "w_gate": P(ma, None, None),
            "w_up": P(ma, None, None),
            "w_down": P(ma, None, None),
        }

        def body(p_l, x_l):
            rank = jax.lax.axis_index(ma)
            y, aux = _moe_local(p_l, x_l, cfg, E // nm, rank * (E // nm))
            # bf16 all-reduce (MaxText-style): halves ICI bytes vs f32
            y = jax.lax.psum(y.astype(x_l.dtype), ma)
            lb = jax.lax.pmean(aux["lb_loss"], ma).reshape(1)
            drop = (1.0 - jax.lax.psum(aux["kept"], ma) / aux["slots"]).reshape(1)
            return y, lb, drop
    else:
        w_spec = {
            "router": P(None, None),
            "w_gate": P(None, None, ma),
            "w_up": P(None, None, ma),
            "w_down": P(None, ma, None),
        }

        def body(p_l, x_l):
            y, aux = _moe_tp_local(p_l, x_l, cfg)
            y = jax.lax.psum(y.astype(x_l.dtype), ma)
            lb = jax.lax.pmean(aux["lb_loss"], ma).reshape(1)
            drop = (1.0 - jax.lax.pmean(aux["kept"], ma) / aux["slots"]).reshape(1)
            return y, lb, drop

    fn = compat.shard_map(
        body,
        mesh=mesh_info.mesh,
        in_specs=(w_spec, x_spec),
        out_specs=(x_spec, P(batch), P(batch)),
    )
    y, lb, drop = fn(p, x)
    return y, {"lb_loss": lb.mean(), "drop_frac": drop.mean()}
