"""Shared model components: RMSNorm, RoPE, inits, dtype policy."""
from __future__ import annotations


import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm with fp32 accumulation."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies (head_dim//2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x (..., S, H, D), positions (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                          # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    sin = jnp.sin(angles)[..., None, :]                 # (..., S, 1, D/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16, scale: float = 1.0):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis]
    std = scale * (fan_in ** -0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
