"""Mamba2 (SSD) block: init + train/prefill/decode application.

TP-friendly layout: instead of one fused in_proj, the projections are split
(w_z, w_x, w_dt sharded on their output = head axis; w_B, w_C replicated —
they are tiny, G·N wide) so the SSD runs head-parallel over the `model` mesh
axis with zero collectives until the out_proj all-reduce — the same
collective profile as a TP MLP.

  z  = h @ w_z                       (B,S,di)   [sharded di]
  x  = silu(conv_x(h @ w_x))         (B,S,di)   [sharded di]
  Bm = silu(conv_B(h @ w_B))         (B,S,G·N)  [replicated]
  Cm = silu(conv_C(h @ w_C))         (B,S,G·N)  [replicated]
  dt = softplus(h @ w_dt + bias)     (B,S,nh)   [sharded nh]
  y  = SSD(x, dt, A, Bm, Cm, D)                 [head-parallel]
  out = RMSNorm(y ⊙ silu(z)) @ w_out            [row-parallel -> all-reduce]
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.common import dense_init, rms_norm, split_keys


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    gn = s.n_groups * s.d_state
    return s, di, nh, gn


def init_ssm_params(key, cfg: ModelConfig, dtype) -> dict:
    s, di, nh, gn = _dims(cfg)
    ks = split_keys(key, 10)
    dt = jnp.exp(
        jax.random.uniform(ks[0], (nh,), jnp.float32)
        * (jnp.log(s.dt_max) - jnp.log(s.dt_min))
        + jnp.log(s.dt_min)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "w_z": dense_init(ks[1], (cfg.d_model, di), dtype=dtype),
        "w_x": dense_init(ks[2], (cfg.d_model, di), dtype=dtype),
        "w_B": dense_init(ks[3], (cfg.d_model, gn), dtype=dtype),
        "w_C": dense_init(ks[4], (cfg.d_model, gn), dtype=dtype),
        "w_dt": dense_init(ks[5], (cfg.d_model, nh), dtype=dtype),
        "conv_x_w": dense_init(ks[6], (s.d_conv, di), in_axis=0, dtype=dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_B_w": dense_init(ks[7], (s.d_conv, gn), in_axis=0, dtype=dtype),
        "conv_B_b": jnp.zeros((gn,), dtype),
        "conv_C_w": dense_init(ks[8], (s.d_conv, gn), in_axis=0, dtype=dtype),
        "conv_C_b": jnp.zeros((gn,), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[9], (nh,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[5], (di, cfg.d_model), dtype=dtype),
    }


def _causal_conv(xc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d + SiLU, window K.  xc (B,S,C); state (B,K-1,C)
    carries trailing raw inputs of the previous segment."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xc.shape[0], k - 1, xc.shape[2]), xc.dtype)
    else:
        pad = state.astype(xc.dtype)
    xp = jnp.concatenate([pad, xc], axis=1)          # (B, S+K-1, C)
    out = sum(xp[:, i : i + xc.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, xc.shape[1]:]                   # last K-1 raw inputs
    return jax.nn.silu(out), new_state


def _conv_step(x_t: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, state: jnp.ndarray):
    """One-token conv update.  x_t (B,C), state (B,K-1,C)."""
    k = w.shape[0]
    window = jnp.concatenate([state.astype(x_t.dtype), x_t[:, None]], axis=1)  # (B,K,C)
    out = sum(window[:, i] * w[i] for i in range(k)) + b
    return jax.nn.silu(out), window[:, 1:]


def ssm_forward(
    p, hidden: jnp.ndarray, cfg: ModelConfig, *,
    impl: str, return_state: bool = False, initial_state=None,
):
    """Full-sequence Mamba2 block.  hidden (B,S,D).
    state = (conv_x, conv_B, conv_C, ssm) when return_state."""
    s, di, nh, gn = _dims(cfg)
    b, seq, _ = hidden.shape
    st = initial_state or (None, None, None, None)
    z = hidden @ p["w_z"]
    x, cxs = _causal_conv(hidden @ p["w_x"], p["conv_x_w"], p["conv_x_b"], st[0])
    Bm, cbs = _causal_conv(hidden @ p["w_B"], p["conv_B_w"], p["conv_B_b"], st[1])
    Cm, ccs = _causal_conv(hidden @ p["w_C"], p["conv_C_w"], p["conv_C_b"], st[2])
    dt = jax.nn.softplus((hidden @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    x = x.reshape(b, seq, nh, s.head_dim)
    Bm = Bm.reshape(b, seq, s.n_groups, s.d_state)
    Cm = Cm.reshape(b, seq, s.n_groups, s.d_state)
    y, ssm_state = ops.ssd_scan(
        x, dt, A, Bm, Cm, p["D"], chunk=s.chunk, impl=impl, initial_state=st[3]
    )
    y = y.reshape(b, seq, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, (cxs, cbs, ccs, ssm_state)
    return out


def ssm_decode(p, hidden: jnp.ndarray, state, cfg: ModelConfig):
    """One-token decode.  hidden (B,1,D); state=(conv_x (B,K-1,di),
    conv_B (B,K-1,gn), conv_C (B,K-1,gn), ssm (B,nh,P,N))."""
    s, di, nh, gn = _dims(cfg)
    b = hidden.shape[0]
    cx, cb, cc, ssm_state = state
    h_t = hidden[:, 0]                                  # (B,D)
    z = h_t @ p["w_z"]
    x, cx = _conv_step(h_t @ p["w_x"], p["conv_x_w"], p["conv_x_b"], cx)
    Bm, cb = _conv_step(h_t @ p["w_B"], p["conv_B_w"], p["conv_B_b"], cb)
    Cm, cc = _conv_step(h_t @ p["w_C"], p["conv_C_w"], p["conv_C_b"], cc)
    dt = jax.nn.softplus((h_t @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    x = x.reshape(b, nh, s.head_dim)
    Bm = Bm.reshape(b, s.n_groups, s.d_state)
    Cm = Cm.reshape(b, s.n_groups, s.d_state)
    y, new_ssm = ops.ssm_decode_step(x, dt, A, Bm, Cm, p["D"], ssm_state)
    y = y.reshape(b, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return (y @ p["out_proj"])[:, None], (cx, cb, cc, new_ssm)


def init_ssm_state(cfg: ModelConfig, batch: int):
    s, di, nh, gn = _dims(cfg)
    return (
        jnp.zeros((batch, s.d_conv - 1, di), jnp.bfloat16),
        jnp.zeros((batch, s.d_conv - 1, gn), jnp.bfloat16),
        jnp.zeros((batch, s.d_conv - 1, gn), jnp.bfloat16),
        jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    )
