"""SwiGLU MLP block."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, split_keys


def init_mlp_params(key, cfg: ModelConfig, dtype) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    return {
        "w_gate": dense_init(ks[0], (D, F), dtype=dtype),
        "w_up": dense_init(ks[1], (D, F), dtype=dtype),
        "w_down": dense_init(ks[2], (F, D), dtype=dtype),
    }


def mlp_forward(p, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]
