"""GQA attention block: init + train/prefill/decode application."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.common import apply_rope, dense_init, split_keys


def init_attn_params(key, cfg: ModelConfig, dtype) -> dict:
    D, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    Hp, KVp = cfg.eff_n_heads, cfg.eff_n_kv_heads
    ks = split_keys(key, 4)

    def pad_cols(w, n_real, n_pad):
        if n_pad == n_real:
            return w
        return jnp.concatenate(
            [w, jnp.zeros((w.shape[0], (n_pad - n_real) * hd), w.dtype)], axis=1)

    wq = pad_cols(dense_init(ks[0], (D, H * hd), dtype=dtype), H, Hp)
    wk = pad_cols(dense_init(ks[1], (D, KV * hd), dtype=dtype), KV, KVp)
    wv = pad_cols(dense_init(ks[2], (D, KV * hd), dtype=dtype), KV, KVp)
    wo = dense_init(ks[3], (H * hd, D), in_axis=0, dtype=dtype)
    if Hp != H:
        # zero rows for padded heads: their (garbage) attention output never
        # reaches the residual stream, and their grads stay exactly zero —
        # the padded model is numerically identical to the unpadded one.
        wo = jnp.concatenate([wo, jnp.zeros(((Hp - H) * hd, D), wo.dtype)], axis=0)
    p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hp * hd,), dtype)
        p["bk"] = jnp.zeros((KVp * hd,), dtype)
        p["bv"] = jnp.zeros((KVp * hd,), dtype)
    return p


def kv_quantize(k: jnp.ndarray):
    """Per-(…, head)-vector absmax int8 quantization over head_dim."""
    scale = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0].astype(jnp.float32)


def kv_dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _qkv(p, x, cfg: ModelConfig):
    b, s, _ = x.shape
    H, KV, hd = cfg.eff_n_heads, cfg.eff_n_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (
        q.reshape(b, s, H, hd),
        k.reshape(b, s, KV, hd),
        v.reshape(b, s, KV, hd),
    )


def attn_forward(p, x, cfg: ModelConfig, *, impl: str) -> jnp.ndarray:
    """Full-sequence causal attention (training / prefill compute). x (B,S,D)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    pos = jnp.arange(s)[None, :]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    o = ops.flash_attention(q, k, v, causal=True, window=cfg.sliding_window, impl=impl)
    return o.reshape(b, s, -1) @ p["wo"]


def attn_prefill(p, x, cfg: ModelConfig, smax: int, *, impl: str):
    """Prefill: returns (out (B,S,D), k_cache, v_cache (B,Smax,KV,hd))."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    pos = jnp.arange(s)[None, :]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    o = ops.flash_attention(q, k, v, causal=True, window=cfg.sliding_window, impl=impl)
    out = o.reshape(b, s, -1) @ p["wo"]
    if cfg.sliding_window is not None and smax < s:
        # rolling buffer keeps only the last `smax` positions
        k, v = k[:, s - smax:], v[:, s - smax:]
        pad = 0
    else:
        pad = smax - s
    k_cache = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v_cache = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if cfg.kv_cache_dtype == "int8":
        kq, ks = kv_quantize(k_cache)
        vq, vs = kv_quantize(v_cache)
        return out, (kq, ks), (vq, vs)
    return out, k_cache, v_cache


def attn_decode(
    p, x, k_cache, v_cache, lengths, cfg: ModelConfig, *, impl: str
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode.  x (B,1,D); cache (B,Smax,KV,hd) — or, with an int8
    cache, a (values int8, scales f32) pair; lengths (B,) = tokens already in
    cache.  Returns (out (B,1,D), new_k_cache, new_v_cache)."""
    b = x.shape[0]
    quant = cfg.kv_cache_dtype == "int8"
    kq = ks = vq = vs = None
    if quant:
        kq, ks = k_cache
        vq, vs = v_cache
        smax = kq.shape[1]
    else:
        smax = k_cache.shape[1]
    q, k, v = _qkv(p, x, cfg)                     # (B,1,H,hd)/(B,1,KV,hd)
    q = apply_rope(q, lengths[:, None], cfg.rope_theta)
    k = apply_rope(k, lengths[:, None], cfg.rope_theta)
    if cfg.sliding_window is not None and smax <= cfg.sliding_window:
        # rolling buffer: slot = lengths % smax
        slot = lengths % smax
    else:
        slot = jnp.minimum(lengths, smax - 1)
    bidx = jnp.arange(b)
    if quant:
        knq, kns = kv_quantize(k[:, 0])
        vnq, vns = kv_quantize(v[:, 0])
        kq = kq.at[bidx, slot].set(knq)
        ks = ks.at[bidx, slot].set(kns)
        vq = vq.at[bidx, slot].set(vnq)
        vs = vs.at[bidx, slot].set(vns)
        k_cache = kv_dequantize(kq, ks, x.dtype)
        v_cache = kv_dequantize(vq, vs, x.dtype)
    else:
        k_cache = k_cache.at[bidx, slot].set(k[:, 0])
        v_cache = v_cache.at[bidx, slot].set(v[:, 0])
    if cfg.sliding_window is not None and smax <= cfg.sliding_window:
        # cache holds a rotated window; decode attention masks by min(len+1, smax)
        eff_len = jnp.minimum(lengths + 1, smax)
        # NOTE: positions are rotated; softmax is permutation-invariant so a
        # rotated cache is fine as long as RoPE was applied pre-insertion.
        o = ops.decode_attention(q[:, 0], k_cache, v_cache, eff_len, impl=impl)
    else:
        o = ops.decode_attention(
            q[:, 0], k_cache, v_cache, lengths + 1,
            window=cfg.sliding_window, impl=impl,
        )
    out = o.reshape(b, 1, -1) @ p["wo"]
    if quant:
        return out, (kq, ks), (vq, vs)
    return out, k_cache, v_cache


# ======================================================================
# Sharded split-KV flash-decode (perf path — EXPERIMENTS.md §Perf)
#
# For caches whose kv-head count does not divide the TP degree, the
# baseline shards the cache on the SEQUENCE axis and GSPMD then gathers
# the whole cache to every chip each step.  This path instead runs a
# distributed flash-decode inside shard_map: every model-shard attends
# over its local KV slice and only the per-head softmax partials
# (m, l, acc) cross the interconnect — psum bytes are O(B·H·hd), i.e.
# ~kilobytes instead of the gigabyte-scale cache.
# ======================================================================

def attn_decode_sharded(
    p, x, k_cache, v_cache, lengths, cfg: ModelConfig, mesh_info,
):
    """Decode with a sequence-sharded KV cache.  x (B,1,D); caches
    (B,Smax,KV,hd) sharded on axis 1 over `model`.  Returns
    (out (B,1,D), new_k_cache, new_v_cache)."""
    import jax
    from jax.sharding import PartitionSpec as P

    b = x.shape[0]
    quant = cfg.kv_cache_dtype == "int8"
    if quant:
        (kq_c, ks_c), (vq_c, vs_c) = k_cache, v_cache
        smax = kq_c.shape[1]
    else:
        smax = k_cache.shape[1]
    KV, hd = cfg.eff_n_kv_heads, cfg.resolved_head_dim
    H = cfg.eff_n_heads
    grp = H // KV
    nm = mesh_info.n_model
    chunk = smax // nm
    scale = hd ** -0.5

    q, k, v = _qkv(p, x, cfg)                      # (B,1,H,hd)/(B,1,KV,hd)
    q = apply_rope(q, lengths[:, None], cfg.rope_theta)
    k = apply_rope(k, lengths[:, None], cfg.rope_theta)
    qg = (q[:, 0].astype(jnp.float32) * scale).reshape(b, KV, grp, hd)
    k_new, v_new = k[:, 0], v[:, 0]                # (B,KV,hd)

    rolling = cfg.sliding_window is not None and smax <= cfg.sliding_window
    slot = (lengths % smax) if rolling else jnp.minimum(lengths, smax - 1)
    eff_len = jnp.minimum(lengths + 1, smax) if rolling else lengths + 1

    shardable = b % mesh_info.n_batch == 0 and b >= mesh_info.n_batch
    b_ax = mesh_info.batch_axes if shardable else None
    ma = mesh_info.model_axis
    NEG = -1e30

    def body(qg_l, kn_l, vn_l, kc_l, vc_l, slot_l, eff_l, *scales):
        rank = jax.lax.axis_index(ma)
        off = rank * chunk
        bidx = jnp.arange(qg_l.shape[0])
        loc = jnp.clip(slot_l - off, 0, chunk - 1)
        in_rng = (slot_l >= off) & (slot_l < off + chunk)
        cur_k = kc_l[bidx, loc]
        cur_v = vc_l[bidx, loc]
        kc_l = kc_l.at[bidx, loc].set(jnp.where(in_rng[:, None, None], kn_l, cur_k))
        vc_l = vc_l.at[bidx, loc].set(jnp.where(in_rng[:, None, None], vn_l, cur_v))
        if quant:
            ks_l, vs_l, kns_l, vns_l = scales
            cur_ks = ks_l[bidx, loc]
            cur_vs = vs_l[bidx, loc]
            ks_l = ks_l.at[bidx, loc].set(jnp.where(in_rng[:, None], kns_l, cur_ks))
            vs_l = vs_l.at[bidx, loc].set(jnp.where(in_rng[:, None], vns_l, cur_vs))
            k_eff = (kc_l.astype(jnp.float32) * ks_l[..., None]).astype(x.dtype)
            v_eff = (vc_l.astype(jnp.float32) * vs_l[..., None]).astype(x.dtype)
        else:
            k_eff = kc_l
            v_eff = vc_l
        # local partial flash-decode over this shard's cache slice — on TPU
        # this is the Pallas partials kernel (kernels/decode_attention.py),
        # on CPU the identical jnp path; only (m, l, acc) cross the ICI.
        eff_local = jnp.clip(eff_l - off, 0, chunk)
        win = cfg.sliding_window if (cfg.sliding_window is not None and not rolling) else None
        acc, m, l = ops.decode_attention_partials(
            qg_l.reshape(qg_l.shape[0], KV * grp, hd), k_eff, v_eff,
            eff_local, scale=1.0, window=win,
        )
        # combine softmax partials across shards (tiny psum)
        m_g = jax.lax.pmax(m, ma)
        coef = jnp.where(jnp.isfinite(m), jnp.exp(m - m_g), 0.0)
        l_g = jax.lax.psum(l * coef, ma)
        acc_g = jax.lax.psum(acc * coef[..., None], ma)
        o = (acc_g / jnp.maximum(l_g[..., None], 1e-30)).astype(x.dtype)
        if quant:
            return o, kc_l, vc_l, ks_l, vs_l
        return o, kc_l, vc_l

    in_specs = [
        P(b_ax, None, None, None),      # qg
        P(b_ax, None, None),            # k_new
        P(b_ax, None, None),            # v_new
        P(b_ax, ma, None, None),        # k_cache (seq-sharded)
        P(b_ax, ma, None, None),        # v_cache
        P(b_ax),                        # slot
        P(b_ax),                        # eff_len
    ]
    out_specs = [P(b_ax, None, None, None), P(b_ax, ma, None, None),
                 P(b_ax, ma, None, None)]
    if quant:
        knq, kns = kv_quantize(k_new)
        vnq, vns = kv_quantize(v_new)
        in_specs += [P(b_ax, ma, None), P(b_ax, ma, None),   # ks, vs caches
                     P(b_ax, None), P(b_ax, None)]           # new scales
        out_specs += [P(b_ax, ma, None), P(b_ax, ma, None)]
        fn = compat.shard_map(body, mesh=mesh_info.mesh, in_specs=tuple(in_specs),
                              out_specs=tuple(out_specs))
        o, kq_c, vq_c, ks_c, vs_c = fn(qg, knq, vnq, kq_c, vq_c, slot, eff_len,
                                       ks_c, vs_c, kns, vns)
        out = o.reshape(b, 1, H * hd) @ p["wo"]
        return out, (kq_c, ks_c), (vq_c, vs_c)
    fn = compat.shard_map(body, mesh=mesh_info.mesh, in_specs=tuple(in_specs),
                          out_specs=tuple(out_specs))
    o, k_cache, v_cache = fn(qg, k_new, v_new, k_cache, v_cache, slot, eff_len)
    out = o.reshape(b, 1, H * hd) @ p["wo"]
    return out, k_cache, v_cache


def attn_decode_dispatch(p, x, k_cache, v_cache, lengths, cfg: ModelConfig,
                         mesh_info, *, impl: str):
    """Choose the sharded split-KV path when enabled and applicable."""
    smax_chk = (k_cache[0] if cfg.kv_cache_dtype == "int8" else k_cache).shape[1]
    if (cfg.sharded_decode_attn and mesh_info is not None
            and mesh_info.n_model > 1
            and smax_chk % mesh_info.n_model == 0):
        return attn_decode_sharded(p, x, k_cache, v_cache, lengths, cfg, mesh_info)
    return attn_decode(p, x, k_cache, v_cache, lengths, cfg, impl=impl)
