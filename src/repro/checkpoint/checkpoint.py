"""Sharded checkpointing: npz shards + JSON manifest, atomic commit, async
writer, elastic restore (reshard onto a different mesh on load).

Layout:
  <dir>/step_<N>.tmp/            (written)
  <dir>/step_<N>/                (atomic rename = commit)
    manifest.json                {step, keys, shapes, dtypes, tree hash}
    arrays.npz                   one entry per flattened leaf

Fault-tolerance contract: a crash mid-write leaves only a .tmp directory;
`latest_step` ignores it, so restart resumes from the last COMMITTED step.
Restore takes a (possibly different) mesh + sharding spec tree and
device_puts each leaf with its new sharding — elastic re-mesh after node
loss is a restore onto the survivor mesh.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy cannot persist ml_dtypes (bfloat16 etc.) natively: store as a raw
# uint view and round-trip through the manifest's dtype record.
_VIEW_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[arr.dtype.name])
        flat[key] = arr
    return flat


def save(tree, directory: str, step: int, *, extra: Optional[Dict] = None) -> str:
    """Synchronous atomic save.  Returns the committed path."""
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    logical_dtypes = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        logical_dtypes[key] = str(np.asarray(leaf).dtype)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": logical_dtypes,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)            # atomic commit
    return final


class AsyncCheckpointer:
    """Overlaps checkpoint IO with compute: `save` returns immediately after
    snapshotting to host memory; the writer thread persists in background.
    `wait()` joins the in-flight write (call before exit / next save)."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None
        self.last_committed: Optional[str] = None

    def save(self, tree, step: int, *, extra=None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # device -> host snapshot

        def _write():
            self.last_committed = save(host_tree, self.directory, step, extra=extra)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(
    directory: str, step: int, like_tree, *,
    mesh=None, spec_tree=None,
) -> Any:
    """Load a checkpoint into the structure of `like_tree`.

    With (mesh, spec_tree) the leaves are device_put with NamedShardings —
    restoring onto a DIFFERENT mesh than the one that saved is how elastic
    re-meshing after node failure works."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as data:
        flat = {k: data[k] for k in data.files}
    for k, dt in manifest["dtypes"].items():
        if dt in _VIEW_DTYPES:
            flat[k] = flat[k].view(getattr(ml_dtypes, dt))
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    new_leaves = []
    specs_flat = None
    if spec_tree is not None:
        from jax.sharding import PartitionSpec as P
        specs_flat = [
            s for _, s in jax.tree_util.tree_flatten_with_path(
                spec_tree, is_leaf=lambda x: isinstance(x, P))[0]
        ]
    for i, (pth, leaf) in enumerate(leaves_paths):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
        arr = flat[key].astype(leaf.dtype)
        if mesh is not None and specs_flat is not None:
            from jax.sharding import NamedSharding
            arr = jax.device_put(arr, NamedSharding(mesh, specs_flat[i]))
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
