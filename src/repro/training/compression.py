"""Error-feedback int8 gradient compression for the cross-pod (DCN) axis.

On the multi-pod mesh the gradient all-reduce crosses the slow inter-pod
link once per step.  This module compresses that sync: each pod quantizes
its gradient shard to int8 with a per-tensor scale (keeping the
quantization residual in an error-feedback buffer so the bias vanishes
over steps — 1-bit-Adam-style), all-gathers the int8 payload + scales over
the ``pod`` axis (bytes = S·(n−1)/n per pod vs 2·S·(n−1)/n for a bf16
ring all-reduce → 4× fewer DCN bytes), and sums the dequantized shards
locally.

Usage (opt-in):
    err = init_error_feedback(grads)
    grads, err = compressed_grad_sync(grads, err, mesh, axis="pod")
Within-pod reduction stays in GSPMD (fast ICI); only the DCN hop is
compressed.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat


def init_error_feedback(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def quantize_ef(g: jnp.ndarray, err: jnp.ndarray):
    """Error-feedback int8 quantization.  Returns (q int8, scale f32 scalar,
    new_err)."""
    v = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    new_err = v - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_grad_sync(grads, err_tree, mesh, axis: str = "pod"):
    """Sync gradients across `axis` with int8 + error feedback.

    grads enter as the LOCAL (per-pod) average; exit as the cross-pod mean.
    Works per-leaf inside one shard_map over `axis` (other mesh axes pass
    through untouched)."""
    n = mesh.shape[axis]
    P = jax.sharding.PartitionSpec

    def leaf_sync(g, err):
        def body(g_l, err_l):
            q, scale, new_err = quantize_ef(g_l, err_l)
            # all-gather int8 payloads + scales across pods, sum locally
            q_all = jax.lax.all_gather(q, axis)            # (n, ...)
            s_all = jax.lax.all_gather(scale, axis)        # (n,)
            summed = jnp.tensordot(
                s_all.astype(jnp.float32),
                q_all.astype(jnp.float32),
                axes=([0], [0]),
            )
            return (summed / n).astype(g_l.dtype), new_err

        fn = compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(), P()),
            out_specs=(P(), P()),
        )
        return fn(g, err)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    out = [leaf_sync(g, e) for g, e in zip(flat_g, flat_e, strict=True)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    return new_g, new_e


def dcn_bytes(grads, n_pods: int) -> Tuple[int, int]:
    """(compressed, bf16-allreduce) DCN bytes per pod per step."""
    elems = sum(int(g.size) for g in jax.tree.leaves(grads))
    compressed = elems * 1 * (n_pods - 1) // n_pods + 4 * (n_pods - 1)
    bf16_ar = 2 * elems * 2 * (n_pods - 1) // n_pods
    return compressed, bf16_ar
