"""jit-able train / serve step functions (the units the dry-run lowers)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod
from repro.training import optimizer as opt_mod
from repro.training.loss import softmax_xent


def loss_fn(params, cfg: ModelConfig, batch, mesh_info=None):
    logits, aux = model_mod.forward(params, cfg, batch, mesh_info)
    loss, n = softmax_xent(logits, batch["labels"], cfg.vocab_size)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux, "n_tokens": n}


def train_step(
    params, opt_state, batch, *, cfg: ModelConfig,
    opt_cfg: opt_mod.AdamWConfig, mesh_info=None, microbatches: int = 1,
):
    """One optimizer step; optional gradient accumulation over microbatches."""
    if microbatches == 1:
        (tot, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, mesh_info
        )
    else:
        def micro(i):
            mb = jax.tree.map(
                lambda a: a.reshape((microbatches, a.shape[0] // microbatches) + a.shape[1:])[i],
                batch,
            )
            return jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, mb, mesh_info)

        def body(carry, i):
            (tot, metrics), grads = micro(i)
            acc_tot, acc_metrics, acc_grads = carry
            acc_grads = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc_grads, grads)
            return (acc_tot + tot, {k: acc_metrics[k] + metrics[k] for k in metrics}, acc_grads), None

        zg = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        z_metrics = {"loss": 0.0, "aux_loss": 0.0, "n_tokens": 0}
        (tot, metrics, grads), _ = jax.lax.scan(
            body, (jnp.float32(0.0), z_metrics, zg), jnp.arange(microbatches)
        )
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        metrics = {k: v / microbatches for k, v in metrics.items()}

    new_params, new_opt, om = opt_mod.apply_updates(params, grads, opt_state, opt_cfg)
    metrics = dict(metrics)
    metrics.update(om)
    return new_params, new_opt, metrics


def serve_step(params, cache, tokens, *, cfg: ModelConfig, mesh_info=None):
    """One decode step: greedy next-token.  tokens (B,) -> (next (B,), logits, cache)."""
    logits, cache = model_mod.decode_step(params, cfg, cache, tokens, mesh_info)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, logits, cache


def prefill_step(params, batch, *, cfg: ModelConfig, max_len: int, mesh_info=None):
    """Prompt ingestion: returns (first sampled token (B,), cache)."""
    logits, cache = model_mod.prefill(params, cfg, batch, max_len, mesh_info)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, cache
