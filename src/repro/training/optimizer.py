"""AdamW with global-norm clipping and warmup-cosine schedule (no optax).

Optimizer state mirrors the param pytree (fp32 m/v), so the same
PartitionSpec tree shards it; ZeRO-style sharding just extends the specs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def init_opt_state(params) -> dict:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_at(step: jnp.ndarray, c: AdamWConfig) -> jnp.ndarray:
    warm = c.lr * jnp.minimum(1.0, (step + 1) / max(c.warmup_steps, 1))
    prog = jnp.clip((step - c.warmup_steps) / max(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = c.lr * (c.min_lr_frac + (1 - c.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < c.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves))


def apply_updates(params, grads, opt_state, c: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(step, c)
    b1t = 1 - c.b1 ** (step.astype(jnp.float32) + 1)
    b2t = 1 - c.b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = c.b1 * m + (1 - c.b1) * g
        v = c.b2 * v + (1 - c.b2) * g * g
        mhat = m / b1t
        vhat = v / b2t
        step_p = mhat / (jnp.sqrt(vhat) + c.eps) + c.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_p).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v, strict=True)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
