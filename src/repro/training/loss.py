"""Cross-entropy LM loss (fp32, padded-vocab aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray, vocab_size: int):
    """logits (B,S,Vp) fp32, labels (B,S) int32.  Returns (loss, n_tokens)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    valid = (labels >= 0) & (labels < vocab_size)
    nll = jnp.where(valid, lse - ll, 0.0)
    n = jnp.maximum(valid.sum(), 1)
    return nll.sum() / n, n
