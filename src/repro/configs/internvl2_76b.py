"""internvl2-76b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

Backbone only; the vision frontend is a STUB (input_specs provides
precomputed patch embeddings).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    rope_theta=1e6,
    frontend="patch",
)
