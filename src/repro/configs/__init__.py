"""Config registry: --arch <id> resolves here."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME, cell_supported

from repro.configs.internvl2_76b import CONFIG as internvl2_76b
from repro.configs.granite_8b import CONFIG as granite_8b
from repro.configs.qwen2_7b import CONFIG as qwen2_7b
from repro.configs.deepseek_7b import CONFIG as deepseek_7b
from repro.configs.mistral_nemo_12b import CONFIG as mistral_nemo_12b
from repro.configs.musicgen_medium import CONFIG as musicgen_medium
from repro.configs.qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b
from repro.configs.mixtral_8x7b import CONFIG as mixtral_8x7b
from repro.configs.zamba2_1p2b import CONFIG as zamba2_1p2b
from repro.configs.mamba2_2p7b import CONFIG as mamba2_2p7b

ARCHS = {
    c.name: c
    for c in (
        internvl2_76b, granite_8b, qwen2_7b, deepseek_7b, mistral_nemo_12b,
        musicgen_medium, qwen3_moe_30b_a3b, mixtral_8x7b, zamba2_1p2b,
        mamba2_2p7b,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
