"""Architecture configuration dataclasses.

Every assigned architecture is expressed as a :class:`ModelConfig`.  Configs
are plain frozen dataclasses — importing a config module never touches jax
device state.  ``reduced()`` produces the small-family smoke-test variant of
the same architecture (same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    renorm_gate: bool = True          # renormalize top-k softmax (mixtral/qwen3 style)
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    n_groups: int = 1
    head_dim: int = 64                # Mamba2 "P"
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # SWA (mixtral)
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0               # hybrid: shared attn block after layers i%attn_every==attn_every-1
    frontend: str = "tokens"          # tokens | patch (vlm) | frames (audio)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # --- runtime knobs ---
    remat: bool = True
    attn_impl: str = "auto"           # auto | pallas | ref
    max_seq_len: int = 131072
    # perf: pad attention head counts up to a multiple (zero-initialized
    # padded heads -> exact semantics, TP-clean sharding).  See EXPERIMENTS.md §Perf.
    head_pad_multiple: Optional[int] = None
    # perf: decode attention over a sequence-sharded KV cache via shard_map
    # split-KV flash-decode (psum of softmax partials instead of cache gathers)
    sharded_decode_attn: bool = False
    # perf: constrain per-block activations to stay batch-sharded over ALL
    # mesh axes (forces GSPMD to all-gather weights, i.e. true FSDP)
    fsdp_act_constraint: bool = False
    # perf: int8 KV cache (per-token-per-head absmax scales) — halves the
    # decode memory-roofline cache-streaming term
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def eff_n_heads(self) -> int:
        if self.head_pad_multiple:
            return _round_up(self.n_heads, self.head_pad_multiple)
        return self.n_heads

    @property
    def eff_n_kv_heads(self) -> int:
        if self.head_pad_multiple:
            return _round_up(self.n_kv_heads, self.head_pad_multiple)
        return self.n_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a TP-friendly multiple (MaxText-style)."""
        return _round_up(self.vocab_size, 256)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_subquadratic_attention(self) -> bool:
        """True if long-context (500k) decode is in scope for this arch."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        n = V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("ssm", "hybrid"):
            s = self.ssm
            di = s.d_inner(D)
            nh = s.n_heads(D)
            conv_dim = di + 2 * s.n_groups * s.d_state
            per_layer += D * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj
            per_layer += conv_dim * s.d_conv                               # conv
            per_layer += di * D                                            # out_proj
            per_layer += 2 * nh + di + D                                   # A, D, norm, ln
        if self.family in ("dense", "vlm", "audio") or self.attn_every:
            qkv = D * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * D
            n_attn = self.n_layers if not self.attn_every else 1  # shared block for hybrid
            per_attn = qkv + 2 * D
            if not self.attn_every:
                per_layer += per_attn
            else:
                n += per_attn  # one shared block
        if self.family in ("dense", "vlm", "audio"):
            per_layer += 3 * D * F + 2 * D
        if self.family == "moe":
            qkv = D * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * D
            per_layer += qkv + 2 * D
            per_layer += D * self.moe.n_experts
            per_layer += 3 * D * self.moe.d_ff_expert * self.moe.n_experts
        n += per_layer * self.n_layers + D
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE uses top_k experts only)."""
        if self.family != "moe":
            return self.n_params()
        dense_like = self.n_params()
        e, k = self.moe.n_experts, self.moe.top_k
        expert_params = 3 * self.d_model * self.moe.d_ff_expert * self.moe.n_experts * self.n_layers
        return dense_like - expert_params + expert_params * k // e

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4 if not self.attn_every else 6),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            max_seq_len=512,
            remat=False,
        )
        if self.moe is not None:
            # capacity_factor high enough that the toy config never drops
            # tokens — keeps prefill/decode numerically consistent in tests
            kw["moe"] = dataclasses.replace(self.moe, n_experts=min(self.moe.n_experts, 4),
                                            top_k=min(self.moe.top_k, 2), d_ff_expert=64,
                                            capacity_factor=8.0)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=32, chunk=32)
        if self.sliding_window is not None:
            kw["sliding_window"] = 64
        return dataclasses.replace(self, **kw)


# ----------------------------------------------------------------------
# Shape sets (assigned): every LM arch gets all four; applicability of
# decode/long cells is resolved by `cells_for()` below.
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell."""
    if shape.name == "long_500k" and not cfg.has_subquadratic_attention:
        return False, "long_500k skipped: pure full-attention arch (quadratic); see DESIGN.md"
    return True, ""
