"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block [arXiv:2411.15242].

Zamba-style: ONE shared attention block (shared weights) applied after every
6th Mamba2 layer.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,                # unused by mamba blocks; shared attn block is attn-only
    vocab_size=32000,
    head_dim=64,
    attn_every=6,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=128),
)
