"""B-PASTE ⨯ serving: batch-slot speculation on the TPU substrate.

Model nodes in a branch hypothesis are future reasoning boundaries: on the
serving engine they become *speculative sequences* — the predicted tool
result is rendered into tokens and prefilled into a free slot, so the
reasoning that will follow the tool is already decoding while the tool runs
on the host.  When the authoritative tool result arrives and matches the
prediction, the slot is promoted (zero-copy, per engine.promote); otherwise
it is preempted at the next step boundary.

This module is the hardware-adaptation of the paper's slack-resource rule:
slack = free batch slots, preemption = slot reclaim, budget B = the max
number of speculative slots the operator allows.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.hypothesis import BranchHypothesis, NodeKind
from repro.serving.engine import ServingEngine


def render_observation(tool: str, args: Dict[str, Any], result: Any,
                       vocab_size: int, length: int = 16) -> List[int]:
    """Deterministic 'tokenizer' stub: hash the observation into token ids.
    Identical (tool, args, result) always renders identically, so a matching
    speculative prefill is exactly reusable."""
    key = f"{tool}|{sorted(args.items())!r}|{result!r}"
    h = hashlib.sha256(key.encode()).digest()
    return [2 + (h[i % len(h)] * 256 + h[(i + 1) % len(h)]) % (vocab_size - 3)
            for i in range(length)]


@dataclass
class SpecSequence:
    hid: int
    node_idx: int
    slot: int
    predicted_obs: Tuple[int, ...]
    eu: float


@dataclass
class SlotSpeculator:
    """Admits speculative continuations into free engine slots by EU order,
    under a speculative-slot budget; preempts ascending-EU under pressure."""
    engine: ServingEngine
    budget_slots: int = 2
    active: Dict[int, SpecSequence] = field(default_factory=dict)  # slot -> seq
    promotions: int = 0
    preemptions: int = 0
    admitted: int = 0

    def spec_slots_used(self) -> int:
        return len(self.active)

    def admit(self, hyps: List[Tuple[BranchHypothesis, float]],
              history_prompt: List[int]) -> int:
        """hyps: (hypothesis, EU) sorted desc; admit best into free slots."""
        n = 0
        for hyp, eu in sorted(hyps, key=lambda x: -x[1]):
            if eu <= 0:
                continue
            if self.spec_slots_used() >= self.budget_slots:
                break
            if self.engine.slack() == 0:
                break
            node = hyp.first_tool()
            if node is None:
                continue
            # predicted observation for the model node after this tool
            obs = render_observation(node.tool, {}, f"pred:{hyp.hid}:{node.idx}",
                                     self.engine.cfg.vocab_size)
            prompt = history_prompt + obs
            slot = self.engine.add_request(
                prompt, request_id=-hyp.hid, speculative=True, eu=eu
            )
            if slot is None:
                break
            self.active[slot] = SpecSequence(hyp.hid, node.idx, slot, tuple(obs), eu)
            self.admitted += 1
            n += 1
        return n

    def ensure_authoritative_room(self, needed_slots: int):
        """Phase-2 analogue: preempt speculative slots (ascending EU) until
        `needed_slots` are free."""
        while self.engine.slack() < needed_slots and self.active:
            victim_slot = min(self.active, key=lambda s: self.active[s].eu)
            self.engine.preempt(victim_slot)
            del self.active[victim_slot]
            self.preemptions += 1

    def match_and_promote(self, authoritative_obs: List[int],
                          request_id: int) -> Optional[int]:
        """Phase-1 analogue: if a speculative slot decoded from exactly this
        observation, promote it (its generated tokens are already valid)."""
        for slot, seq in list(self.active.items()):
            if tuple(authoritative_obs) == seq.predicted_obs:
                self.engine.promote(slot, request_id)
                del self.active[slot]
                self.promotions += 1
                return slot
        return None

    def squash_all(self):
        for slot in list(self.active):
            self.engine.preempt(slot)
            del self.active[slot]
            self.preemptions += 1
