"""Batched decode engine with slot management (continuous batching).

The engine owns a fixed-capacity batched KV/state cache; requests occupy
*slots*.  Free slots are the serving-side analogue of the paper's "slack
resources": B-PASTE admits speculative sequences into them, preempts by
dropping a slot at the next decode-step boundary (one step = the preemption
granularity on an accelerator), and promotes by re-tagging a slot
authoritative — zero-copy, KV rows are slot-stable.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_mod


@dataclass
class Slot:
    idx: int
    request_id: Optional[int] = None
    speculative: bool = False
    eu: float = 0.0
    tokens: List[int] = field(default_factory=list)
    active: bool = False
    done: bool = False


def _write_slot(cache_tree, slot_cache_tree, slot: int):
    """Write a single-sequence cache into batch position `slot`.

    Batch position differs per leaf: KV leaves are (L, B, S, KV, hd) — batch
    at axis 1; lengths (B,) at axis 0; ssm states (L, B, ...) axis 1."""

    def upd(big, small):
        # the batch axis is the first dim where the batched and the
        # single-sequence cache disagree (1 vs max_batch)
        axis = None
        for i, (b_, s_) in enumerate(zip(big.shape, small.shape, strict=False)):
            if b_ != s_:
                axis = i
                break
        if axis is None:
            return small.astype(big.dtype)
        idx = [slice(None)] * big.ndim
        idx[axis] = slot
        take = [slice(None)] * small.ndim
        take[axis] = 0
        return big.at[tuple(idx)].set(small[tuple(take)].astype(big.dtype))

    return jax.tree.map(upd, cache_tree, slot_cache_tree)


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 8,
        max_len: int = 1024,
        mesh_info=None,
        eos_id: int = 1,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.mesh_info = mesh_info
        self.eos_id = eos_id
        self.cache = model_mod.init_cache(cfg, max_batch, max_len)
        self.slots = [Slot(i) for i in range(max_batch)]
        self.pending_tokens = np.zeros((max_batch,), np.int32)
        self.steps_executed = 0
        self.spec_steps_executed = 0

        # per-leaf batch axis, derived structurally (a size-1 probe cache
        # differs from the batched cache exactly at the batch axis)
        big_s = jax.eval_shape(lambda: model_mod.init_cache(cfg, max_batch, max_len))
        small_s = jax.eval_shape(lambda: model_mod.init_cache(cfg, 1, max_len))
        self._batch_axes = jax.tree.map(
            lambda b, sm: next(
                (i for i, (x, y) in enumerate(zip(b.shape, sm.shape, strict=False)) if x != y), 0
            ),
            big_s, small_s,
        )

        def _mask_batch(new, old, mask, axis):
            shape = [1] * new.ndim
            shape[axis] = mask.shape[0]
            return jnp.where(mask.reshape(shape), new, old)

        @jax.jit
        def _decode(params, cache, tokens, active_mask):
            logits, new_cache = model_mod.decode_step(params, cfg, cache, tokens, mesh_info)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            # inactive slots do not advance
            new_cache = jax.tree.map(
                lambda new, old, ax: _mask_batch(new, old, active_mask, ax),
                new_cache, cache, self._batch_axes,
            )
            return nxt, new_cache

        self._decode = _decode

        @functools.partial(jax.jit, static_argnames=("prompt_len",))
        def _prefill_one(params, tokens, prompt_len: int):
            logits, cache1 = model_mod.prefill(
                params, cfg, {"tokens": tokens}, max_len, mesh_info
            )
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, cache1

        self._prefill_one = _prefill_one

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [s.idx for s in self.slots if not s.active]

    def slack(self) -> int:
        """Idle batch capacity = the engine's slack resource."""
        return len(self.free_slots())

    def add_request(
        self, prompt: List[int], *, request_id: int, speculative: bool = False,
        eu: float = 0.0,
    ) -> Optional[int]:
        free = self.free_slots()
        if not free:
            return None
        slot = free[0]
        s = self.slots[slot]
        s.request_id = request_id
        s.speculative = speculative
        s.eu = eu
        s.tokens = list(prompt)
        s.active = True
        s.done = False
        toks = jnp.asarray([prompt], jnp.int32)
        nxt, cache1 = self._prefill_one(self.params, toks, len(prompt))
        self.cache = _write_slot(self.cache, cache1, slot)
        self.pending_tokens[slot] = int(nxt[0])
        return slot

    def preempt(self, slot: int):
        """Reclaim a speculative slot at a step boundary (drop, zero-copy)."""
        s = self.slots[slot]
        assert s.speculative, "authoritative slots are never preempted"
        s.active = False
        s.request_id = None

    def promote(self, slot: int, request_id: int):
        """Speculative -> authoritative (non-preemptible), zero-copy."""
        s = self.slots[slot]
        s.speculative = False
        s.request_id = request_id
        s.eu = float("inf")

    def step(self) -> Dict[int, int]:
        """One batched decode step; returns {slot: new_token} for active slots."""
        active = np.array([s.active and not s.done for s in self.slots])
        if not active.any():
            return {}
        tokens = jnp.asarray(self.pending_tokens, jnp.int32)
        nxt, self.cache = self._decode(self.params, self.cache, tokens, jnp.asarray(active))
        nxt = np.asarray(nxt)
        out: Dict[int, int] = {}
        self.steps_executed += 1
        self.spec_steps_executed += int(
            sum(1 for s in self.slots if s.active and s.speculative)
        )
        for s in self.slots:
            if not (s.active and not s.done):
                continue
            tok = int(nxt[s.idx])
            s.tokens.append(int(self.pending_tokens[s.idx]))
            self.pending_tokens[s.idx] = tok
            out[s.idx] = tok
            if tok == self.eos_id or len(s.tokens) >= self.max_len - 1:
                s.done = True
                s.active = False
        return out
