"""``python -m repro.staticcheck`` — cache-coherence & trace-discipline
static checker over the runtime source itself.

PR 7's analyzer checks the *configuration* (policies, footprints, mined
tables) and its sanitizer cross-checks the *running state* on sampled ticks.
What neither covers is the code: the event scheduler's whole performance
story rests on epoch-guarded caches, dirty-sets, and counter-group slack
staying coherent with dozens of hand-written mutation sites scattered across
``runtime.py``/``simulator.py``/``memo.py`` — a future edit that writes
guarded state without the matching invalidation only trips the sanitizer
*probabilistically, at runtime*.  This module lifts those contracts to an
AST-level static guarantee, checked in CI on every push:

  C1-mutation   mutation coverage: a declared registry of guarded state
                (``MUTATION_RULES``) and its invalidation idioms (epoch
                bump / ``_mark_dirty``, counter-cache clear, heap push /
                lazy-invalidate, ``bump_if_live``).  Every function that
                writes a guarded attribute — directly, through a mutator
                method (``.append``/``.pop``/…), or through a local alias
                of the guarded container — must hit a matching invalidation
                on ALL control-flow paths (intra-procedural flow over
                (wrote, invalidated) states; known dirtying-transition
                methods count as invalidators).  Pair-grouped fields
                (``NodeRun.*_cache``/``*_epoch``) must be written together.
  C2-trace      trace discipline: inside ``jax.jit``-decorated functions,
                ``lax`` loop/branch bodies, and Pallas kernels, flag
                host-sync coercions (``float()``/``int()``/``bool()``/
                ``.item()``/``.tolist()`` on traced values), ``np.`` calls
                applied to traced arguments, and Python ``if``/``while`` on
                traced scalars.  ``static_argnames`` params are untainted;
                ``.shape``/``.ndim``/``.dtype``/``len()`` launder taint
                (they are static under tracing).
  C3-compat     compat-bypass: direct ``Mesh``/``shard_map``/``pltpu``
                compiler-param usage outside ``repro/compat.py`` (the
                ROADMAP version-shim rule, previously unenforced).
  C4-dispatch   dispatch-shape discipline: ``pack_beam`` calls whose k
                argument doesn't flow through ``bucket_k`` (or the
                ``k_max`` cap it buckets to), and calls into the jitted
                entrypoints ``admit_beam``/``score_beam`` outside their
                blessed wrappers (``fused_admit``/``Scorer.score``) — the
                bounded-compile-shape invariant.

Approximations (deliberate, documented so findings stay interpretable):
the C1 flow treats loop bodies as executing once (every registry idiom
invalidates unconditionally; "invalidate only inside a maybe-empty loop"
is accepted), and C2 scans only *directly* traced scopes — helpers like
``static_gain_terms`` that branch on static params are called from jit but
are legitimately bimodal host/device code.

Zero findings are required on the default tree.  A site that is safe for
reasons the checker cannot see is listed in ``BASELINE`` with a written
justification; baselined hits land in ``report.meta["baselined"]``, never
in the findings.

Reuses :class:`repro.core.analysis.Finding`/``AnalysisReport``.  Exit
status mirrors ``python -m repro.analysis``: 0 clean, 1 findings, 2 under
``--strict`` when any finding is an error.
"""
from __future__ import annotations

import argparse
import ast
import json
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, FrozenSet, Iterable, List, Optional, Set, Tuple

from repro.core.analysis import AnalysisReport, exit_code

# ======================================================================
# registries
# ======================================================================

#: container-mutating method names that count as writes to the object the
#: method chain hangs off (``self._read_index.setdefault(nk, set()).add(k)``
#: is a write to ``_read_index``)
MUTATOR_METHODS: FrozenSet[str] = frozenset({
    "append", "extend", "insert", "pop", "popitem", "remove", "clear",
    "add", "discard", "update", "setdefault", "sort",
})


@dataclass(frozen=True)
class MutationRule:
    """One C1 entry: guarded attributes + the invalidation idiom that must
    accompany any write to them.

    ``invalidators`` entries are ``"call:<dotted tail>"`` (a call whose
    dotted name ends with the tail — ``_mark_dirty``,
    ``_demand_cache.clear``) or ``"write:<attr>"`` (a write to that attr is
    itself the invalidation — the heap's lazy ``_live`` tombstone).  A rule
    with ``pair_groups`` instead requires group members to be written
    together.  A rule with neither bans writes outright (single-writer
    fields); ``exempt`` qualnames are the sanctioned writers.
    """
    name: str
    modules: Tuple[str, ...]                  # relpath suffixes the rule scans
    attrs: FrozenSet[str]
    invalidators: Tuple[str, ...] = ()
    pair_groups: Tuple[FrozenSet[str], ...] = ()
    mutators: FrozenSet[str] = MUTATOR_METHODS
    exempt: FrozenSet[str] = frozenset()


MUTATION_RULES: Tuple[MutationRule, ...] = (
    # EpisodeState/NodeRun/HypRun fields the phase-4 rebuild caches hang off:
    # any write must bump the episode epoch + dirty-set (directly or through
    # a known dirtying-transition method, each of which marks internally).
    MutationRule(
        name="runtime-epoch",
        modules=("core/runtime.py",),
        attrs=frozenset({
            "history", "pending_action", "inflight", "hyp_runs", "phase",
            "warm_until", "matched_hr", "step_idx", "status", "result",
            "job", "served", "epoch",
        }),
        invalidators=(
            "call:_mark_dirty", "call:_mark_dirty_eid",
            # dirtying transitions: each calls _mark_dirty before mutating
            "call:_finish_action", "call:_commit_path", "call:_squash_one",
            "call:_squash_all", "call:_prune_beam", "call:_serve_spec",
        ),
        exempt=frozenset({"BPasteRuntime._mark_dirty"}),  # IS the bump
    ),
    # epoch-stamped cache pairs: writing the cache without the stamp (or
    # vice versa) silently serves a stale value next epoch check.
    MutationRule(
        name="noderun-pairs",
        modules=("core/runtime.py",),
        attrs=frozenset({
            "args_cache", "args_epoch", "mkey_cache", "mkey_epoch",
            "serv_epoch", "serv_pubs", "serv_inval", "serv_ok",
        }),
        pair_groups=(
            frozenset({"args_cache", "args_epoch"}),
            frozenset({"mkey_cache", "mkey_epoch"}),
            frozenset({"serv_epoch", "serv_pubs", "serv_inval", "serv_ok"}),
        ),
    ),
    # counter-group demand: any change to the running set or the group
    # counters must clear the O(#groups) demand cache.
    MutationRule(
        name="sim-demand",
        modules=("core/simulator.py",),
        attrs=frozenset({"running", "_groups"}),
        invalidators=("call:_demand_cache.clear",),
    ),
    # completion-time heap: a re-rated job needs a fresh heap entry
    # (_push) or a lazy tombstone (dropping its _live sequence number).
    MutationRule(
        name="sim-heap",
        modules=("core/simulator.py",),
        attrs=frozenset({"_rate"}),
        invalidators=("call:_push", "write:_live"),
    ),
    # job class flips corrupt the auth/spec counter split unless they go
    # through Simulator.set_speculative — ban every other write.
    MutationRule(
        name="class-flip",
        modules=("core/runtime.py", "core/simulator.py",
                 "core/model_service.py"),
        attrs=frozenset({"speculative", "priority"}),
        exempt=frozenset({"Simulator.set_speculative"}),
    ),
    # entry-table writes must keep the read index coherent.
    MutationRule(
        name="store-index",
        modules=("core/memo.py",),
        attrs=frozenset({"entries"}),
        invalidators=("call:_deindex", "write:_read_index"),
    ),
    # tool_pubs is the servability-cache monotone counter: single writer
    # (publish), never decremented — _deindex deliberately leaves it alone.
    MutationRule(
        name="store-pubs",
        modules=("core/memo.py",),
        attrs=frozenset({"tool_pubs"}),
        exempt=frozenset({"ResultStore.publish"}),
    ),
    # live-state tool writes must advance the sandbox staleness version.
    MutationRule(
        name="live-bump",
        modules=("core/executor.py",),
        attrs=frozenset({"M", "F", "E"}),
        invalidators=("call:bump_if_live",),
        mutators=frozenset({"set", "delete"}),
    ),
)

#: sites that are safe for reasons outside the intra-procedural view —
#: keyed (rule id, site), value is the justification recorded in
#: ``report.meta["baselined"]``.
BASELINE: Dict[Tuple[str, str], str] = {
    ("C1-mutation", "core/runtime.py:BPasteRuntime._launch_frontier"):
        "settle-warm flips a pending env_warmup prep to reused mid-walk; "
        "the mutating walk only runs while phase 4 is rebuilding a dirty "
        "episode's frontier (the value being cached), and the sanitizer "
        "uses the settle_warm=False variant, which never mutates",
    ("C1-mutation", "core/runtime.py:BPasteRuntime._refresh_beam"):
        "appends fresh HypRuns while phase 4 rebuilds a dirty episode's "
        "beam — the epoch-guarded caches are recomputed in the same pass, "
        "and new NodeRuns start at epoch -1 so nothing stale can serve",
}

# C4: blessed wrappers for the jitted entrypoints (relpath suffix, qualname)
_JIT_ENTRYPOINT_WRAPPERS: Dict[str, Tuple[Tuple[str, str], ...]] = {
    "admit_beam": (("core/admission.py", "fused_admit"),),
    "score_beam": (("core/scoring.py", "Scorer.score"),),
}

_LAX_LOOP_FUNCS = frozenset({
    "while_loop", "fori_loop", "scan", "cond", "switch", "map",
})
_TAINT_LAUNDER_ATTRS = frozenset({"shape", "ndim", "dtype"})
_JAX_NAMESPACES = frozenset({"jnp", "lax", "jax", "pl", "pltpu"})


# ======================================================================
# small AST helpers
# ======================================================================

def _dotted(node: ast.AST) -> str:
    """Dotted-name suffix of an Attribute/Name chain (``self._groups.get``);
    chains hanging off calls/subscripts keep only the attribute tail."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _walk_shallow(node: ast.AST) -> Iterable[ast.AST]:
    """ast.walk that does NOT descend into nested function/lambda bodies
    (those execute on their own schedule and are analyzed separately)."""
    stack = [node]
    while stack:
        n = stack.pop()
        yield n
        for c in ast.iter_child_nodes(n):
            if isinstance(c, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda, ast.ClassDef)):
                continue
            stack.append(c)


def _chain_base_attr(node: ast.AST, attrs: FrozenSet[str],
                     aliases: Dict[str, str]) -> Optional[str]:
    """Descend an Attribute/Subscript/Call chain to the guarded attribute
    (or alias) it hangs off, if any."""
    while True:
        if isinstance(node, ast.Attribute):
            if node.attr in attrs:
                return node.attr
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        elif isinstance(node, ast.Name):
            return aliases.get(node.id)
        else:
            return None


def _target_attr(t: ast.AST, attrs: FrozenSet[str],
                 aliases: Dict[str, str]) -> List[str]:
    """Guarded attrs written by one assignment/delete target."""
    if isinstance(t, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in t.elts:
            out.extend(_target_attr(e, attrs, aliases))
        return out
    if isinstance(t, ast.Starred):
        return _target_attr(t.value, attrs, aliases)
    if isinstance(t, ast.Attribute):
        return [t.attr] if t.attr in attrs else []
    if isinstance(t, ast.Subscript):
        a = _chain_base_attr(t.value, attrs, aliases)
        return [a] if a else []
    # plain Name rebinding is not a mutation of the guarded object
    return []


def _writes_in(stmt: ast.AST, attrs: FrozenSet[str],
               aliases: Dict[str, str],
               mutators: FrozenSet[str]) -> Set[str]:
    """Guarded attrs this statement writes: assignment targets, ``del``,
    and mutator-method calls anywhere in the statement."""
    written: Set[str] = set()
    for n in _walk_shallow(stmt):
        if isinstance(n, ast.Assign):
            for t in n.targets:
                written.update(_target_attr(t, attrs, aliases))
        elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
            written.update(_target_attr(n.target, attrs, aliases))
        elif isinstance(n, ast.Delete):
            for t in n.targets:
                written.update(_target_attr(t, attrs, aliases))
        elif (isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
              and n.func.attr in mutators):
            a = _chain_base_attr(n.func.value, attrs, aliases)
            if a:
                written.add(a)
    return written


def _alias_source_attr(v: ast.AST, attrs: FrozenSet[str]) -> Optional[str]:
    """Does this RHS expression evaluate to (an element of) a guarded
    container, so the bound name aliases it?  Copies (``list(...)``,
    comprehensions, slices of copies) do NOT alias."""
    if isinstance(v, ast.Attribute) and v.attr in attrs:
        return v.attr
    if isinstance(v, ast.Subscript):
        inner = v.value
        if isinstance(inner, ast.Attribute) and inner.attr in attrs:
            return inner.attr
    if (isinstance(v, ast.Call) and isinstance(v.func, ast.Attribute)
            and v.func.attr in ("get", "setdefault")
            and isinstance(v.func.value, ast.Attribute)
            and v.func.value.attr in attrs):
        return v.func.value.attr
    return None


def _collect_aliases(fn: ast.AST, attrs: FrozenSet[str]) -> Dict[str, str]:
    """Local names bound to guarded containers (``g = self._groups.get(k)``,
    ``g = self._groups[k] = [...]``) — writes through them count."""
    aliases: Dict[str, str] = {}
    for n in _walk_shallow(fn):
        if not isinstance(n, ast.Assign):
            continue
        names = [t.id for t in n.targets if isinstance(t, ast.Name)]
        if not names:
            continue
        src = _alias_source_attr(n.value, attrs)
        if src is None:
            # multi-target: ``g = self._groups[key] = [...]``
            for t in n.targets:
                if isinstance(t, ast.Subscript):
                    inner = t.value
                    if isinstance(inner, ast.Attribute) and inner.attr in attrs:
                        src = inner.attr
                        break
        if src is not None:
            for name in names:
                aliases[name] = src
    return aliases


# ======================================================================
# C1 — mutation-coverage dataflow
# ======================================================================

# flow state: (wrote a guarded attr, hit an invalidator) — sets of these
# (≤4 members) flow through the function; a terminal (True, False) state is
# a path that mutated guarded state without invalidating.

class _C1Flow:
    def __init__(self, rule: MutationRule, aliases: Dict[str, str]):
        self.rule = rule
        self.aliases = aliases
        self.written: Set[str] = set()     # attrs written anywhere (detail)
        self._write_specs = frozenset(
            s.split(":", 1)[1] for s in rule.invalidators
            if s.startswith("write:"))
        self._call_specs = tuple(
            s.split(":", 1)[1] for s in rule.invalidators
            if s.startswith("call:"))

    def _invalidates(self, stmt: ast.AST) -> bool:
        if self._call_specs:
            for n in _walk_shallow(stmt):
                if isinstance(n, ast.Call):
                    d = _dotted(n.func)
                    if any(d == tail or d.endswith("." + tail)
                           for tail in self._call_specs):
                        return True
        if self._write_specs and _writes_in(
                stmt, self._write_specs, self.aliases, self.rule.mutators):
            return True
        return False

    def _apply(self, stmt: ast.AST,
               states: Set[Tuple[bool, bool]]) -> Set[Tuple[bool, bool]]:
        w = _writes_in(stmt, self.rule.attrs, self.aliases, self.rule.mutators)
        self.written.update(w)
        inv = self._invalidates(stmt)
        if not w and not inv:
            return states
        return {(ws or bool(w), vs or inv) for ws, vs in states}

    def run_block(self, stmts, states):
        """Returns (fallthrough states, return/raise states, break states).
        Loop bodies run exactly once (see module docstring)."""
        cur = set(states)
        exits: Set[Tuple[bool, bool]] = set()
        breaks: Set[Tuple[bool, bool]] = set()
        for stmt in stmts:
            if not cur:
                break                      # unreachable
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue                   # analyzed as its own function
            if isinstance(stmt, (ast.Return, ast.Raise)):
                exits |= self._apply(stmt, cur)
                cur = set()
            elif isinstance(stmt, (ast.Break, ast.Continue)):
                breaks |= cur
                cur = set()
            elif isinstance(stmt, ast.If):
                cur = self._apply(stmt.test, cur)
                b1, e1, br1 = self.run_block(stmt.body, cur)
                b2, e2, br2 = self.run_block(stmt.orelse, cur)
                cur = b1 | b2
                exits |= e1 | e2
                breaks |= br1 | br2
            elif isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
                head = stmt.iter if isinstance(stmt, (ast.For, ast.AsyncFor)) \
                    else stmt.test
                cur = self._apply(head, cur)
                b, e, br = self.run_block(stmt.body, cur)
                cur = b | br               # this loop consumes its breaks
                exits |= e
                if stmt.orelse:
                    b2, e2, br2 = self.run_block(stmt.orelse, cur)
                    cur, exits, breaks = b2, exits | e2, breaks | br2
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    cur = self._apply(item.context_expr, cur)
                b, e, br = self.run_block(stmt.body, cur)
                cur, exits, breaks = b, exits | e, breaks | br
            elif isinstance(stmt, ast.Try):
                b, e, br = self.run_block(stmt.body, cur)
                exits |= e
                breaks |= br
                out = set(b)
                for h in stmt.handlers:
                    hb, he, hbr = self.run_block(h.body, cur | b)
                    out |= hb
                    exits |= he
                    breaks |= hbr
                if stmt.orelse:
                    ob, oe, obr = self.run_block(stmt.orelse, b)
                    out = (out - b) | ob
                    exits |= oe
                    breaks |= obr
                if stmt.finalbody:
                    out, fe, fbr = self.run_block(stmt.finalbody, out)
                    exits |= fe
                    breaks |= fbr
                cur = out
            else:
                cur = self._apply(stmt, cur)
        return cur, exits, breaks


def _check_mutation_rule(rule: MutationRule, relpath: str,
                         functions: List[Tuple[str, ast.AST]],
                         report: AnalysisReport) -> None:
    for qualname, fn in functions:
        if qualname.split(".")[-1] == "__init__":
            continue                       # construction populates, by design
        if any(qualname == ex or qualname.endswith("." + ex)
               for ex in rule.exempt):
            continue
        site = f"{relpath}:{qualname}"
        aliases = _collect_aliases(fn, rule.attrs)
        if rule.pair_groups:
            written = set()
            for stmt in fn.body:
                written |= _writes_in(stmt, rule.attrs, aliases, rule.mutators)
            for group in rule.pair_groups:
                hit = written & group
                if hit and hit != group:
                    _emit(report, "C1-mutation", "error", site,
                          f"[{rule.name}] writes {sorted(hit)} without the "
                          f"rest of the cache/epoch group "
                          f"{sorted(group - hit)} — a stale pair serves "
                          f"under the next epoch check")
            continue
        flow = _C1Flow(rule, aliases)
        out, exits, breaks = flow.run_block(fn.body, {(False, False)})
        final = out | exits | breaks
        if not rule.invalidators:
            if flow.written:
                _emit(report, "C1-mutation", "error", site,
                      f"[{rule.name}] writes single-writer field(s) "
                      f"{sorted(flow.written)} outside the sanctioned "
                      f"writer(s) {sorted(rule.exempt) or '(none)'}")
            continue
        if any(w and not inv for w, inv in final):
            _emit(report, "C1-mutation", "error", site,
                  f"[{rule.name}] writes guarded state "
                  f"{sorted(flow.written)} but some path reaches the end "
                  f"of the function without any of "
                  f"{list(rule.invalidators)}")


# ======================================================================
# C2 — trace discipline
# ======================================================================

def _static_argnames(dec: ast.Call) -> Set[str]:
    names: Set[str] = set()
    for kw in dec.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
    return names


def _param_names(fn) -> List[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


def _positional_params(fn) -> List[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args)]


def _traced_scopes(tree: ast.Module):
    """(fn node, tainted param names, kind) for every directly-traced scope:
    jit-decorated defs, local defs/lambdas handed to lax control flow,
    ``jax.jit(f)`` call-form targets, and Pallas kernel bodies."""
    by_name: Dict[str, ast.AST] = {}
    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(n.name, n)
    scopes: List[Tuple[ast.AST, Set[str], str]] = []
    seen: Set[int] = set()

    def add(fn, static: Set[str], kind: str, pos_only: bool = False):
        if fn is None or id(fn) in seen:
            return
        seen.add(id(fn))
        # pos_only (Pallas kernels): refs arrive positionally, so
        # keyword-only params are always static Python configuration
        names = _positional_params(fn) if pos_only else _param_names(fn)
        scopes.append((fn, set(names) - static, kind))

    def resolve(node):
        """Function-typed argument -> (def/lambda node, statically-bound
        param names) — ``functools.partial`` bindings are trace-time
        constants, not traced operands."""
        if isinstance(node, ast.Lambda):
            return node, set()
        if isinstance(node, ast.Name):
            fn = by_name.get(node.id)
            return (fn, set()) if fn is not None else None
        if (isinstance(node, ast.Call) and node.args
                and _dotted(node.func).endswith("partial")):
            r = resolve(node.args[0])
            if r is None:
                return None
            fn, bound = r
            bound = set(bound)
            bound.update(kw.arg for kw in node.keywords if kw.arg)
            if not isinstance(fn, ast.Lambda):
                bound.update(_positional_params(fn)[:len(node.args) - 1])
            return fn, bound
        return None

    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in n.decorator_list:
                d = _dotted(dec if not isinstance(dec, ast.Call) else dec.func)
                if d.endswith("jit") and not isinstance(dec, ast.Call):
                    add(n, set(), "jit")
                elif isinstance(dec, ast.Call) and d.endswith("partial"):
                    if any(_dotted(a).endswith("jit") for a in dec.args):
                        add(n, _static_argnames(dec), "jit")
        elif isinstance(n, ast.Call):
            d = _dotted(n.func)
            tail = d.split(".")[-1]
            # require the lax namespace explicitly: jax.tree.map and
            # friends are host-side maps, not traced control flow
            if tail in _LAX_LOOP_FUNCS and d.endswith("lax." + tail):
                for a in n.args:
                    r = resolve(a)
                    if r is not None:
                        add(r[0], r[1], f"lax.{tail} body")
            elif tail == "jit" and n.args:
                r = resolve(n.args[0])
                if r is not None:
                    add(r[0], r[1] | _static_argnames(n), "jit")
            elif tail == "pallas_call" and n.args:
                r = resolve(n.args[0])
                if r is not None:
                    add(r[0], r[1], "pallas kernel", pos_only=True)
    return scopes


def _taint_evidence(node: ast.AST, tainted: Set[str]) -> bool:
    """Does this expression observe a traced value?  ``.shape``/``.ndim``/
    ``.dtype``/``len()`` are static under tracing and launder taint."""
    if isinstance(node, ast.Attribute) and node.attr in _TAINT_LAUNDER_ATTRS:
        return False
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "len"):
        return False
    if isinstance(node, ast.Name):
        return node.id in tainted
    return any(_taint_evidence(c, tainted)
               for c in ast.iter_child_nodes(node))


def _mentions_jax(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Name) and n.id in _JAX_NAMESPACES
               for n in ast.walk(node))


def _scan_traced(fn, tainted: Set[str], kind: str, relpath: str,
                 qualname: str, report: AnalysisReport,
                 seen: Set[Tuple[str, int, str]]) -> None:
    site = f"{relpath}:{qualname}"

    def taint_targets(t):
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                taint_targets(e)
        elif isinstance(t, ast.Starred):
            taint_targets(t.value)
        elif isinstance(t, ast.Name):
            tainted.add(t.id)

    def flag(lineno: int, what: str):
        key = (site, lineno, what)
        if key in seen:
            return                         # two-pass scan revisits lines
        seen.add(key)
        _emit(report, "C2-trace", "error", site,
              f"[{kind}] line {lineno}: {what}")

    def scan_expr(e: ast.AST):
        for n in _walk_shallow(e):
            if not isinstance(n, ast.Call):
                continue
            d = _dotted(n.func)
            argv = list(n.args) + [kw.value for kw in n.keywords]
            if (isinstance(n.func, ast.Name)
                    and n.func.id in ("float", "int", "bool")
                    and any(_taint_evidence(a, tainted) for a in argv)):
                flag(n.lineno, f"host-sync coercion {n.func.id}() on a "
                               f"traced value")
            elif (isinstance(n.func, ast.Attribute)
                  and n.func.attr in ("item", "tolist")
                  and _taint_evidence(n.func.value, tainted)):
                flag(n.lineno, f".{n.func.attr}() forces a device sync on a "
                               f"traced value")
            elif (d.startswith("np.")
                  and any(_taint_evidence(a, tainted) for a in argv)):
                flag(n.lineno, f"numpy call {d}() on a traced argument "
                               f"(falls off the trace; use jnp)")

    def walk_body(stmts):
        # two passes so taints assigned later in loops still propagate
        for _ in range(2):
            for stmt in stmts:
                visit(stmt)

    def visit(stmt):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs inside a traced scope trace too (pl.when bodies,
            # scan carriers): inherit the closure taint + own params
            inner = set(tainted) | set(_param_names(stmt))
            _scan_traced(stmt, inner, kind, relpath,
                         f"{qualname}.<locals>.{stmt.name}", report, seen)
            return
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            val = stmt.value
            if val is not None:
                scan_expr(val)
                if _taint_evidence(val, tainted) or _mentions_jax(val):
                    targets = (stmt.targets if isinstance(stmt, ast.Assign)
                               else [stmt.target])
                    for t in targets:
                        taint_targets(t)
            return
        if isinstance(stmt, (ast.If, ast.While)):
            scan_expr(stmt.test)
            if _taint_evidence(stmt.test, tainted):
                flag(stmt.lineno,
                     "Python branch on a traced value (concretizes the "
                     "tracer; use lax.cond/jnp.where)")
            walk_body(stmt.body)
            walk_body(stmt.orelse)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            scan_expr(stmt.iter)
            if _taint_evidence(stmt.iter, tainted):
                flag(stmt.lineno, "Python loop over a traced value")
                taint_targets(stmt.target)
            walk_body(stmt.body)
            walk_body(stmt.orelse)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                scan_expr(item.context_expr)
            walk_body(stmt.body)
            return
        if isinstance(stmt, ast.Try):
            walk_body(stmt.body)
            for h in stmt.handlers:
                walk_body(h.body)
            walk_body(stmt.orelse)
            walk_body(stmt.finalbody)
            return
        scan_expr(stmt)

    walk_body(fn.body)


def _check_trace(relpath: str, tree: ast.Module,
                 qualnames: Dict[int, str],
                 report: AnalysisReport) -> None:
    seen: Set[Tuple[str, int, str]] = set()
    for fn, tainted, kind in _traced_scopes(tree):
        qn = qualnames.get(id(fn), getattr(fn, "name", "<lambda>"))
        if isinstance(fn, ast.Lambda):
            body = ast.Expr(value=fn.body)
            ast.copy_location(body, fn.body)
            wrapper = ast.FunctionDef(
                name="<lambda>", args=fn.args, body=[body],
                decorator_list=[], returns=None, type_comment=None)
            ast.copy_location(wrapper, fn)
            _scan_traced(wrapper, set(tainted), kind, relpath, qn, report,
                         seen)
        else:
            _scan_traced(fn, set(tainted), kind, relpath, qn, report, seen)


# ======================================================================
# C3 — compat bypass
# ======================================================================

def _check_compat(relpath: str, tree: ast.Module,
                  report: AnalysisReport) -> None:
    if relpath.endswith("compat.py"):
        return
    site = f"{relpath}"
    flagged: Set[Tuple[int, str]] = set()

    def flag(lineno: int, what: str):
        if (lineno, what) in flagged:
            return
        flagged.add((lineno, what))
        _emit(report, "C3-compat", "error", site,
              f"line {lineno}: {what} — route through repro.compat "
              f"(the jax 0.4.x/modern shim layer)")

    for n in ast.walk(tree):
        if isinstance(n, ast.ImportFrom):
            mod = n.module or ""
            if mod == "jax.experimental.shard_map":
                flag(n.lineno, "direct jax.experimental.shard_map import")
            elif mod == "jax.sharding" and any(
                    a.name == "Mesh" for a in n.names):
                flag(n.lineno, "direct jax.sharding.Mesh import")
            elif mod == "jax" and any(
                    a.name in ("shard_map", "make_mesh") for a in n.names):
                flag(n.lineno, f"direct jax.{n.names[0].name} import")
        elif isinstance(n, ast.Attribute):
            d = _dotted(n)
            if n.attr in ("TPUCompilerParams", "CompilerParams") \
                    and "pltpu" in d.split("."):
                flag(n.lineno, f"direct {d} compiler-params construction")
            elif d in ("jax.shard_map", "jax.make_mesh",
                       "jax.sharding.Mesh", "jax.experimental.shard_map"):
                flag(n.lineno, f"direct {d} usage")


# ======================================================================
# C4 — dispatch-shape discipline
# ======================================================================

def _check_dispatch(relpath: str, tree: ast.Module,
                    qualnames: Dict[int, str],
                    report: AnalysisReport) -> None:
    # enclosing-function map for every call node
    encl: Dict[int, str] = {}

    def assign_encl(fn, qn):
        for n in _walk_shallow(fn):
            encl[id(n)] = qn

    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            assign_encl(n, qualnames.get(id(n), n.name))

    for n in ast.walk(tree):
        if not isinstance(n, ast.Call):
            continue
        tail = _dotted(n.func).split(".")[-1]
        qn = encl.get(id(n), "<module>")
        site = f"{relpath}:{qn}"
        if tail == "pack_beam":
            k_arg = None
            if len(n.args) >= 2:
                k_arg = n.args[1]
            else:
                for kw in n.keywords:
                    if kw.arg == "k_max":
                        k_arg = kw.value
            if k_arg is None:
                continue
            ok = False
            for sub in ast.walk(k_arg):
                if isinstance(sub, ast.Call) \
                        and _dotted(sub.func).split(".")[-1] == "bucket_k":
                    ok = True
                elif (isinstance(sub, ast.Name) and sub.id == "k_max") or \
                        (isinstance(sub, ast.Attribute)
                         and sub.attr == "k_max"):
                    ok = True
            if not ok and isinstance(k_arg, ast.Name):
                # local assigned from bucket_k(...) earlier in the function
                for fn_node in ast.walk(tree):
                    if isinstance(fn_node, (ast.FunctionDef,
                                            ast.AsyncFunctionDef)) \
                            and qualnames.get(id(fn_node)) == qn:
                        for a in _walk_shallow(fn_node):
                            if (isinstance(a, ast.Assign)
                                    and isinstance(a.value, ast.Call)
                                    and _dotted(a.value.func).split(".")[-1]
                                    == "bucket_k"
                                    and any(isinstance(t, ast.Name)
                                            and t.id == k_arg.id
                                            for t in a.targets)):
                                ok = True
            if not ok:
                _emit(report, "C4-dispatch", "error", site,
                      f"line {n.lineno}: pack_beam k argument does not flow "
                      f"through bucket_k/k_max — unbounded compile shapes "
                      f"for the jitted kernels downstream")
        elif tail in _JIT_ENTRYPOINT_WRAPPERS:
            allowed = _JIT_ENTRYPOINT_WRAPPERS[tail]
            if not any(relpath.endswith(mod)
                       and (qn == f or qn.endswith("." + f))
                       for mod, f in allowed):
                _emit(report, "C4-dispatch", "error", site,
                      f"line {n.lineno}: direct call into jitted entrypoint "
                      f"{tail}() outside its blessed wrapper "
                      f"{[f'{m}:{f}' for m, f in allowed]} — bypasses "
                      f"bucketing and shape discipline")


# ======================================================================
# driver
# ======================================================================

def _emit(report: AnalysisReport, rule: str, severity: str, site: str,
          detail: str) -> None:
    just = BASELINE.get((rule, site))
    if just is not None:
        report.meta.setdefault("baselined", []).append(
            {"rule": rule, "site": site, "detail": detail,
             "justification": just})
        return
    report.add(rule, severity, site, detail)


def _index_functions(tree: ast.Module):
    """[(qualname, node)] for every def, plus an id->qualname map."""
    out: List[Tuple[str, ast.AST]] = []
    qualnames: Dict[int, str] = {}

    def walk(node, prefix):
        for c in ast.iter_child_nodes(node):
            if isinstance(c, ast.ClassDef):
                walk(c, f"{prefix}{c.name}.")
            elif isinstance(c, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = f"{prefix}{c.name}"
                out.append((qn, c))
                qualnames[id(c)] = qn
                walk(c, f"{qn}.<locals>.")
            else:
                walk(c, prefix)

    walk(tree, "")
    return out, qualnames


def check_source(src: str, relpath: str,
                 report: Optional[AnalysisReport] = None) -> AnalysisReport:
    """Run C1–C4 over one module's source text (tests feed snippets here
    with a crafted ``relpath`` to select the rule scope)."""
    if report is None:
        report = AnalysisReport()
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        report.add("C0-syntax", "error", relpath, str(e))
        return report
    functions, qualnames = _index_functions(tree)
    for rule in MUTATION_RULES:
        if any(relpath.endswith(m) for m in rule.modules):
            _check_mutation_rule(rule, relpath, functions, report)
    _check_trace(relpath, tree, qualnames, report)
    _check_compat(relpath, tree, report)
    _check_dispatch(relpath, tree, qualnames, report)
    return report


def check_tree(root: Optional[Path] = None) -> AnalysisReport:
    """Run the checker over every module under ``src/repro``."""
    if root is None:
        root = Path(__file__).resolve().parent
    report = AnalysisReport()
    files = sorted(p for p in root.rglob("*.py")
                   if "__pycache__" not in p.parts)
    for p in files:
        relpath = p.relative_to(root).as_posix()
        check_source(p.read_text(), relpath, report)
    report.meta["files_checked"] = len(files)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.staticcheck",
        description="Cache-coherence & trace-discipline static checker "
                    "(rules C1-C4) over the runtime source.")
    ap.add_argument("--root", default=None,
                    help="package root to scan (default: the installed "
                         "repro package directory)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the report as JSON ('-' for stdout)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 2 when any finding is an error")
    args = ap.parse_args(argv)

    report = check_tree(Path(args.root) if args.root else None)
    print(report.render())
    base = report.meta.get("baselined", [])
    print(f"({report.meta.get('files_checked', 0)} files checked, "
          f"{len(base)} baselined site(s))")
    for b in base:
        print(f"  baselined {b['rule']} @ {b['site']}: {b['justification']}")
    if args.json:
        payload = json.dumps(report.to_json(), indent=2)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w") as fh:
                fh.write(payload + "\n")
    return exit_code(report, strict=args.strict)


if __name__ == "__main__":
    sys.exit(main())
