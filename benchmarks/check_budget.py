"""NON-BLOCKING scheduler-overhead budget check for CI.

Compares the ``scheduler/tick_sweep_*`` rows of a bench JSON (written by
``benchmarks/run.py --json``) against the checked-in baseline
(``benchmarks/baselines/scheduler_sweep.json``) and the absolute
µs/tick/episode budget.  Regressions >2x the baseline — and budget
breaches — are emitted as GitHub ``::warning::`` annotations so they show
up on the PR without failing the job (bench boxes are noisy; a hard gate
on wall time would flake).

Always exits 0.  Usage:

    python benchmarks/check_budget.py bench-smoke.json
"""
from __future__ import annotations

import json
import os
import re
import sys

REGRESSION_FACTOR = 2.0
# the admission warm-start (ISSUE 8) must keep paying for itself: the
# bench's warmoff/warm us-per-admit ratio at c>=64 dropping to ~1x means
# the signature replay + static-terms cache stopped hitting
WARM_CUT_MIN = 1.1
# speculative reasoning steps (ISSUE 9): passengers are free by
# construction, but a drifting pattern table shows up as a squash-rate
# spike (slots burned on dead predictions) or as the specstep row losing
# its lead over the plain batched row on the edge box
SPEC_SQUASH_MAX = 0.8
BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "scheduler_sweep.json")
KNEE_BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                             "serving_knee.json")


def _derived_num(row, key: str):
    m = re.search(rf"\b{re.escape(key)}=([0-9.]+)", row.get("derived", ""))
    return float(m.group(1)) if m else None


def check_spec_steps(rows) -> list:
    """Non-blocking watch over the serving bench's specstep rows: warn
    when the squash rate spikes or the edge-box specstep cell stops
    beating the plain batched cell it free-rides on."""
    warnings = []
    by_name = {r.get("name", ""): r for r in rows}
    for r in rows:
        name = r.get("name", "")
        if "specstep" not in name or not name.startswith("serving/"):
            continue
        m = re.search(r"\bspec_acc=(\d+)/(\d+)", r.get("derived", ""))
        if m:
            acc, sub = int(m.group(1)), int(m.group(2))
            squash_rate = 1.0 - acc / sub if sub else 0.0
            if sub and squash_rate > SPEC_SQUASH_MAX:
                warnings.append(
                    f"{name}: spec-step squash rate {squash_rate:.2f} "
                    f"({sub - acc}/{sub} non-accepted) exceeds "
                    f"{SPEC_SQUASH_MAX} — the mined table's predictions "
                    f"are mostly dead on arrival")
    spec = by_name.get("serving/thor_c8_bpaste+memo+batch+specstep")
    plain = by_name.get("serving/thor_c8_bpaste+memo+batch")
    if spec and plain:
        ms, mp = _derived_num(spec, "makespan"), _derived_num(plain,
                                                              "makespan")
        if ms is not None and mp is not None and ms >= mp:
            warnings.append(
                f"thor_c8 specstep makespan {ms:.1f} no longer beats the "
                f"plain batched cell ({mp:.1f}) — idle-slot drafts have "
                f"stopped paying")
        slow = _derived_num(spec, "mean_auth_slowdown")
        if slow is not None and slow > 1.0:
            warnings.append(
                f"thor_c8 specstep mean_auth_slowdown={slow:.3f} — "
                f"passengers must ride free (expected exactly 1.000)")
    return warnings


def check_knee(rows, knee_base) -> list:
    """Non-blocking watch over the open-loop sweep (ISSUE 10): warn when
    a mode's saturation knee regresses below the checked-in baseline,
    when the full bpaste stack no longer sustains at least the serial
    knee, or when any swept rate taxes authoritative work (the shed
    ladder must price out speculation strictly before QoS suffers)."""
    warnings = []
    base_knees = knee_base.get("knees", {})
    knees = {}
    for r in rows:
        name = r.get("name", "")
        if name.startswith("serving/open_knee_"):
            label = name[len("serving/open_knee_"):]
            knee = _derived_num(r, "knee_rate")
            if knee is not None:
                knees[label] = knee
                ref = base_knees.get(label)
                if ref is not None and knee < ref:
                    warnings.append(
                        f"{name}: saturation knee {knee:g} eps/s is below "
                        f"the checked-in baseline ({ref:g}) — sustainable "
                        f"load under the p95-sojourn SLO regressed")
        elif name.startswith("serving/open_"):
            slow = _derived_num(r, "mean_auth_slowdown")
            if slow is not None and slow > 1.0:
                warnings.append(
                    f"{name}: mean_auth_slowdown={slow:.3f} under open-loop "
                    f"load — speculation must shed before authoritative "
                    f"work slows (expected exactly 1.000)")
            qos = _derived_num(r, "qos_violations")
            if qos:
                warnings.append(
                    f"{name}: {qos:g} QoS violations under open-loop load "
                    f"— the shedding ladder failed to protect "
                    f"authoritative deadlines")
    stack, serial = knees.get("bpaste+stack"), knees.get("serial")
    if stack is not None and serial is not None and stack < serial:
        warnings.append(
            f"open-loop sweep: bpaste+stack knee ({stack:g} eps/s) fell "
            f"below the serial knee ({serial:g}) — the stack no longer "
            f"buys sustained-load headroom")
    return warnings


def check(rows, baseline) -> list:
    warnings = []
    base = {r["name"]: r["us_per_call"] for r in baseline.get("rows", [])}
    budget = baseline.get("budget_us_per_tick_episode", 50.0)
    for r in rows:
        name = r.get("name", "")
        if name.startswith("scheduler/warm_admit_cut_"):
            cut = r.get("admit_cut", 0.0)
            if cut and cut < WARM_CUT_MIN:
                warnings.append(
                    f"{name}: warm-start admission cut is only "
                    f"{cut:.2f}x (expected >= {WARM_CUT_MIN}x) — the "
                    f"per-hid static-terms cache is no longer paying")
            continue
        if not name.startswith("scheduler/tick_sweep_") or r.get("skipped"):
            continue
        if "speedup" in name:
            continue
        us = r.get("us_per_call", 0.0)
        ref = base.get(name)
        if ref and us > REGRESSION_FACTOR * ref:
            warnings.append(
                f"{name}: {us:.1f} us/tick/episode is "
                f"{us / ref:.1f}x the checked-in baseline ({ref:.1f})")
        # The budget is an AT-SCALE target: small-c cells divide the
        # per-tick fixed costs (one jitted score dispatch, one admission
        # pass) over a handful of episodes, so only cells at c >= 256 —
        # where those costs amortize and the dirty-set machinery is the
        # residual — are held to it.  Small-c cells are still covered by
        # the baseline-regression check above.
        if (r.get("scheduler") == "event" and r.get("c", 0) >= 256
                and us > budget):
            warnings.append(
                f"{name}: {us:.1f} us/tick/episode exceeds the "
                f"{budget:.0f}us budget")
    return warnings


def main() -> int:
    if len(sys.argv) != 2:
        print(f"usage: {sys.argv[0]} BENCH_JSON", file=sys.stderr)
        return 0                              # non-blocking by contract
    try:
        with open(sys.argv[1]) as f:
            rows = json.load(f)
        with open(BASELINE) as f:
            baseline = json.load(f)
    except (OSError, ValueError) as e:
        print(f"::warning::budget check skipped: {e}")
        return 0
    try:
        with open(KNEE_BASELINE) as f:
            knee_base = json.load(f)
    except (OSError, ValueError):
        knee_base = {}
    warnings = (check(rows, baseline) + check_spec_steps(rows)
                + check_knee(rows, knee_base))
    for w in warnings:
        print(f"::warning::{w}")
    if not warnings:
        print("scheduler overhead within budget and baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
