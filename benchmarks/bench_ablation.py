"""Ablations of the B-PASTE objective (paper §5): knock out each EU term
and sweep λ/μ, measuring end-to-end speedup on the Thor-class profile.
Demonstrates that the *composition* (q · (ΔO + λΔU − μΔI)) matters, not
just raw probability ranking."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.events import ResourceVector
from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import run_mode
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes

THOR = Machine(ResourceVector(cpu=6, mem_bw=50, io=200, accel=1))
TIGHT = Machine(ResourceVector(cpu=3, mem_bw=20, io=80, accel=1))


def run() -> List[Dict]:
    train_eps = make_episodes(WorkloadConfig(seed=1, n_episodes=60))
    engine = PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train_eps))
    test_eps = make_episodes(WorkloadConfig(seed=42, n_episodes=12))
    rows = []
    serial = run_mode(test_eps, engine, "serial", THOR, seed=7).makespan
    serial_t = run_mode(test_eps, engine, "serial", TIGHT, seed=7,
                        max_concurrent_episodes=3).makespan

    variants = [
        ("full", dict(lam=0.5, mu=1.0)),
        ("no_unlock", dict(lam=0.0, mu=1.0)),     # ΔU knocked out
        ("no_interference", dict(lam=0.5, mu=0.0)),  # ΔI knocked out
        ("lam2", dict(lam=2.0, mu=1.0)),
        ("mu4", dict(lam=0.5, mu=4.0)),           # over-cautious
    ]
    for name, kw in variants:
        t0 = time.perf_counter()
        m = run_mode(test_eps, engine, "bpaste", THOR, seed=7, **kw)
        m_t = run_mode(test_eps, engine, "bpaste", TIGHT, seed=7,
                       max_concurrent_episodes=3, **kw)
        wall = time.perf_counter() - t0
        rows.append({
            "name": f"ablation/{name}",
            "us_per_call": wall * 1e6,
            "derived": (
                f"thor_speedup={serial/m.makespan:.3f} "
                f"tight_speedup={serial_t/m_t.makespan:.3f} "
                f"waste={m.summary()['wasted_frac']:.2f} "
                f"tight_waste={m_t.summary()['wasted_frac']:.2f}"
            ),
        })

    # beam width sweep (bounded-search sensitivity)
    for k in (1, 2, 4, 8):
        m = run_mode(test_eps, engine, "bpaste", THOR, seed=7, beam_k=k)
        rows.append({
            "name": f"ablation/beam_k{k}",
            "us_per_call": 0.0,
            "derived": f"speedup={serial/m.makespan:.3f} reuse={m.reuses} promo={m.promotions}",
        })
    return rows
