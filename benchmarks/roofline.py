"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Per (arch × shape × mesh): the three roofline terms in seconds, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS useful-compute ratio, and a one-line
lever.  Writes markdown (for EXPERIMENTS.md §Roofline) and emits CSV rows
for benchmarks.run."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

LEVERS = {
    "compute": "raise arithmetic efficiency: fused kernels / lower remat recompute",
    "memory": "cut HBM traffic: KV/cache layout, quantized cache, larger per-step batch",
    "collective": "reshard to cut all-reduce bytes: 2D TP, comm/compute overlap, bf16 collectives",
}


def load(results_dir: str = "results/dryrun") -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def markdown_table(recs: List[Dict], mesh: str = "single") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_term_s']:.4g} | "
            f"{r['memory_term_s']:.4g} | {r['collective_term_s']:.4g} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def run() -> List[Dict]:
    recs = load()
    rows = []
    for r in recs:
        if r["mesh"] != "single":
            continue
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "us_per_call": max(r["compute_term_s"], r["memory_term_s"],
                               r["collective_term_s"]) * 1e6,
            "derived": (
                f"bottleneck={r['bottleneck']} "
                f"c={r['compute_term_s']:.3g} m={r['memory_term_s']:.3g} "
                f"x={r['collective_term_s']:.3g} useful={r['useful_flops_ratio']:.2f}"
            ),
        })
    return rows


if __name__ == "__main__":
    recs = load()
    print(markdown_table(recs, "single"))
