"""Concurrent-episode serving sweep: the shared cross-episode beam under
multi-tenant load.

Grid: ``max_concurrent_episodes`` x mode (serial / paste / bpaste) on the
default motif-variant workload with staggered tenant arrivals.  Per cell:
makespan, p95 service latency, p95 sojourn (ARRIVAL -> completion —
queueing delay included, the metric concurrency actually buys down: a
tenant that waited 400s for a slot and ran 40s did not experience 40s of
latency), mean authoritative slowdown, QoS violations, and the worst
single tenant's mean slowdown (the pooled mean can hide one starved
tenant — fairness is judged on the worst).

Headline row: bpaste at concurrency 4 vs serial at the same concurrency —
the shared-beam admission must buy makespan without letting speculation tax
authoritative work (mean_auth_slowdown <= 1.05 on the default workload).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import run_mode
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes


def _fit_engine(n_train: int) -> PatternEngine:
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=n_train))
    return PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))


def run(smoke: bool = False) -> List[Dict]:
    n_train, n_test = (20, 4) if smoke else (60, 12)
    concurrencies = [1, 4] if smoke else [1, 2, 4, 8]
    modes = ["serial", "bpaste"] if smoke else ["serial", "paste", "bpaste"]
    engine = _fit_engine(n_train)
    test = make_episodes(WorkloadConfig(seed=42, n_episodes=n_test,
                                        arrival_stagger=4.0))
    rows: List[Dict] = []
    cells: Dict = {}
    for conc in concurrencies:
        for mode in modes:
            m = run_mode(test, engine, mode, Machine(), seed=7,
                         max_concurrent_episodes=conc)
            s = m.summary()
            cells[(mode, conc)] = s
            worst = s["worst_tenant_slowdown"]
            trunc = " TRUNCATED" if s["truncated"] else ""
            rows.append({
                "name": f"serving/{mode}_c{conc}",
                "us_per_call": 0.0,
                "derived": (f"makespan={s['makespan']:.1f} "
                            f"p95_latency={s['p95_latency']:.1f} "
                            f"p95_sojourn={s['p95_sojourn']:.1f} "
                            f"mean_auth_slowdown={s['mean_auth_slowdown']:.3f} "
                            f"qos_violations={s['qos_violations']:.0f} "
                            f"worst_tenant_slowdown={worst:.3f}{trunc}"),
            })
    if ("bpaste", 4) in cells and ("serial", 4) in cells:
        bp, sr = cells[("bpaste", 4)], cells[("serial", 4)]
        rows.append({
            "name": "serving/bpaste_c4_vs_serial_c4",
            "us_per_call": 0.0,
            "derived": (
                f"makespan {sr['makespan']:.1f}->{bp['makespan']:.1f} "
                f"({sr['makespan'] / max(bp['makespan'], 1e-9):.3f}x) "
                f"mean_auth_slowdown={bp['mean_auth_slowdown']:.3f} "
                f"(target<=1.05) p95_sojourn {sr['p95_sojourn']:.1f}->"
                f"{bp['p95_sojourn']:.1f}"),
        })
    return rows
