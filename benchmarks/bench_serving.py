"""Concurrent-episode serving sweep: the shared cross-episode beam, the
cross-episode result store, and the batched model-step service under
multi-tenant load.

Grid: ``max_concurrent_episodes`` x mode (serial / paste / bpaste /
bpaste+memo) on the shared-corpus serving workload (staggered tenant
arrivals, ``shared_frac`` of tenants working subjects from a small shared
pool — the corpus-overlap regime cross-tenant result caching targets).
Per cell: makespan, p95 service latency, p95 sojourn (ARRIVAL ->
completion — queueing delay included, the metric concurrency actually buys
down), mean authoritative slowdown, QoS violations, result-store serves,
and the worst single tenant's mean slowdown.

Machine: PR 3 ran this sweep on the Thor edge box (accel=1), where c >= 4
is ACCELERATOR-bound — eight concurrent model steps queue on one slot, so
every scheduler converges on the model-step floor and no tool-level
mechanism (speculative execution OR result serving) can move makespan.
The grid itself runs on a serving box with 4 accelerator slots, where c=8
is genuinely work-saturated but TOOL-bound — the regime the result store
exists for.

The ``thor_c8`` rows are PR 5's headline: the batched model-step service
(``RuntimeConfig.model_max_batch``, model_service.py) coalesces concurrent
episodes' reasoning steps into micro-batched model invocations, which is
the only lever that can move an accel-bound box — it compresses the
model-step queue itself and the reclaimed accelerator time becomes slack
speculation can spend.  ``serial+batch`` isolates the infra win (batching
alone); ``bpaste+memo+batch`` stacks speculation + the result store on the
recovered slack.  The previously-converged cells (277.4 = 277.4 in PR 4)
must SEPARATE: bpaste+memo+batch > serial+batch > serial = bpaste+memo,
with ``mean_auth_slowdown <= 1.05`` and zero QoS violations per batch —
batching never weakens the authoritative-protection invariant.

``max_batch=1`` rows are the pinned baseline: the service's solo fast path
is a synchronous pass-through, regression-tested bit-identical in
tests/test_model_service.py.

The ``bpaste+memo+batch+specstep`` row is PR 9's headline: batch slots
that would otherwise dispatch under-full carry speculative reasoning
steps — drafts of upcoming reasoning boundaries predicted from the
hypothesis trees' spines (runtime.py `_submit_spec_step`,
model_service.py `submit_speculative`).  Passengers ride free (batch
duration is set by authoritative works only) and validate on arrival, so
the row must show ``mean_auth_slowdown=1.000`` and zero QoS violations
while beating the plain ``+batch`` makespan.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.events import ResourceVector
from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import run_mode
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes

# 12-core / 4-accelerator serving box: c=8 saturates on tool work, not on
# the model-step queue (see module docstring)
SERVE_BOX = Machine(ResourceVector(cpu=12, mem_bw=100, io=500, accel=4))
THOR_BOX = Machine()                      # PR 3's edge box (accel=1)

# micro-batch cap for the "+batch" rows; linger/marginal ride on the
# RuntimeConfig defaults (1.5 s window, 0.3 marginal — see DESIGN.md)
BATCH = 8

# mode label -> (runtime mode, memo enabled, model_max_batch, spec steps).
# NOTE: the runtime DEFAULT is memo=True (the store is part of the shipped
# system, and every other bench measures bpaste with it on); this grid's
# plain "paste"/"bpaste" rows disable it explicitly so the "+memo" column
# isolates the store's contribution — same scheduler, store off vs on.
# The "+batch" rows raise model_max_batch the same way: same scheduler and
# store, batched vs serial model-step queue.  The "+specstep" row then
# fills the batch slots that would otherwise dispatch under-full with
# speculative reasoning steps (RuntimeConfig.spec_model_steps) — same
# scheduler, store, and batch cap, idle slots riding free vs wasted.
MODES = {
    "serial": ("serial", False, 1, False),
    "paste": ("paste", False, 1, False),
    "bpaste": ("bpaste", False, 1, False),
    "bpaste+memo": ("bpaste", True, 1, False),
    "serial+batch": ("serial", False, BATCH, False),
    "bpaste+memo+batch": ("bpaste", True, BATCH, False),
    "bpaste+memo+batch+specstep": ("bpaste", True, BATCH, True),
}


def _fit_engine(n_train: int) -> PatternEngine:
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=n_train))
    return PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))


def _cell(test, engine, label: str, conc: int, machine) -> Dict:
    mode, memo, max_batch, spec = MODES[label]
    m = run_mode(test, engine, mode, machine, seed=7,
                 max_concurrent_episodes=conc, memo=memo,
                 model_max_batch=max_batch, spec_model_steps=spec)
    s = m.summary()
    return s


def _row(name: str, s: Dict) -> Dict:
    trunc = " TRUNCATED" if s["truncated"] else ""
    # batch-service columns whenever the batched path ran — gated on queue
    # activity, not occupancy>=2, so an all-singleton batching run still
    # shows the linger tax its tenants paid; max_batch=1 rows (no queue,
    # no delay) stay textually identical to the pre-service bench
    batch = ""
    if (s.get("model_batched_steps", 0) > 0
            or s.get("model_queue_delay_seconds", 0.0) > 0):
        batch = (f" model_batch_occ={s['model_batch_occupancy']:.2f} "
                 f"model_qdelay={s['mean_model_queue_delay']:.2f}")
    if s.get("spec_steps_submitted", 0) > 0:
        batch += (f" spec_acc={s['spec_steps_accepted']:.0f}"
                  f"/{s['spec_steps_submitted']:.0f} "
                  f"spec_saved={s['spec_step_saved_seconds']:.1f} "
                  f"spec_fill={s['spec_slot_fill']:.2f}")
    return {
        "name": name,
        "us_per_call": 0.0,
        "derived": (f"makespan={s['makespan']:.1f} "
                    f"p95_latency={s['p95_latency']:.1f} "
                    f"p95_sojourn={s['p95_sojourn']:.1f} "
                    f"mean_auth_slowdown={s['mean_auth_slowdown']:.3f} "
                    f"qos_violations={s['qos_violations']:.0f} "
                    f"memo_serves={s['memo_serves']:.0f} "
                    f"memo_saved={s['memo_saved_seconds']:.1f} "
                    f"worst_tenant_slowdown={s['worst_tenant_slowdown']:.3f}"
                    f"{batch}{trunc}"),
    }


def _compare_row(name: str, base: Dict, new: Dict) -> Dict:
    return {
        "name": name,
        "us_per_call": 0.0,
        "derived": (
            f"makespan {base['makespan']:.1f}->{new['makespan']:.1f} "
            f"({base['makespan'] / max(new['makespan'], 1e-9):.3f}x) "
            f"p95_sojourn {base['p95_sojourn']:.1f}->"
            f"{new['p95_sojourn']:.1f} "
            f"({base['p95_sojourn'] / max(new['p95_sojourn'], 1e-9):.3f}x) "
            f"mean_auth_slowdown={new['mean_auth_slowdown']:.3f} "
            f"(target<=1.05)"),
    }


def run(smoke: bool = False) -> List[Dict]:
    n_train, n_test = (20, 8) if smoke else (60, 16)
    concurrencies = [1, 8] if smoke else [1, 2, 4, 8]
    labels = (["serial", "bpaste", "bpaste+memo"] if smoke
              else ["serial", "paste", "bpaste", "bpaste+memo"])
    # PR 5 headline cells: the accel=1 edge box at c=8 — model-step-bound,
    # converged for every tool-level mechanism (PR 3/4) — re-run with the
    # model-step queue batched.  In the smoke tier too: these are the rows
    # CI's bench-smoke artifact tracks for the separation regression.
    thor_labels = (["serial", "bpaste+memo", "bpaste+memo+batch",
                    "bpaste+memo+batch+specstep"] if smoke
                   else ["serial", "serial+batch", "bpaste+memo",
                         "bpaste+memo+batch",
                         "bpaste+memo+batch+specstep"])
    engine = _fit_engine(n_train)
    test = make_episodes(WorkloadConfig(seed=42, n_episodes=n_test,
                                        arrival_stagger=4.0,
                                        shared_frac=0.5, shared_pool=2))
    rows: List[Dict] = []
    cells: Dict = {}
    for conc in concurrencies:
        for label in labels:
            s = _cell(test, engine, label, conc, SERVE_BOX)
            cells[(label, conc)] = s
            rows.append(_row(f"serving/{label}_c{conc}", s))
    thor: Dict = {}
    for label in thor_labels:
        s = _cell(test, engine, label, 8, THOR_BOX)
        thor[label] = s
        rows.append(_row(f"serving/thor_c8_{label}", s))
    if ("bpaste+memo", 8) in cells and ("serial", 8) in cells:
        rows.append(_compare_row("serving/memo_c8_vs_serial_c8",
                                 cells[("serial", 8)],
                                 cells[("bpaste+memo", 8)]))
    if ("bpaste+memo", 8) in cells and ("bpaste", 8) in cells:
        rows.append(_compare_row("serving/memo_c8_vs_bpaste_c8",
                                 cells[("bpaste", 8)],
                                 cells[("bpaste+memo", 8)]))
    if ("bpaste+memo", 4) in cells and ("serial", 4) in cells:
        rows.append(_compare_row("serving/memo_c4_vs_serial_c4",
                                 cells[("serial", 4)],
                                 cells[("bpaste+memo", 4)]))
    # the separation the batched model-step service buys on the edge box
    if "bpaste+memo+batch" in thor and "serial" in thor:
        rows.append(_compare_row("serving/thor_c8_batch_vs_serial",
                                 thor["serial"], thor["bpaste+memo+batch"]))
    if "bpaste+memo+batch" in thor and "serial+batch" in thor:
        rows.append(_compare_row("serving/thor_c8_batch_vs_serial_batch",
                                 thor["serial+batch"],
                                 thor["bpaste+memo+batch"]))
    # the latency speculative reasoning steps reclaim from under-full
    # batch dispatches (PR 9 headline: idle slots ride free)
    if ("bpaste+memo+batch+specstep" in thor
            and "bpaste+memo+batch" in thor):
        rows.append(_compare_row("serving/thor_c8_specstep_vs_batch",
                                 thor["bpaste+memo+batch"],
                                 thor["bpaste+memo+batch+specstep"]))
    return rows
