"""Concurrent-episode serving sweep: the shared cross-episode beam, the
cross-episode result store, and the batched model-step service under
multi-tenant load.

Grid: ``max_concurrent_episodes`` x mode (serial / paste / bpaste /
bpaste+memo) on the shared-corpus serving workload (staggered tenant
arrivals, ``shared_frac`` of tenants working subjects from a small shared
pool — the corpus-overlap regime cross-tenant result caching targets).
Per cell: makespan, p95 service latency, p95 sojourn (ARRIVAL ->
completion — queueing delay included, the metric concurrency actually buys
down), mean authoritative slowdown, QoS violations, result-store serves,
and the worst single tenant's mean slowdown.

Machine: PR 3 ran this sweep on the Thor edge box (accel=1), where c >= 4
is ACCELERATOR-bound — eight concurrent model steps queue on one slot, so
every scheduler converges on the model-step floor and no tool-level
mechanism (speculative execution OR result serving) can move makespan.
The grid itself runs on a serving box with 4 accelerator slots, where c=8
is genuinely work-saturated but TOOL-bound — the regime the result store
exists for.

The ``thor_c8`` rows are PR 5's headline: the batched model-step service
(``RuntimeConfig.model_max_batch``, model_service.py) coalesces concurrent
episodes' reasoning steps into micro-batched model invocations, which is
the only lever that can move an accel-bound box — it compresses the
model-step queue itself and the reclaimed accelerator time becomes slack
speculation can spend.  ``serial+batch`` isolates the infra win (batching
alone); ``bpaste+memo+batch`` stacks speculation + the result store on the
recovered slack.  The previously-converged cells (277.4 = 277.4 in PR 4)
must SEPARATE: bpaste+memo+batch > serial+batch > serial = bpaste+memo,
with ``mean_auth_slowdown <= 1.05`` and zero QoS violations per batch —
batching never weakens the authoritative-protection invariant.

``max_batch=1`` rows are the pinned baseline: the service's solo fast path
is a synchronous pass-through, regression-tested bit-identical in
tests/test_model_service.py.

The ``bpaste+memo+batch+specstep`` row is PR 9's headline: batch slots
that would otherwise dispatch under-full carry speculative reasoning
steps — drafts of upcoming reasoning boundaries predicted from the
hypothesis trees' spines (runtime.py `_submit_spec_step`,
model_service.py `submit_speculative`).  Passengers ride free (batch
duration is set by authoritative works only) and validate on arrival, so
the row must show ``mean_auth_slowdown=1.000`` and zero QoS violations
while beating the plain ``+batch`` makespan.

The ``serving/open_*`` rows are PR 10's headline: an OPEN-LOOP
goodput-vs-offered-load sweep.  Tenants arrive as a sustained exponential
process (``WorkloadConfig.open_loop_rate``, pulled lazily through
``workload.open_loop_source``) instead of from a frozen roster, and each
mode is swept over offered rates on the edge box until its p95 sojourn
blows through the SLO — the max rate still inside it is the mode's
SATURATION KNEE, the sustained-load number the paper's edge-serving claim
actually rests on.  The full bpaste stack (memo + batch + specstep +
load-shedding admission + adaptive linger) must hold the SLO at a rate
≥ serial's, with ``mean_auth_slowdown=1.000`` at EVERY swept rate —
under overload the shedding ladder prices speculation out (the
``shed=...`` column) before any authoritative QoS violation appears.
``check_budget.py`` watches the knee against
``baselines/serving_knee.json``.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.events import ResourceVector
from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import run_mode
from repro.core.workload import (
    WorkloadConfig, episodes_to_traces, make_episodes, open_loop_source,
)

# 12-core / 4-accelerator serving box: c=8 saturates on tool work, not on
# the model-step queue (see module docstring)
SERVE_BOX = Machine(ResourceVector(cpu=12, mem_bw=100, io=500, accel=4))
THOR_BOX = Machine()                      # PR 3's edge box (accel=1)

# micro-batch cap for the "+batch" rows; linger/marginal ride on the
# RuntimeConfig defaults (1.5 s window, 0.3 marginal — see DESIGN.md)
BATCH = 8

# mode label -> (runtime mode, memo enabled, model_max_batch, spec steps).
# NOTE: the runtime DEFAULT is memo=True (the store is part of the shipped
# system, and every other bench measures bpaste with it on); this grid's
# plain "paste"/"bpaste" rows disable it explicitly so the "+memo" column
# isolates the store's contribution — same scheduler, store off vs on.
# The "+batch" rows raise model_max_batch the same way: same scheduler and
# store, batched vs serial model-step queue.  The "+specstep" row then
# fills the batch slots that would otherwise dispatch under-full with
# speculative reasoning steps (RuntimeConfig.spec_model_steps) — same
# scheduler, store, and batch cap, idle slots riding free vs wasted.
MODES = {
    "serial": ("serial", False, 1, False),
    "paste": ("paste", False, 1, False),
    "bpaste": ("bpaste", False, 1, False),
    "bpaste+memo": ("bpaste", True, 1, False),
    "serial+batch": ("serial", False, BATCH, False),
    "bpaste+memo+batch": ("bpaste", True, BATCH, False),
    "bpaste+memo+batch+specstep": ("bpaste", True, BATCH, True),
}


# ---- open-loop sustained-load sweep (PR 10) --------------------------
# p95-sojourn SLO the knee is judged against: calibrated so the serial
# baseline holds it only at the lightest swept rate on the edge box
# (16 tenants, 4 serving slots) while the full stack holds it 4x further
SLO_P95_SOJOURN = 120.0
OPEN_CONC = 4                 # serving slots: fewer than tenants, so an
                              # arrival backlog (the shedding signal) can
                              # actually form under overload
OPEN_N_TEST = 16
# offered rates (episodes/sec); the knee must land strictly inside the
# swept range for both modes or the report is a lie by truncation
OPEN_RATES_SMOKE = [0.05, 0.1, 0.2]
OPEN_RATES_FULL = [0.05, 0.1, 0.15, 0.2, 0.3]
# sweep mode label -> run_mode kwargs.  "bpaste+stack" is the full ladder:
# store + batched model steps + speculative reasoning steps + load-shedding
# admission + load-aware linger — everything the graceful-degradation
# story needs on at once.
OPEN_MODES = {
    "serial": dict(mode="serial", memo=False),
    "bpaste+stack": dict(mode="bpaste", memo=True, model_max_batch=BATCH,
                         spec_model_steps=True, shed_alpha=1.0,
                         adaptive_linger=True),
}


def _fit_engine(n_train: int) -> PatternEngine:
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=n_train))
    return PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))


def _cell(test, engine, label: str, conc: int, machine) -> Dict:
    mode, memo, max_batch, spec = MODES[label]
    m = run_mode(test, engine, mode, machine, seed=7,
                 max_concurrent_episodes=conc, memo=memo,
                 model_max_batch=max_batch, spec_model_steps=spec)
    s = m.summary()
    return s


def _open_cell(engine, label: str, rate: float) -> Dict:
    """One open-loop cell: sustained arrivals at ``rate`` episodes/sec,
    served from the lazy source.  Adds per-tenant SLO accounting: tenants
    whose ARRIVAL->completion sojourn blew the SLO, and goodput — tenants
    served inside it per second of wall clock."""
    kw = dict(OPEN_MODES[label])
    mode = kw.pop("mode")
    cfg = WorkloadConfig(seed=42, n_episodes=OPEN_N_TEST,
                         open_loop_rate=rate,
                         shared_frac=0.5, shared_pool=2)
    m = run_mode([], engine, mode, THOR_BOX, seed=7,
                 max_concurrent_episodes=OPEN_CONC,
                 episode_source=open_loop_source(cfg), **kw)
    s = m.summary()
    soj = list(m.tenant_sojourn.values())
    viol = sum(1 for x in soj if x > SLO_P95_SOJOURN)
    s["slo_violations"] = viol
    s["goodput"] = (len(soj) - viol) / max(s["makespan"], 1e-9)
    return s


def _open_row(label: str, rate: float, s: Dict) -> Dict:
    trunc = " TRUNCATED" if s["truncated"] else ""
    return {
        "name": f"serving/open_{label}_r{rate:g}",
        "us_per_call": 0.0,
        "derived": (f"offered_rate={rate:.2f} "
                    f"p95_sojourn={s['p95_sojourn']:.1f} "
                    f"goodput={s['goodput']:.4f} "
                    f"slo_violations={s['slo_violations']:.0f} "
                    f"shed_passes={s['shed_passes']:.0f} "
                    f"shed_peak_backlog={s['shed_peak_backlog']:.0f} "
                    f"mean_auth_slowdown={s['mean_auth_slowdown']:.3f} "
                    f"qos_violations={s['qos_violations']:.0f}"
                    f"{trunc}"),
    }


def _knee_row(label: str, rates: List[float], cells: Dict) -> Dict:
    """The mode's saturation knee: the max swept offered rate whose p95
    sojourn still holds the SLO (0 when even the lightest rate blows it)."""
    knee, p95_at_knee = 0.0, 0.0
    for rate in rates:
        s = cells[(label, rate)]
        if s["p95_sojourn"] <= SLO_P95_SOJOURN:
            knee, p95_at_knee = rate, s["p95_sojourn"]
    return {
        "name": f"serving/open_knee_{label}",
        "us_per_call": 0.0,
        "derived": (f"knee_rate={knee:.2f} "
                    f"slo_p95={SLO_P95_SOJOURN:.0f} "
                    f"p95_at_knee={p95_at_knee:.1f} "
                    f"rates_swept={len(rates):.0f}"),
    }


def _row(name: str, s: Dict) -> Dict:
    trunc = " TRUNCATED" if s["truncated"] else ""
    # batch-service columns whenever the batched path ran — gated on queue
    # activity, not occupancy>=2, so an all-singleton batching run still
    # shows the linger tax its tenants paid; max_batch=1 rows (no queue,
    # no delay) stay textually identical to the pre-service bench
    batch = ""
    if (s.get("model_batched_steps", 0) > 0
            or s.get("model_queue_delay_seconds", 0.0) > 0):
        batch = (f" model_batch_occ={s['model_batch_occupancy']:.2f} "
                 f"model_qdelay={s['mean_model_queue_delay']:.2f}")
    if s.get("spec_steps_submitted", 0) > 0:
        batch += (f" spec_acc={s['spec_steps_accepted']:.0f}"
                  f"/{s['spec_steps_submitted']:.0f} "
                  f"spec_saved={s['spec_step_saved_seconds']:.1f} "
                  f"spec_fill={s['spec_slot_fill']:.2f}")
    return {
        "name": name,
        "us_per_call": 0.0,
        "derived": (f"makespan={s['makespan']:.1f} "
                    f"p95_latency={s['p95_latency']:.1f} "
                    f"p95_sojourn={s['p95_sojourn']:.1f} "
                    f"mean_auth_slowdown={s['mean_auth_slowdown']:.3f} "
                    f"qos_violations={s['qos_violations']:.0f} "
                    f"memo_serves={s['memo_serves']:.0f} "
                    f"memo_saved={s['memo_saved_seconds']:.1f} "
                    f"worst_tenant_slowdown={s['worst_tenant_slowdown']:.3f}"
                    f"{batch}{trunc}"),
    }


def _compare_row(name: str, base: Dict, new: Dict) -> Dict:
    return {
        "name": name,
        "us_per_call": 0.0,
        "derived": (
            f"makespan {base['makespan']:.1f}->{new['makespan']:.1f} "
            f"({base['makespan'] / max(new['makespan'], 1e-9):.3f}x) "
            f"p95_sojourn {base['p95_sojourn']:.1f}->"
            f"{new['p95_sojourn']:.1f} "
            f"({base['p95_sojourn'] / max(new['p95_sojourn'], 1e-9):.3f}x) "
            f"mean_auth_slowdown={new['mean_auth_slowdown']:.3f} "
            f"(target<=1.05)"),
    }


def run(smoke: bool = False) -> List[Dict]:
    n_train, n_test = (20, 8) if smoke else (60, 16)
    concurrencies = [1, 8] if smoke else [1, 2, 4, 8]
    labels = (["serial", "bpaste", "bpaste+memo"] if smoke
              else ["serial", "paste", "bpaste", "bpaste+memo"])
    # PR 5 headline cells: the accel=1 edge box at c=8 — model-step-bound,
    # converged for every tool-level mechanism (PR 3/4) — re-run with the
    # model-step queue batched.  In the smoke tier too: these are the rows
    # CI's bench-smoke artifact tracks for the separation regression.
    thor_labels = (["serial", "bpaste+memo", "bpaste+memo+batch",
                    "bpaste+memo+batch+specstep"] if smoke
                   else ["serial", "serial+batch", "bpaste+memo",
                         "bpaste+memo+batch",
                         "bpaste+memo+batch+specstep"])
    engine = _fit_engine(n_train)
    test = make_episodes(WorkloadConfig(seed=42, n_episodes=n_test,
                                        arrival_stagger=4.0,
                                        shared_frac=0.5, shared_pool=2))
    rows: List[Dict] = []
    cells: Dict = {}
    for conc in concurrencies:
        for label in labels:
            s = _cell(test, engine, label, conc, SERVE_BOX)
            cells[(label, conc)] = s
            rows.append(_row(f"serving/{label}_c{conc}", s))
    thor: Dict = {}
    for label in thor_labels:
        s = _cell(test, engine, label, 8, THOR_BOX)
        thor[label] = s
        rows.append(_row(f"serving/thor_c8_{label}", s))
    if ("bpaste+memo", 8) in cells and ("serial", 8) in cells:
        rows.append(_compare_row("serving/memo_c8_vs_serial_c8",
                                 cells[("serial", 8)],
                                 cells[("bpaste+memo", 8)]))
    if ("bpaste+memo", 8) in cells and ("bpaste", 8) in cells:
        rows.append(_compare_row("serving/memo_c8_vs_bpaste_c8",
                                 cells[("bpaste", 8)],
                                 cells[("bpaste+memo", 8)]))
    if ("bpaste+memo", 4) in cells and ("serial", 4) in cells:
        rows.append(_compare_row("serving/memo_c4_vs_serial_c4",
                                 cells[("serial", 4)],
                                 cells[("bpaste+memo", 4)]))
    # the separation the batched model-step service buys on the edge box
    if "bpaste+memo+batch" in thor and "serial" in thor:
        rows.append(_compare_row("serving/thor_c8_batch_vs_serial",
                                 thor["serial"], thor["bpaste+memo+batch"]))
    if "bpaste+memo+batch" in thor and "serial+batch" in thor:
        rows.append(_compare_row("serving/thor_c8_batch_vs_serial_batch",
                                 thor["serial+batch"],
                                 thor["bpaste+memo+batch"]))
    # the latency speculative reasoning steps reclaim from under-full
    # batch dispatches (PR 9 headline: idle slots ride free)
    if ("bpaste+memo+batch+specstep" in thor
            and "bpaste+memo+batch" in thor):
        rows.append(_compare_row("serving/thor_c8_specstep_vs_batch",
                                 thor["bpaste+memo+batch"],
                                 thor["bpaste+memo+batch+specstep"]))
    # open-loop sustained-load sweep: goodput vs offered rate, per-mode
    # saturation knee (PR 10 headline — see module docstring).  In the
    # smoke tier too: the knee rows are what CI's bench-smoke artifact
    # tracks against baselines/serving_knee.json.
    open_rates = OPEN_RATES_SMOKE if smoke else OPEN_RATES_FULL
    open_cells: Dict = {}
    for label in OPEN_MODES:
        for rate in open_rates:
            s = _open_cell(engine, label, rate)
            open_cells[(label, rate)] = s
            rows.append(_open_row(label, rate, s))
        rows.append(_knee_row(label, open_rates, open_cells))
    return rows
