"""Table 1 reproduction: normalized end-to-end latency / speedup on a
Thor-class edge environment (serial baseline vs B-PASTE), plus the PASTE
and naive-parallel baselines the paper positions against."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.events import ResourceVector
from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import run_mode
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes

THOR = Machine(ResourceVector(cpu=6, mem_bw=50, io=200, accel=1))


def run(n_train: int = 60, n_test: int = 12) -> List[Dict]:
    train_eps = make_episodes(WorkloadConfig(seed=1, n_episodes=n_train))
    engine = PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train_eps))
    test_eps = make_episodes(WorkloadConfig(seed=42, n_episodes=n_test))
    rows = []
    base = None
    for mode in ("serial", "paste", "bpaste", "parallel"):
        t0 = time.perf_counter()
        m = run_mode(test_eps, engine, mode, THOR, seed=7)
        wall = time.perf_counter() - t0
        s = m.summary()
        if mode == "serial":
            base = s["makespan"]
        rows.append({
            "name": f"table1/{mode}",
            "us_per_call": wall * 1e6 / max(len(test_eps), 1),
            "derived": (
                f"norm_latency={s['makespan']/base:.3f} "
                f"speedup={base/s['makespan']:.3f} "
                f"promo={s['promotions']} reuse={s['reuses']} "
                f"waste={s['wasted_frac']:.2f}"
            ),
        })
    return rows
