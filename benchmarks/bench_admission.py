"""Scheduler-overhead-per-tick: fused one-dispatch ``admit_beam`` vs the
per-iteration reference greedy.

Two views:
  * microbench — one admission pass over a synthetic beam (K = 4/8/12/16),
    reference vs fused-with-repack vs fused-with-cached-PackedBeam;
  * end-to-end — the bpaste runtime on a real workload with
    ``admission="reference"`` vs ``"fused"``, reporting wall-µs burned
    inside admission per tick (Metrics.sched_us_per_admit) and the
    incremental-packing hit rate.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import admission, scoring
from repro.core.events import DEFAULT_TOOLS, ResourceVector
from repro.core.hypothesis import BranchHypothesis, Node, NodeKind
from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import run_mode
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes


def _mk_hyp(hid, tools, q=0.8):
    nodes, edges = [], []
    for i, t in enumerate(tools):
        spec = DEFAULT_TOOLS[t]
        nodes.append(Node(i, NodeKind.TOOL, t, spec.level, spec.rho, spec.base_latency))
        if i:
            edges.append((i - 1, i))
    return BranchHypothesis(hid, nodes, edges, q, context_key=("x",))


def _beam(k):
    chains = [["grep", "read", "parse", "search"][: 1 + i % 4] for i in range(k)]
    return [_mk_hyp(i, c, q=0.95 - 0.05 * (i % 10)) for i, c in enumerate(chains)]


def _time(fn, n):
    fn()                                    # warm (jit compile outside timing)
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def run(smoke: bool = False) -> List[Dict]:
    rows = []
    sc = scoring.Scorer(Machine(), k_max=8, n_max=12)
    slack = np.array([6.0, 50.0, 200.0, 1.0])
    budget = slack.copy()
    auth = np.array([1.0, 5.0, 10.0, 1.0])
    n = 20 if smoke else 100
    for k in ([8] if smoke else [4, 8, 12, 16]):
        hyps = _beam(k)
        pb = scoring.pack_beam(hyps, admission.bucket_k(k, sc.k_max), sc.n_max)
        us_ref = _time(
            lambda: admission.greedy_admit(hyps, sc, slack, budget, auth), n)
        us_fused = _time(
            lambda: admission.fused_admit(hyps, sc, slack, budget, auth), n)
        us_cached = _time(
            lambda: admission.fused_admit(hyps, sc, slack, budget, auth, packed=pb), n)
        res_r = admission.greedy_admit(hyps, sc, slack, budget, auth)
        res_f = admission.fused_admit(hyps, sc, slack, budget, auth, packed=pb)
        same = sorted(h.hid for h in res_r.admitted) == sorted(
            h.hid for h in res_f.admitted)
        rows.append({
            "name": f"admission/reference_k{k}", "us_per_call": us_ref,
            "derived": f"admitted={len(res_r.admitted)}"})
        rows.append({
            "name": f"admission/fused_k{k}", "us_per_call": us_fused,
            "derived": f"speedup={us_ref / max(us_fused, 1e-9):.2f}x equiv={same}"})
        rows.append({
            "name": f"admission/fused_cached_k{k}", "us_per_call": us_cached,
            "derived": f"speedup={us_ref / max(us_cached, 1e-9):.2f}x"})

    # end-to-end scheduler overhead per tick through the runtime (wider
    # beams + episode concurrency: the scaling regime the fused path targets)
    n_train, n_test = (20, 3) if smoke else (60, 8)
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=n_train))
    engine = PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))
    test = make_episodes(WorkloadConfig(seed=42, n_episodes=n_test))
    roomy = Machine(ResourceVector(cpu=12, mem_bw=100, io=500, accel=1))
    per_tick = {}
    reps = 2 if smoke else 4
    for adm in ("reference", "fused"):
        # first run pays jit compile (amortized away in serving); report the
        # best of the warm runs to damp shared-CPU noise
        runs = []
        for i in range(1 + reps):
            m = run_mode(test, engine, "bpaste", roomy, seed=7, admission=adm,
                         beam_k=8, max_concurrent_episodes=3)
            if i > 0:
                runs.append(m.summary())
        s = min(runs, key=lambda r: r["sched_us_per_admit"])
        per_tick[adm] = s["sched_us_per_admit"]
        rows.append({
            "name": f"admission/runtime_{adm}",
            "us_per_call": s["sched_us_per_admit"],
            "derived": (f"admit_calls={s['sched_admit_calls']} "
                        f"pack_hit={s['sched_pack_hit_rate']:.2f} "
                        f"beam_occupancy={s['beam_occupancy']:.2f} "
                        f"reuse_rate={s['reuse_rate']:.3f} "
                        f"makespan={s['makespan']:.2f} best_of={reps}"),
        })
    rows.append({
        "name": "admission/runtime_overhead_reduction", "us_per_call": 0.0,
        "derived": f"fused_vs_reference={per_tick['reference'] / max(per_tick['fused'], 1e-9):.2f}x",
    })
    return rows
