"""Result-store microbenchmarks + the saturated-regime end-to-end cell.

Micro: publish / peek / validate (cached and uncached) / footprint
invalidation throughput on a store pre-filled with workload-shaped entries —
the store sits on the Phase-1 hot path and inside the per-tick memo-mask
computation, so its per-op cost must stay in single-digit microseconds.

End-to-end: concurrency 8 on the tool-bound serving box (see
bench_serving.SERVE_BOX) with the shared-corpus workload — serial vs
bpaste with the store off vs on.  This is the cell PR 3 could not win:
at full utilization execution speculation has no slack to convert, but
cache-served commits still delete authoritative work.  The thor-box row
shows the same cell on the accelerator-bound edge box, where the
model-step queue is the floor and no tool-level mechanism can move it.
"""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.bench_serving import SERVE_BOX, THOR_BOX
from repro.core.memo import ResultStore, memo_key
from repro.core.patterns import PatternEngine
from repro.core.runtime import run_mode
from repro.core.sandbox import AgentState
from repro.core.events import SafetyLevel
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes


def _time(fn, n):
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def _fill(store: ResultStore, n: int) -> None:
    for i in range(n):
        store.publish(
            "read", {"path": f"src/f{i}.py"}, {"path": f"src/f{i}.py",
                                               "content": f"c{i}"},
            reads={f"F:src/f{i}.py": f"c{i}"},
            writes={},
            level=SafetyLevel.READ_ONLY, solo_work=0.8, eid=i % 4)


def run(smoke: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    n = 200 if smoke else 2000

    store = ResultStore()
    _fill(store, 256)
    st = AgentState(fs={f"src/f{i}.py": f"c{i}" for i in range(256)})

    rows.append({
        "name": "memo/publish", "us_per_call": _time(
            lambda: store.publish("grep", {"pattern": "p"}, {"path": "x"},
                                  reads={}, writes={},
                                  level=SafetyLevel.READ_ONLY,
                                  solo_work=1.5, eid=0), n),
        "derived": f"entries={len(store)}"})
    entry = store.peek("read", {"path": "src/f7.py"})
    rows.append({
        "name": "memo/peek", "us_per_call": _time(
            lambda: store.peek("read", {"path": "src/f7.py"}), n),
        "derived": "key=(tool, canonical args)"})
    rows.append({
        "name": "memo/validate_cached", "us_per_call": _time(
            lambda: store.validate(entry, st, eid=0), n),
        "derived": "versioned per-tenant cache hit"})
    rows.append({
        "name": "memo/validate_uncached", "us_per_call": _time(
            lambda: store.validate(entry, st), n),
        "derived": "value check over read footprint"})
    rows.append({
        "name": "memo/note_writes_miss", "us_per_call": _time(
            lambda: store.note_writes({"F:untracked": "v"}), n),
        "derived": "no read-index intersection"})

    def churn():
        store.publish("read", {"path": "src/f3.py"},
                      {"path": "src/f3.py", "content": "c3"},
                      reads={"F:src/f3.py": "c3"}, writes={},
                      level=SafetyLevel.READ_ONLY, solo_work=0.8, eid=0)
        store.note_writes({"F:src/f3.py": "DIFFERENT"})
    rows.append({
        "name": "memo/invalidate_cycle", "us_per_call": _time(churn, n),
        "derived": "publish + footprint-intersection kill"})

    # ---- end-to-end: the saturated regime ------------------------------
    n_train, n_test = (20, 8) if smoke else (60, 16)
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=n_train))
    engine = PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))
    test = make_episodes(WorkloadConfig(seed=42, n_episodes=n_test,
                                        arrival_stagger=4.0,
                                        shared_frac=0.5, shared_pool=2))
    cells = {}
    for label, mode, memo, box in [
        ("serial", "serial", False, SERVE_BOX),
        ("bpaste", "bpaste", False, SERVE_BOX),
        ("bpaste_memo", "bpaste", True, SERVE_BOX),
        ("thor_bpaste_memo", "bpaste", True, THOR_BOX),
    ]:
        m = run_mode(test, engine, mode, box, seed=7,
                     max_concurrent_episodes=8, memo=memo)
        s = m.summary()
        cells[label] = s
        rows.append({
            "name": f"memo/c8_{label}", "us_per_call": 0.0,
            "derived": (f"makespan={s['makespan']:.1f} "
                        f"p95_sojourn={s['p95_sojourn']:.1f} "
                        f"serves={s['memo_serves']:.0f} "
                        f"hits={s['memo_hits']:.0f} "
                        f"dedups={s['memo_dedups']:.0f} "
                        f"invalidations={s['memo_invalidations']:.0f} "
                        f"saved={s['memo_saved_seconds']:.1f}s "
                        f"slowdown={s['mean_auth_slowdown']:.3f}")})
    sr, bm = cells["serial"], cells["bpaste_memo"]
    rows.append({
        "name": "memo/c8_memo_vs_serial", "us_per_call": 0.0,
        "derived": (f"makespan {sr['makespan']:.1f}->{bm['makespan']:.1f} "
                    f"({sr['makespan'] / max(bm['makespan'], 1e-9):.3f}x) "
                    f"p95_sojourn {sr['p95_sojourn']:.1f}->"
                    f"{bm['p95_sojourn']:.1f} "
                    f"({sr['p95_sojourn'] / max(bm['p95_sojourn'], 1e-9):.3f}x)")})
    return rows
