"""Serving-substrate integration: batched engine throughput (reduced model
on CPU) and B-PASTE batch-slot speculation hit behavior — the paper's
technique running against real model decode steps."""
from __future__ import annotations

import time
from typing import Dict, List

import jax

from repro.configs import get_config
from repro.core.events import DEFAULT_TOOLS
from repro.core.hypothesis import BranchHypothesis, Node, NodeKind
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.spec_serving import SlotSpeculator, render_observation


def run() -> List[Dict]:
    rows = []
    cfg = get_config("musicgen-medium").reduced()
    params = model_mod.init_params(jax.random.key(0), cfg)
    eng = ServingEngine(cfg, params, max_batch=4, max_len=128)
    eng.add_request([2, 3, 4], request_id=0)
    eng.step()  # warm jit
    t0 = time.perf_counter()
    n = 30
    for _ in range(n):
        eng.step()
    dt = (time.perf_counter() - t0) / n
    rows.append({"name": "serving/decode_step_b4", "us_per_call": dt * 1e6,
                 "derived": f"steps/s={1/dt:.1f} (reduced model, CPU)"})

    # prefill-into-slot latency
    t0 = time.perf_counter()
    slot = eng.add_request([5, 6, 7, 8, 9], request_id=1)
    dt = time.perf_counter() - t0
    rows.append({"name": "serving/prefill_into_slot", "us_per_call": dt * 1e6,
                 "derived": "includes slot cache write"})

    # speculation promote path
    for s in eng.slots:
        s.active = False
    spec = SlotSpeculator(eng, budget_slots=2)
    n_spec = DEFAULT_TOOLS["search"]
    h = BranchHypothesis(1, [Node(0, NodeKind.TOOL, "search", n_spec.level,
                                  n_spec.rho, 1.0)], [], q=0.9, context_key=())
    t0 = time.perf_counter()
    spec.admit([(h, 1.0)], history_prompt=[2, 3])
    for _ in range(5):
        eng.step()
    obs = render_observation("search", {}, "pred:1:0", cfg.vocab_size)
    got = spec.match_and_promote(obs, request_id=7)
    dt = time.perf_counter() - t0
    rows.append({
        "name": "serving/speculate_admit_promote",
        "us_per_call": dt * 1e6,
        "derived": f"promoted={got is not None} (5 spec decode steps already done at promotion)",
    })
    return rows
