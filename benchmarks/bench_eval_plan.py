"""Paper §9 evaluation-plan metrics: average/tail latency, promotion &
prefix-reuse rate, wasted speculative compute, authoritative QoS
violations, and co-run slowdown across interference regimes (roomy /
thor / tight machines × concurrency)."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.events import ResourceVector
from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import run_mode
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes

REGIMES = [
    ("roomy", Machine(ResourceVector(cpu=12, mem_bw=100, io=500, accel=1)), 1),
    ("thor", Machine(ResourceVector(cpu=6, mem_bw=50, io=200, accel=1)), 1),
    ("thor_multi", Machine(ResourceVector(cpu=6, mem_bw=50, io=200, accel=1)), 3),
    ("tight", Machine(ResourceVector(cpu=3, mem_bw=20, io=80, accel=1)), 3),
]


def run(n_test: int = 12, smoke: bool = False) -> List[Dict]:
    if smoke:
        n_test = 3
    train_eps = make_episodes(WorkloadConfig(seed=1, n_episodes=20 if smoke else 60))
    engine = PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train_eps))
    test_eps = make_episodes(WorkloadConfig(seed=42, n_episodes=n_test))
    rows = []
    regimes = REGIMES[:2] if smoke else REGIMES
    for regime, machine, conc in regimes:
        base = None
        for mode in ("serial", "bpaste", "parallel"):
            t0 = time.perf_counter()
            m = run_mode(test_eps, engine, mode, machine, seed=7,
                         max_concurrent_episodes=conc)
            wall = time.perf_counter() - t0
            s = m.summary()
            if mode == "serial":
                base = s["makespan"]
            n_steps = sum(len(e.steps) for e in test_eps)
            rows.append({
                "name": f"eval/{regime}/{mode}",
                "us_per_call": wall * 1e6 / n_test,
                "derived": (
                    f"speedup={base/s['makespan']:.3f} "
                    f"mean_lat={s['mean_latency']:.1f} p95={s['p95_latency']:.1f} "
                    f"promo_rate={s['promotions']/n_steps:.2f} "
                    f"prefix_rate={s['prefix_reuses']/n_steps:.2f} "
                    f"waste={s['wasted_frac']:.2f} qos={s['qos_violations']} "
                    f"slow={s['mean_auth_slowdown']:.3f} "
                    f"sched_us={s['sched_us_per_admit']:.0f}"
                ),
            })
    return rows
