"""Benchmark entrypoint: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

``--smoke`` runs a minutes-scale sanity pass (scheduler + admission + a
reduced eval plan) for the tier-1 loop; the full suite is the default.
``--only SECTION`` filters sections by substring.
``--json PATH`` additionally writes every row (plus its section) as a JSON
list — CI artifacts this so bench regressions are diffable across runs.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast sanity pass: scheduler, admission, reduced eval plan")
    ap.add_argument("--only", default=None,
                    help="run only sections whose name contains this substring")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all rows as a JSON list to PATH")
    args = ap.parse_args()

    from benchmarks import (bench_ablation, bench_admission, bench_beam,
                            bench_engine, bench_eval_plan, bench_kernels,
                            bench_memo, bench_scheduler, bench_serving,
                            bench_table1, roofline)

    if args.smoke:
        sections = [
            ("scheduler (runtime overhead)",
             lambda: bench_scheduler.run(smoke=True)),
            ("admission (fused vs reference)",
             lambda: bench_admission.run(smoke=True)),
            ("beam (tree assembly occupancy/reuse)",
             lambda: bench_beam.run(smoke=True)),
            ("serving (concurrent episodes, shared beam)",
             lambda: bench_serving.run(smoke=True)),
            ("memo (result store, cache-served commits)",
             lambda: bench_memo.run(smoke=True)),
            ("eval_plan (paper SS9 metrics, smoke)",
             lambda: bench_eval_plan.run(smoke=True)),
        ]
    else:
        sections = [
            ("table1 (paper Table 1: end-to-end speedup)", bench_table1.run),
            ("eval_plan (paper SS9 metrics)", bench_eval_plan.run),
            ("ablation (EU objective / beam width)", bench_ablation.run),
            ("scheduler (runtime overhead)", bench_scheduler.run),
            ("admission (fused vs reference)", bench_admission.run),
            ("beam (tree assembly occupancy/reuse)", bench_beam.run),
            ("serving (concurrent episodes, shared beam)", bench_serving.run),
            ("memo (result store, cache-served commits)", bench_memo.run),
            ("engine (B-PASTE x serving engine integration)", bench_engine.run),
            ("kernels", bench_kernels.run),
            ("roofline (dry-run derived)", roofline.run),
        ]
    if args.only:
        sections = [(t, f) for t, f in sections if args.only in t]
    all_rows = []
    print("name,us_per_call,derived")
    for title, fn in sections:
        print(f"# --- {title} ---", file=sys.stderr)
        try:
            for row in fn():
                print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
                all_rows.append({"section": title, **row})
        except Exception as e:  # keep the harness robust
            print(f"{title},0,\"ERROR: {type(e).__name__}: {e}\"")
            all_rows.append({"section": title, "name": title,
                             "us_per_call": 0.0,
                             "derived": f"ERROR: {type(e).__name__}: {e}"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_rows, f, indent=2)
        print(f"# wrote {len(all_rows)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
