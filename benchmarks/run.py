"""Benchmark entrypoint: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (bench_ablation, bench_eval_plan, bench_kernels,
                            bench_scheduler, bench_serving, bench_table1,
                            roofline)

    sections = [
        ("table1 (paper Table 1: end-to-end speedup)", bench_table1.run),
        ("eval_plan (paper SS9 metrics)", bench_eval_plan.run),
        ("ablation (EU objective / beam width)", bench_ablation.run),
        ("scheduler (runtime overhead)", bench_scheduler.run),
        ("serving (B-PASTE x engine integration)", bench_serving.run),
        ("kernels", bench_kernels.run),
        ("roofline (dry-run derived)", roofline.run),
    ]
    print("name,us_per_call,derived")
    for title, fn in sections:
        print(f"# --- {title} ---", file=sys.stderr)
        try:
            for row in fn():
                print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
        except Exception as e:  # keep the harness robust
            print(f"{title},0,\"ERROR: {type(e).__name__}: {e}\"")


if __name__ == "__main__":
    main()
