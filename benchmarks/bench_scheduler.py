"""Scheduler microbenchmarks: the runtime must not eat the slack it
exploits.  Beam EU scoring (jit), greedy admission, greedy-vs-exact
quality, PrefixSpan mining throughput."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import admission, scoring
from repro.core.events import DEFAULT_TOOLS
from repro.core.hypothesis import BranchHypothesis, HypothesisBuilder, Node, NodeKind
from repro.core.interference import Machine
from repro.core.mining.prefixspan import prefixspan
from repro.core.patterns import PatternEngine
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes


def _mk_hyp(hid, tools, q=0.8):
    nodes, edges = [], []
    for i, t in enumerate(tools):
        spec = DEFAULT_TOOLS[t]
        nodes.append(Node(i, NodeKind.TOOL, t, spec.level, spec.rho, spec.base_latency))
        if i:
            edges.append((i - 1, i))
    return BranchHypothesis(hid, nodes, edges, q, context_key=("x",))


def run() -> List[Dict]:
    rows = []
    sc = scoring.Scorer(Machine(), k_max=8, n_max=12)
    hyps = [_mk_hyp(i, ["grep", "read", "parse", "search"][: 1 + i % 4], q=0.9 - 0.1 * i)
            for i in range(8)]
    adm = np.array([1.0, 5.0, 10.0, 1.0])
    sc.score(hyps, adm)                      # warm the jit cache
    n = 200
    t0 = time.perf_counter()
    for _ in range(n):
        sc.score(hyps, adm)
    dt = (time.perf_counter() - t0) / n
    rows.append({"name": "scheduler/score_beam_k8", "us_per_call": dt * 1e6,
                 "derived": "jit beam EU (K=8,N=12)"})

    slack = np.array([6.0, 50.0, 200.0, 1.0])
    budget = slack.copy()
    t0 = time.perf_counter()
    for _ in range(50):
        res = admission.greedy_admit(hyps, sc, slack, budget, adm)
    dt = (time.perf_counter() - t0) / 50
    rows.append({"name": "scheduler/greedy_admit_k8", "us_per_call": dt * 1e6,
                 "derived": f"admitted={len(res.admitted)}"})

    pb = scoring.pack_beam(hyps, admission.bucket_k(len(hyps), sc.k_max), sc.n_max)
    admission.fused_admit(hyps, sc, slack, budget, adm, packed=pb)  # warm jit
    t0 = time.perf_counter()
    for _ in range(50):
        res_f = admission.fused_admit(hyps, sc, slack, budget, adm, packed=pb)
    dt = (time.perf_counter() - t0) / 50
    rows.append({"name": "scheduler/fused_admit_k8", "us_per_call": dt * 1e6,
                 "derived": f"admitted={len(res_f.admitted)} (one XLA dispatch/pass)"})

    g = sum(res.eu.values())
    _, ex = admission.exact_admit(hyps[:6], sc, slack, budget, adm)
    res6 = admission.greedy_admit(hyps[:6], sc, slack, budget, adm)
    g6 = sum(res6.eu.values())
    rows.append({"name": "scheduler/greedy_vs_exact_k6", "us_per_call": 0.0,
                 "derived": f"quality_ratio={g6/max(ex,1e-9):.3f}"})

    eps = make_episodes(WorkloadConfig(seed=1, n_episodes=60))
    traces = episodes_to_traces(eps)
    from repro.core.events import trace_signatures
    seqs = [trace_signatures(t) for t in traces]
    t0 = time.perf_counter()
    pats = prefixspan(seqs, min_support=3, max_len=5, max_gap=1)
    dt = time.perf_counter() - t0
    rows.append({"name": "scheduler/prefixspan_60traces", "us_per_call": dt * 1e6,
                 "derived": f"patterns={len(pats)}"})

    t0 = time.perf_counter()
    pe = PatternEngine(context_len=2, min_support=3).fit(traces)
    dt = time.perf_counter() - t0
    rows.append({"name": "scheduler/pattern_engine_fit", "us_per_call": dt * 1e6,
                 "derived": f"tuples={len(pe.patterns)}"})

    b = HypothesisBuilder(pe)
    hist = traces[0][:2]
    t0 = time.perf_counter()
    for _ in range(100):
        hs = b.build(hist, beam_width=6)
    dt = (time.perf_counter() - t0) / 100
    rows.append({"name": "scheduler/build_beam", "us_per_call": dt * 1e6,
                 "derived": f"hyps={len(hs)}"})
    return rows
