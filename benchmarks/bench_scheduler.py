"""Scheduler microbenchmarks: the runtime must not eat the slack it
exploits.  Beam EU scoring (jit), greedy admission, greedy-vs-exact
quality, PrefixSpan mining throughput, and the tenant-scale tick-loop
sweep (event vs dense scheduler at c∈{8,64,256,1024})."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import admission, scoring
from repro.core.events import DEFAULT_TOOLS
from repro.core.hypothesis import BranchHypothesis, HypothesisBuilder, Node, NodeKind
from repro.core.interference import Machine
from repro.core.mining.prefixspan import prefixspan
from repro.core.patterns import PatternEngine
from repro.core.runtime import run_mode
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes

# scheduler overhead budget, µs of wall time per tick per episode: the
# control loop must stay a rounding error next to the second-scale tool
# work it schedules.  check_budget.py flags >2x regressions vs the
# checked-in baseline; this constant is the absolute sanity line.
TICK_BUDGET_US = 50.0

# dense is O(c) per tick — at c=1024 a single run takes minutes of pure
# Python scanning, which is exactly the point; measure it only up to here
DENSE_C_MAX = 256


def _mk_hyp(hid, tools, q=0.8):
    nodes, edges = [], []
    for i, t in enumerate(tools):
        spec = DEFAULT_TOOLS[t]
        nodes.append(Node(i, NodeKind.TOOL, t, spec.level, spec.rho, spec.base_latency))
        if i:
            edges.append((i - 1, i))
    return BranchHypothesis(hid, nodes, edges, q, context_key=("x",))


def _sweep_cell(c: int, scheduler: str, engine: PatternEngine,
                sanitize: bool = False, warm: bool = True) -> Dict:
    """One synthetic-tenant serving cell: c staggered episodes on a serve
    box, event or dense scheduler, log recording off (the c=1024 event log
    is a memory blowup — satellite knob record_log=False).  ``warm=False``
    disables the verified admission warm-start (signature replay + per-hid
    static-terms cache) for the before/after comparison rows.  Returns the
    µs/tick/episode overhead row."""
    from repro.core.events import ResourceVector
    from repro.core.interference import Machine as _Machine

    eps = make_episodes(WorkloadConfig(seed=11, n_episodes=c,
                                       arrival_stagger=0.5,
                                       shared_frac=0.5, shared_pool=4))
    box = _Machine(ResourceVector(cpu=24, mem_bw=200, io=1000, accel=8))
    tag = ("_sanitize" if sanitize else "") + ("" if warm else "_warmoff")
    t0 = time.perf_counter()
    m = run_mode(eps, engine, "bpaste", box, seed=7,
                 max_concurrent_episodes=c, scheduler=scheduler,
                 record_log=False, model_max_batch=8, sanitize=sanitize,
                 warm_admit=warm)
    wall = time.perf_counter() - t0
    s = m.summary()
    us_per_tick_ep = s["sched_us_per_tick"] / max(c, 1)
    return {
        "name": f"scheduler/tick_sweep_{scheduler}{tag}_c{c}",
        "us_per_call": us_per_tick_ep,
        "derived": (f"us/tick/episode (ticks={int(s['sched_ticks'])}, "
                    f"makespan={s['makespan']:.1f}s, wall={wall:.1f}s, "
                    f"budget={TICK_BUDGET_US}us)"),
        "c": c, "scheduler": scheduler, "sanitize": sanitize,
        "warm_admit": warm,
        "us_per_tick": s["sched_us_per_tick"],
        "us_per_admit": s.get("sched_us_per_admit", 0.0),
        "warm_hits": m.sched_warm_hits,
        "warm_misses": m.sched_warm_misses,
        "ticks": int(s["sched_ticks"]),
        "wall_seconds": wall,
        "sanitize_findings": s.get("sanitize_findings", 0),
    }


def run(smoke: bool = False) -> List[Dict]:
    rows = []
    sc = scoring.Scorer(Machine(), k_max=8, n_max=12)
    hyps = [_mk_hyp(i, ["grep", "read", "parse", "search"][: 1 + i % 4], q=0.9 - 0.1 * i)
            for i in range(8)]
    adm = np.array([1.0, 5.0, 10.0, 1.0])
    sc.score(hyps, adm)                      # warm the jit cache
    n = 200
    t0 = time.perf_counter()
    for _ in range(n):
        sc.score(hyps, adm)
    dt = (time.perf_counter() - t0) / n
    rows.append({"name": "scheduler/score_beam_k8", "us_per_call": dt * 1e6,
                 "derived": "jit beam EU (K=8,N=12)"})

    slack = np.array([6.0, 50.0, 200.0, 1.0])
    budget = slack.copy()
    t0 = time.perf_counter()
    for _ in range(50):
        res = admission.greedy_admit(hyps, sc, slack, budget, adm)
    dt = (time.perf_counter() - t0) / 50
    rows.append({"name": "scheduler/greedy_admit_k8", "us_per_call": dt * 1e6,
                 "derived": f"admitted={len(res.admitted)}"})

    pb = scoring.pack_beam(hyps, admission.bucket_k(len(hyps), sc.k_max), sc.n_max)
    admission.fused_admit(hyps, sc, slack, budget, adm, packed=pb)  # warm jit
    t0 = time.perf_counter()
    for _ in range(50):
        res_f = admission.fused_admit(hyps, sc, slack, budget, adm, packed=pb)
    dt = (time.perf_counter() - t0) / 50
    rows.append({"name": "scheduler/fused_admit_k8", "us_per_call": dt * 1e6,
                 "derived": f"admitted={len(res_f.admitted)} (one XLA dispatch/pass)"})

    g = sum(res.eu.values())
    _, ex = admission.exact_admit(hyps[:6], sc, slack, budget, adm)
    res6 = admission.greedy_admit(hyps[:6], sc, slack, budget, adm)
    g6 = sum(res6.eu.values())
    rows.append({"name": "scheduler/greedy_vs_exact_k6", "us_per_call": 0.0,
                 "derived": f"quality_ratio={g6/max(ex,1e-9):.3f}"})

    eps = make_episodes(WorkloadConfig(seed=1, n_episodes=60))
    traces = episodes_to_traces(eps)
    from repro.core.events import trace_signatures
    seqs = [trace_signatures(t) for t in traces]
    t0 = time.perf_counter()
    pats = prefixspan(seqs, min_support=3, max_len=5, max_gap=1)
    dt = time.perf_counter() - t0
    rows.append({"name": "scheduler/prefixspan_60traces", "us_per_call": dt * 1e6,
                 "derived": f"patterns={len(pats)}"})

    t0 = time.perf_counter()
    pe = PatternEngine(context_len=2, min_support=3).fit(traces)
    dt = time.perf_counter() - t0
    rows.append({"name": "scheduler/pattern_engine_fit", "us_per_call": dt * 1e6,
                 "derived": f"tuples={len(pe.patterns)}"})

    b = HypothesisBuilder(pe)
    hist = traces[0][:2]
    t0 = time.perf_counter()
    for _ in range(100):
        hs = b.build(hist, beam_width=6)
    dt = (time.perf_counter() - t0) / 100
    rows.append({"name": "scheduler/build_beam", "us_per_call": dt * 1e6,
                 "derived": f"hyps={len(hs)}"})

    # ---- tenant-scale tick-loop sweep (event vs dense) ----------------
    # smoke keeps CI cheap (c<=64); the full sweep is the ISSUE-6
    # acceptance artifact: event >=5x cheaper than dense at c=256, all
    # four c rows reported against TICK_BUDGET_US
    sweep_cs = [8, 64] if smoke else [8, 64, 256, 1024]
    for c in sweep_cs:
        for scheduler in ("event", "dense"):
            if scheduler == "dense" and c > DENSE_C_MAX:
                rows.append({
                    "name": f"scheduler/tick_sweep_dense_c{c}",
                    "us_per_call": 0.0,
                    "derived": f"skipped (dense O(c) loop; measured up to "
                               f"c={DENSE_C_MAX})",
                    "c": c, "scheduler": "dense", "skipped": True,
                })
                continue
            rows.append(_sweep_cell(c, scheduler, pe))
    ev = {r["c"]: r for r in rows if r.get("scheduler") == "event"}
    de = {r["c"]: r for r in rows
          if r.get("scheduler") == "dense" and not r.get("skipped")}
    for c in sorted(set(ev) & set(de)):
        speedup = de[c]["us_per_call"] / max(ev[c]["us_per_call"], 1e-9)
        rows.append({"name": f"scheduler/tick_sweep_speedup_c{c}",
                     "us_per_call": 0.0,
                     "derived": f"event_vs_dense={speedup:.1f}x "
                                f"(us/tick/episode)",
                     "c": c, "speedup": speedup})

    # ---- admission warm-start cut (ISSUE 8) ---------------------------
    # event cells at c>=64 re-run with warm_admit=False: the default rows
    # above already include the warm-start, so the delta in us/admit (and
    # us/tick/episode) is exactly what the signed replay + per-hid
    # static-terms cache buy in the churny big-pool regime
    warm_cs = [64] if smoke else [64, 256]
    for c in warm_cs:
        off = _sweep_cell(c, "event", pe, warm=False)
        rows.append(off)
        on = ev.get(c)
        if on is None:
            continue
        cut = off["us_per_admit"] / max(on["us_per_admit"], 1e-9)
        rows.append({
            "name": f"scheduler/warm_admit_cut_c{c}",
            "us_per_call": 0.0,
            "derived": (f"warmoff_vs_warm={cut:.2f}x us/admit "
                        f"({off['us_per_admit']:.0f} -> "
                        f"{on['us_per_admit']:.0f}us; tick/ep "
                        f"{off['us_per_call']:.1f} -> "
                        f"{on['us_per_call']:.1f}us; warm hits="
                        f"{on['warm_hits']}, misses={on['warm_misses']})"),
            "c": c, "admit_cut": cut,
            "us_per_admit_warm": on["us_per_admit"],
            "us_per_admit_off": off["us_per_admit"],
        })

    # ---- runtime-sanitizer overhead (ISSUE 7) -------------------------
    # same c=8 event cell with RuntimeConfig.sanitize=True: the S1-S5
    # cross-checks every 7th tick are diagnostics, so the row documents
    # what turning them on costs (and that they find nothing on the
    # default config — sanitize_findings lands in the derived string)
    san = _sweep_cell(8, "event", pe, sanitize=True)
    rows.append(san)
    base = ev.get(8)
    if base is not None:
        ratio = san["us_per_call"] / max(base["us_per_call"], 1e-9)
        rows.append({"name": "scheduler/sanitize_overhead_c8",
                     "us_per_call": 0.0,
                     "derived": (f"sanitize_vs_off={ratio:.1f}x "
                                 f"(us/tick/episode, findings="
                                 f"{san['sanitize_findings']})"),
                     "c": 8, "sanitize_ratio": ratio,
                     "sanitize_findings": san["sanitize_findings"]})
    return rows
