"""Kernel micro-timings (CPU wall time of the jnp implementations; the
Pallas kernels target TPU and are validated in interpret mode — CPU wall
time for interpret mode is not meaningful and is excluded)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

RNG = np.random.default_rng(0)


def _time(fn, *args, n=5, **kw):
    fn(*args, **kw)[0].block_until_ready() if isinstance(fn(*args, **kw), tuple) else None
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / n


def run() -> List[Dict]:
    rows = []
    b, s, h, kv, d = 1, 1024, 8, 2, 64
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.float32)
    dt = _time(lambda: ops.flash_attention(q, k, v, impl="jnp"))
    flops = 4 * b * h * s * s * d / 2  # causal
    rows.append({"name": "kernels/flash_attention_1k", "us_per_call": dt * 1e6,
                 "derived": f"gflops/s={flops/dt/1e9:.1f}"})

    qd = jnp.asarray(RNG.normal(size=(8, h, d)), jnp.float32)
    kc = jnp.asarray(RNG.normal(size=(8, 4096, kv, d)), jnp.float32)
    vc = jnp.asarray(RNG.normal(size=(8, 4096, kv, d)), jnp.float32)
    lens = jnp.full((8,), 4096, jnp.int32)
    dt = _time(lambda: ops.decode_attention(qd, kc, vc, lens, impl="jnp"))
    bytes_read = 2 * 8 * 4096 * kv * d * 4
    rows.append({"name": "kernels/decode_attention_4k", "us_per_call": dt * 1e6,
                 "derived": f"gb/s={bytes_read/dt/1e9:.1f}"})

    bb, ss, hh, p, g, n = 1, 2048, 8, 64, 1, 64
    x = jnp.asarray(RNG.normal(size=(bb, ss, hh, p)), jnp.float32)
    dts = jnp.asarray(RNG.uniform(0.01, 0.2, size=(bb, ss, hh)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(hh,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(bb, ss, g, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(bb, ss, g, n)), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(hh,)), jnp.float32)
    dt = _time(lambda: ops.ssd_scan(x, dts, A, B, C, D, chunk=128, impl="jnp"))
    rows.append({"name": "kernels/ssd_scan_2k", "us_per_call": dt * 1e6,
                 "derived": f"tokens/s={bb*ss/dt:.0f}"})
    return rows
