"""Beam widening: tree-structured hypothesis assembly + multi-root fill vs
the pre-PR linear-chain baseline.

Reports the 2x2 grid (assembly x workload variation):
  * ``chain @ variation=0`` is the pre-PR configuration — linear chains,
    first-root monopoly, deterministic legacy workload (the regime where the
    builder seeded 1-3 candidates/tick);
  * ``tree @ variation=1`` is the post-PR default — branching subgraphs,
    merged-backoff multi-root fill, motif-variant workload.

Headline derived row: mean beam occupancy at admission time pre -> post,
with the reuse-rate / makespan movement that the widening buys.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import run_mode
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes


def _cell(assembly: str, variation: float, beam_k: int,
          n_train: int, n_test: int):
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=n_train,
                                         variation=variation))
    engine = PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))
    test = make_episodes(WorkloadConfig(seed=42, n_episodes=n_test,
                                        variation=variation))
    serial = run_mode(test, engine, "serial", Machine(), seed=7)
    m = run_mode(test, engine, "bpaste", Machine(), seed=7,
                 assembly=assembly, beam_k=beam_k)
    s = m.summary()
    s["speedup"] = serial.makespan / max(s["makespan"], 1e-9)
    return s


def run(smoke: bool = False) -> List[Dict]:
    n_train, n_test = (20, 3) if smoke else (60, 8)
    rows = []
    cells = {}
    # chain cells run at the pre-PR default beam_k=6 (the configuration the
    # widening is measured against); tree cells at the post-PR default 12
    for assembly, variation, beam_k in (("chain", 0.0, 6), ("tree", 0.0, 12),
                                        ("chain", 1.0, 6), ("tree", 1.0, 12)):
        s = _cell(assembly, variation, beam_k, n_train, n_test)
        cells[(assembly, variation)] = s
        rows.append({
            "name": f"beam/{assembly}_var{variation:g}",
            "us_per_call": 0.0,
            "derived": (f"occupancy={s['beam_occupancy']:.2f} "
                        f"reuse_rate={s['reuse_rate']:.3f} "
                        f"makespan={s['makespan']:.1f} "
                        f"speedup={s['speedup']:.3f} "
                        f"wasted_frac={s['wasted_frac']:.2f} "
                        f"beam_k={beam_k}"),
        })
    pre = cells[("chain", 0.0)]          # pre-PR assembly on pre-PR workload
    post = cells[("tree", 1.0)]          # post-PR defaults
    same = cells[("chain", 1.0)]         # assembly-only ablation, same workload
    rows.append({
        "name": "beam/occupancy_widening", "us_per_call": 0.0,
        "derived": (
            f"pre={pre['beam_occupancy']:.2f} post={post['beam_occupancy']:.2f} "
            f"({post['beam_occupancy'] / max(pre['beam_occupancy'], 1e-9):.2f}x; "
            f"same-workload {post['beam_occupancy'] / max(same['beam_occupancy'], 1e-9):.2f}x) "
            f"reuse_rate {pre['reuse_rate']:.3f}->{post['reuse_rate']:.3f} "
            f"speedup {pre['speedup']:.3f}->{post['speedup']:.3f}"),
    })
    return rows
