"""Quickstart: mine agent-trace patterns offline, then run B-PASTE vs the
serial baseline on a Thor-class machine and print the end-to-end speedup.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.events import ResourceVector
from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import run_mode
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes


def main():
    # 1. offline: mine PASTE pattern tuples (C, T, f, p) from historical traces
    history = make_episodes(WorkloadConfig(seed=1, n_episodes=60))
    engine = PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(history))
    print(f"mined {len(engine.patterns)} pattern tuples, "
          f"{len(engine.motifs)} PrefixSpan motifs")
    for pt in engine.patterns[:4]:
        print(f"  C={[c[1] for c in pt.context]} -> T={pt.tool} p={pt.confidence:.2f} "
              f"f={[(b.arg_name, b.transform) for b in pt.bindings]}")

    # 2. online: serve fresh episodes with and without speculation
    thor = Machine(ResourceVector(cpu=6, mem_bw=50, io=200, accel=1))
    episodes = make_episodes(WorkloadConfig(seed=42, n_episodes=10))
    serial = run_mode(episodes, engine, "serial", thor)
    bpaste = run_mode(episodes, engine, "bpaste", thor)
    s = bpaste.summary()
    print(f"\nserial   makespan {serial.makespan:8.1f}s")
    print(f"B-PASTE  makespan {bpaste.makespan:8.1f}s  "
          f"speedup {serial.makespan / bpaste.makespan:.2f}x "
          f"(paper Table 1: up to 1.40x)")
    print(f"promotions={s['promotions']} reuses={s['reuses']} "
          f"prefix_reuses={s['prefix_reuses']} wasted_frac={s['wasted_frac']:.2f}")


if __name__ == "__main__":
    main()
