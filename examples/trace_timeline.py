"""Per-episode Gantt timeline of one seeded c=8 serving cell.

  PYTHONPATH=src python examples/trace_timeline.py [--out trace.json]

Runs the event-driven B-PASTE runtime over 8 staggered tenants on an
edge box with a :class:`repro.core.trace.GanttRecorder` attached, dumps
the timeline as JSON rows (job, tenant(s), t_start/t_end, speculative,
batch id, outcome) and renders a seconds-scale ASCII Gantt — the
observability path for debugging schedules where per-job print logging
stops being readable (the c=1024 regime the event scheduler exists for,
demonstrated here at readable scale).

Reading the chart: ``=`` segments are authoritative work (model steps,
batched model invocations carry a ``b<seq>`` batch tag, tools), ``~``
segments are speculative branch nodes running inside sandboxes, ``%``
segments are batched dispatches whose idle slots carry speculative
reasoning-step passengers (label suffix ``+Ns`` counts them — the
free riders `spec_model_steps` books per batch via meta["spec_eids"]),
``x`` marks a preemption (Phase-2 protection or a squash killed the
segment).

CI runs this in the fast tier like speculative_serving.py.
"""
import argparse
import json
import os
import tempfile


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the Gantt JSON here (default: temp file)")
    ap.add_argument("--episodes", type=int, default=8)
    args = ap.parse_args()

    from repro.core.interference import Machine
    from repro.core.patterns import PatternEngine
    from repro.core.runtime import BPasteRuntime, RuntimeConfig
    from repro.core.trace import GanttRecorder, render_ascii
    from repro.core.workload import (
        WorkloadConfig, episodes_to_traces, make_episodes,
    )

    train = make_episodes(WorkloadConfig(seed=1, n_episodes=20))
    engine = PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))
    tenants = make_episodes(WorkloadConfig(
        seed=42, n_episodes=args.episodes, arrival_stagger=4.0,
        shared_frac=0.5, shared_pool=2))

    rec = GanttRecorder()
    rt = BPasteRuntime(tenants, engine, Machine(), rcfg=RuntimeConfig(
        mode="bpaste", seed=7, max_concurrent_episodes=args.episodes,
        model_max_batch=8, spec_model_steps=True, trace=rec))
    m = rt.run()
    rec.close(rt.sim.now)

    out = args.out or os.path.join(tempfile.gettempdir(), "trace_timeline.json")
    rec.dump(out)
    s = m.summary()
    spec_rows = sum(1 for r in rec.rows if r["speculative"])
    batch_rows = sum(1 for r in rec.rows if r["batch"] is not None)
    rider_rows = sum(1 for r in rec.rows if r.get("spec_tenants"))
    print(f"{len(rec.rows)} timeline rows ({spec_rows} speculative, "
          f"{batch_rows} batched model invocations, "
          f"{rider_rows} carrying spec-step passengers) -> {out}")
    print(f"makespan={s['makespan']:.1f}s  reuses={s['reuses']:.0f}  "
          f"promotions={s['promotions']:.0f}  "
          f"spec_steps={s['spec_steps_accepted']:.0f}/"
          f"{s['spec_steps_submitted']:.0f} accepted "
          f"(saved {s['spec_step_saved_seconds']:.1f}s)  "
          f"sched_us_per_tick={s['sched_us_per_tick']:.0f}")
    print()
    print(render_ascii(rec.rows))

    # sanity for CI: the dump is valid JSON with the documented fields
    with open(out) as f:
        rows = json.load(f)
    assert rows and all(
        {"job", "tenant", "t_start", "t_end", "speculative", "batch",
         "spec_tenants"} <= set(r) for r in rows)
    assert any(r["speculative"] for r in rows), "no speculation recorded"
    assert any(r["spec_tenants"] for r in rows), \
        "no spec-step passengers recorded"


if __name__ == "__main__":
    main()
