"""Train a ~100M-param decoder LM for a few hundred steps on the synthetic
bigram stream, with async checkpointing and resume.

Defaults are CPU-sized; pass --full for the 100M configuration.

  PYTHONPATH=src python examples/train_lm.py --steps 100
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (d=768, L=12) instead of the tiny smoke config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("granite-8b")
    if args.full:
        cfg = dataclasses.replace(
            base, name="granite-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, d_ff=2048, vocab_size=8192, head_dim=64, remat=False,
            max_seq_len=512,
        )
        import repro.configs as C
        C.ARCHS[cfg.name] = cfg
        arch, seq, gb = cfg.name, 256, 8
        print(f"training {cfg.name}: ~{cfg.n_params()/1e6:.0f}M params")
        _, _, losses = train(arch, reduced=False, steps=args.steps, seq_len=seq,
                             global_batch=gb, ckpt_dir=args.ckpt_dir, ckpt_every=50)
    else:
        _, _, losses = train("granite-8b", reduced=True, steps=args.steps,
                             seq_len=128, global_batch=8,
                             ckpt_dir=args.ckpt_dir, ckpt_every=25)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
