"""Lower + compile one (arch x shape) cell on the 512-chip multi-pod mesh
and print its roofline terms.

  PYTHONPATH=src python examples/multi_pod_dryrun.py --arch mixtral-8x7b --shape train_4k
"""
import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="multi", choices=["single", "multi"])
    args = ap.parse_args()
    # NOTE: repro.launch.dryrun sets XLA_FLAGS for 512 host devices at import
    from repro.launch.dryrun import run_cell
    rec = run_cell(args.arch, args.shape, args.mesh, out_dir=None)
    keys = ("status", "devices", "compile_s", "compute_term_s", "memory_term_s",
            "collective_term_s", "bottleneck", "useful_flops_ratio")
    print(json.dumps({k: rec.get(k) for k in keys}, indent=2))


if __name__ == "__main__":
    main()
