"""Speculative serving, end to end — two demos in one driver.

Default (fast, CI's fast tier runs exactly this):

  PYTHONPATH=src python examples/speculative_serving.py

demonstrates the **batched edge-box configuration** on the discrete-event
runtime: an accel=1 Thor-class box serving 8 concurrent tenants is
model-step-bound — the serial model-step queue, not tool work, sets the
makespan, so plain speculation cannot help (PR 3/4's converged
``thor_c8`` rows).  Turning on the batched model-step service
(``RuntimeConfig.model_max_batch``, src/repro/core/model_service.py)
coalesces concurrent tenants' reasoning steps into micro-batched model
invocations; the compressed queue frees accelerator time, and B-PASTE's
speculation + cross-episode result store convert the recovered slack into
end-to-end speedup — while ``mean_auth_slowdown`` stays at 1.0 and QoS
violations stay at zero (batching never taxes the authoritative path).

With ``--with-llm``, additionally runs a real (reduced-config) LLM on the
ServingEngine with B-PASTE batch-slot speculation: the agent loop decodes
reasoning tokens on the engine; during each tool call, B-PASTE prefills
the predicted observation into a free slot so the follow-up reasoning is
already decoding when the tool returns (promotion = zero-copy slot
re-tag).  This path compiles a JAX model and takes minutes on CPU.

  PYTHONPATH=src python examples/speculative_serving.py --with-llm --arch qwen2-7b
"""
import argparse
import time


# ----------------------------------------------------------------------
# Part 1 (default): the batched edge-box serving configuration
# ----------------------------------------------------------------------
def run_edge_box_demo(n_episodes: int = 8, concurrency: int = 8,
                      max_batch: int = 8) -> None:
    from repro.core.interference import Machine
    from repro.core.patterns import PatternEngine
    from repro.core.runtime import run_mode
    from repro.core.workload import (
        WorkloadConfig, episodes_to_traces, make_episodes,
    )

    thor = Machine()                         # accel=1 Thor-class edge box
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=20))
    engine = PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))
    tenants = make_episodes(WorkloadConfig(
        seed=42, n_episodes=n_episodes, arrival_stagger=4.0,
        shared_frac=0.5, shared_pool=2))

    print(f"edge box (accel=1), {n_episodes} tenants, "
          f"concurrency={concurrency}:")
    results = {}
    for label, mode, memo, mb in [
        ("serial (no speculation)", "serial", False, 1),
        ("bpaste+memo (queue serial)", "bpaste", True, 1),
        ("bpaste+memo+batch", "bpaste", True, max_batch),
    ]:
        m = run_mode(tenants, engine, mode, thor, seed=7,
                     max_concurrent_episodes=concurrency, memo=memo,
                     model_max_batch=mb)
        s = m.summary()
        results[label] = s
        batch = ""
        if s["model_batched_steps"]:
            batch = (f"  batch_occ={s['model_batch_occupancy']:.2f} "
                     f"queue_delay={s['mean_model_queue_delay']:.2f}s")
        print(f"  {label:28s} makespan={s['makespan']:7.1f}  "
              f"auth_slowdown={s['mean_auth_slowdown']:.3f}  "
              f"qos_violations={s['qos_violations']:.0f}{batch}")
    serial = results["serial (no speculation)"]
    plain = results["bpaste+memo (queue serial)"]
    batched = results["bpaste+memo+batch"]
    print(f"  -> with the model-step queue serial, speculation barely moves "
          f"the edge box ({serial['makespan'] / plain['makespan']:.2f}x): "
          f"the queue IS the bottleneck")
    print(f"  -> batching the queue separates it: "
          f"{serial['makespan'] / batched['makespan']:.2f}x over serial, "
          f"authoritative protection intact")
    assert batched["makespan"] < serial["makespan"], "edge regime must separate"
    assert batched["mean_auth_slowdown"] <= 1.05 and batched["qos_violations"] == 0


# ----------------------------------------------------------------------
# Part 1b (default): sustained load — open-loop arrivals + shed ladder
# ----------------------------------------------------------------------
def run_open_loop_demo(rate: float = 0.1, n_episodes: int = 16,
                       concurrency: int = 4) -> None:
    from repro.core.interference import Machine
    from repro.core.patterns import PatternEngine
    from repro.core.runtime import run_mode
    from repro.core.workload import (
        WorkloadConfig, episodes_to_traces, make_episodes, open_loop_source,
    )

    thor = Machine()
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=20))
    engine = PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))

    def source():
        return open_loop_source(WorkloadConfig(
            seed=42, n_episodes=n_episodes, open_loop_rate=rate,
            shared_frac=0.5, shared_pool=2))

    print(f"\nopen loop: tenants arrive at rate={rate}/s "
          f"(exponential inter-arrivals), concurrency={concurrency}:")
    results = {}
    for label, mode, stack in [
        ("serial (no speculation)", "serial", {}),
        ("bpaste+stack (shed+linger)", "bpaste",
         dict(memo=True, model_max_batch=8, spec_model_steps=True,
              shed_alpha=1.0, adaptive_linger=True)),
    ]:
        m = run_mode([], engine, mode, thor, seed=7,
                     max_concurrent_episodes=concurrency,
                     episode_source=source(), **stack)
        s = m.summary()
        s["_served"] = len(m.tenant_sojourn)
        results[label] = s
        shed = ""
        if s["shed_passes"]:
            shed = (f"  shed_passes={s['shed_passes']:.0f} "
                    f"peak_backlog={s['shed_peak_backlog']:.0f}")
        print(f"  {label:28s} p95_sojourn={s['p95_sojourn']:7.1f}s  "
              f"auth_slowdown={s['mean_auth_slowdown']:.3f}  "
              f"qos_violations={s['qos_violations']:.0f}{shed}")
    for s in results.values():
        assert s["_served"] == n_episodes, "every tenant must be served"
        assert s["mean_auth_slowdown"] <= 1.0 + 1e-9
        assert s["qos_violations"] == 0
    print("  -> under sustained load the ladder sheds speculation first "
          "(never authoritative work): slowdown stays 1.000, QoS clean; "
          "the full goodput-vs-rate knee sweep lives in "
          "`python -m benchmarks.run --only serving`")


# ----------------------------------------------------------------------
# Part 2 (--with-llm): batch-slot speculation on a real reduced LLM
# ----------------------------------------------------------------------
def serve(spec_on: bool, cfg, params, episodes, pattern_engine, reason_tokens=5):
    from repro.core.events import Event
    from repro.core.hypothesis import HypothesisBuilder
    from repro.serving.engine import ServingEngine
    from repro.serving.spec_serving import SlotSpeculator, render_observation

    eng = ServingEngine(cfg, params, max_batch=4, max_len=192)
    spec = SlotSpeculator(eng, budget_slots=2)
    builder = HypothesisBuilder(pattern_engine)
    decode_steps = 0
    hits = 0
    t0 = time.time()
    for ep in episodes:
        history = []
        prompt = [2, 3, 4]
        slot = eng.add_request(prompt, request_id=ep.eid)
        for step in ep.steps[:4]:
            # reasoning: decode a few tokens on the authoritative slot
            for _ in range(reason_tokens):
                eng.step()
                decode_steps += 1
            # while the tool "runs", speculate likely continuations
            if spec_on and history:
                hyps = builder.build(history, beam_width=3)
                spec.admit([(h, h.q) for h in hyps], history_prompt=prompt)
                for _ in range(3):          # tool latency window
                    eng.step()
                    decode_steps += 1
            obs = render_observation(step.tool, {}, f"pred:{step.tool}", cfg.vocab_size)
            got = spec.match_and_promote(obs, ep.eid) if spec_on else None
            if got is not None:
                hits += 1
            history.append(Event("tool", step.tool, dict(step.args), {"ok": True}))
        spec.squash_all()
        for s in eng.slots:
            s.active = False
            s.request_id = None
    return time.time() - t0, decode_steps, hits, spec


def run_llm_demo(arch: str, n_episodes: int) -> None:
    import jax

    from repro.configs import get_config
    from repro.core.patterns import PatternEngine
    from repro.core.workload import (
        WorkloadConfig, episodes_to_traces, make_episodes,
    )
    from repro.models import model as model_mod

    cfg = get_config(arch).reduced()
    params = model_mod.init_params(jax.random.key(0), cfg)
    history = make_episodes(WorkloadConfig(seed=1, n_episodes=40))
    pe = PatternEngine(context_len=2, min_support=3).fit(episodes_to_traces(history))
    episodes = make_episodes(WorkloadConfig(seed=9, n_episodes=n_episodes))

    dt0, steps0, _, _ = serve(False, cfg, params, episodes, pe)
    dt1, steps1, hits, spec = serve(True, cfg, params, episodes, pe)
    print(f"baseline : {steps0} decode steps in {dt0:.1f}s")
    print(f"B-PASTE  : {steps1} decode steps in {dt1:.1f}s "
          f"(speculative slots admitted={spec.admitted}, promoted={spec.promotions}, "
          f"preempted={spec.preemptions})")
    print("promoted slots had their follow-up reasoning already decoded -> "
          "the tool-return -> next-action latency is hidden")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--episodes", type=int, default=8,
                    help="tenants in the edge-box demo (LLM demo caps at 3)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="model-step micro-batch cap for the edge-box demo")
    ap.add_argument("--with-llm", action="store_true",
                    help="also run the reduced-LLM ServingEngine demo "
                         "(compiles a JAX model; minutes on CPU)")
    args = ap.parse_args()
    run_edge_box_demo(n_episodes=args.episodes, max_batch=args.max_batch)
    run_open_loop_demo()
    if args.with_llm:
        run_llm_demo(args.arch, min(args.episodes, 3))


if __name__ == "__main__":
    main()
