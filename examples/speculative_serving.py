"""End-to-end driver: a real (reduced-config) LLM served with batched
requests on the ServingEngine, with B-PASTE batch-slot speculation.

The agent loop decodes reasoning tokens on the engine; tool calls run on
the host.  During each tool call, B-PASTE prefs the predicted observation
into a free slot so the follow-up reasoning is already decoding when the
tool returns (promotion = zero-copy slot re-tag).

  PYTHONPATH=src python examples/speculative_serving.py --arch qwen2-7b
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.core.hypothesis import HypothesisBuilder
from repro.core.patterns import PatternEngine
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.spec_serving import SlotSpeculator, render_observation


def serve(spec_on: bool, cfg, params, episodes, pattern_engine, reason_tokens=5):
    eng = ServingEngine(cfg, params, max_batch=4, max_len=192)
    spec = SlotSpeculator(eng, budget_slots=2)
    builder = HypothesisBuilder(pattern_engine)
    decode_steps = 0
    hits = 0
    t0 = time.time()
    for ep in episodes:
        history = []
        prompt = [2, 3, 4]
        slot = eng.add_request(prompt, request_id=ep.eid)
        for step in ep.steps[:4]:
            # reasoning: decode a few tokens on the authoritative slot
            for _ in range(reason_tokens):
                eng.step()
                decode_steps += 1
            # while the tool "runs", speculate likely continuations
            if spec_on and history:
                hyps = builder.build(history, beam_width=3)
                spec.admit([(h, h.q) for h in hyps], history_prompt=prompt)
                for _ in range(3):          # tool latency window
                    eng.step()
                    decode_steps += 1
            obs = render_observation(step.tool, {}, f"pred:{step.tool}", cfg.vocab_size)
            got = spec.match_and_promote(obs, ep.eid) if spec_on else None
            if got is not None:
                hits += 1
            from repro.core.events import Event
            history.append(Event("tool", step.tool, dict(step.args), {"ok": True}))
        spec.squash_all()
        for s in eng.slots:
            s.active = False
            s.request_id = None
    return time.time() - t0, decode_steps, hits, spec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--episodes", type=int, default=3)
    args = ap.parse_args()
    cfg = get_config(args.arch).reduced()
    params = model_mod.init_params(jax.random.key(0), cfg)
    history = make_episodes(WorkloadConfig(seed=1, n_episodes=40))
    pe = PatternEngine(context_len=2, min_support=3).fit(episodes_to_traces(history))
    episodes = make_episodes(WorkloadConfig(seed=9, n_episodes=args.episodes))

    dt0, steps0, _, _ = serve(False, cfg, params, episodes, pe)
    dt1, steps1, hits, spec = serve(True, cfg, params, episodes, pe)
    print(f"baseline : {steps0} decode steps in {dt0:.1f}s")
    print(f"B-PASTE  : {steps1} decode steps in {dt1:.1f}s "
          f"(speculative slots admitted={spec.admitted}, promoted={spec.promotions}, "
          f"preempted={spec.preemptions})")
    print("promoted slots had their follow-up reasoning already decoded -> "
          "the tool-return -> next-action latency is hidden")


if __name__ == "__main__":
    main()
