"""B-PASTE core: mining, scoring, admission, sandbox, safety — unit +
property tests (hypothesis) on the system's invariants.

The property-testing package ``hypothesis`` (requirements-dev.txt) shares a
name with ``repro.core.hypothesis`` but not an import path; when it is not
installed, the property tests below skip with a reason instead of failing
the whole module at collection (the unit tests still run)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:                     # pragma: no cover
    HYPOTHESIS_SKIP = "hypothesis not installed (pip install -r requirements-dev.txt)"

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            def shim():                          # zero-arg: strategies never run
                pytest.skip(HYPOTHESIS_SKIP)
            shim.__name__ = f.__name__
            shim.__doc__ = f.__doc__
            return shim
        return deco

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.core import admission, interference, scoring
from repro.core.events import (
    DEFAULT_TOOLS, Event, ResourceVector, SafetyLevel, signature,
)
from repro.core.hypothesis import BranchHypothesis, HypothesisBuilder, Node, NodeKind
from repro.core.interference import Machine
from repro.core.mining.prefixspan import conditional_next, prefixspan
from repro.core.patterns import PatternEngine
from repro.core.safety import EligibilityPolicy, FULL_POLICY, READ_ONLY_POLICY
from repro.core.sandbox import AgentState, Sandbox
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes


# ======================================================================
# PrefixSpan
# ======================================================================

def test_prefixspan_counts_exact():
    seqs = [list("abcab"), list("abc"), list("acb")]
    pats = prefixspan(seqs, min_support=2, max_len=3, max_gap=1)
    by_items = {p.items: p.support for p in pats}
    assert by_items[("a", "b")] == 2        # contiguous in seqs 0,1
    assert by_items[("a", "b", "c")] == 2


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.sampled_from("abcd"), min_size=1, max_size=8),
                min_size=1, max_size=8))
def test_prefixspan_support_sound(seqs):
    """Property: every mined pattern occurs (gap-bounded) in >= support seqs."""
    pats = prefixspan(seqs, min_support=2, max_len=4, max_gap=2)

    def occurs(seq, items, max_gap=2):
        pos = 0
        for it in items:
            found = False
            for j in range(pos, min(len(seq), pos + max_gap)):
                if seq[j] == it:
                    pos = j + 1
                    found = True
                    break
            if not found:
                return False
        return True

    for p in pats:
        n = sum(occurs(s, p.items) for s in seqs)
        assert n >= p.support >= 2


def test_conditional_next_normalized():
    seqs = [list("abab"), list("abc")]
    tables = conditional_next(seqs, context_len=2, min_count=1)
    for ctx, t in tables.items():
        assert abs(sum(t.values()) - 1.0) < 1e-9


# ======================================================================
# Interference model
# ======================================================================

def test_slowdown_bottleneck():
    cap = np.array([4.0, 100.0, 100.0, 1.0])
    jobs = np.array([[4.0, 10, 0, 0], [4.0, 10, 0, 0]])  # 2x cpu-saturating
    s = interference.slowdowns(jobs, cap)
    np.testing.assert_allclose(s, [2.0, 2.0])


@settings(max_examples=50, deadline=None)
@given(st.lists(st.lists(st.floats(0, 5), min_size=4, max_size=4), min_size=1, max_size=6))
def test_slowdown_monotone_in_load(demands):
    """Property: adding a job never speeds anyone up."""
    cap = np.array([4.0, 50.0, 100.0, 1.0])
    d = np.array(demands)
    base = interference.slowdowns(d, cap)
    extra = np.vstack([d, [2.0, 10.0, 10.0, 0.0]])
    after = interference.slowdowns(extra, cap)[: len(d)]
    assert np.all(after + 1e-12 >= base)


# ======================================================================
# Scoring / admission
# ======================================================================

def _mk_hyp(hid, tools, q=0.8):
    nodes, edges = [], []
    for i, t in enumerate(tools):
        spec = DEFAULT_TOOLS[t]
        nodes.append(Node(i, NodeKind.TOOL, t, spec.level, spec.rho,
                          spec.base_latency))
        if i:
            edges.append((i - 1, i))
    return BranchHypothesis(hid, nodes, edges, q, context_key=("x",))


def test_eu_decreases_with_interference():
    sc = scoring.Scorer(Machine())
    h = _mk_hyp(0, ["grep", "read"])
    eu_idle, _, _ = sc.score([h], np.zeros(4), idle_window=8.0)
    eu_busy, _, _ = sc.score([h], np.array([11.9, 99.0, 490.0, 1.0]), idle_window=8.0)
    assert eu_idle[0] > eu_busy[0]


def test_eu_scales_with_q():
    sc = scoring.Scorer(Machine())
    h1 = _mk_hyp(0, ["grep", "read"], q=0.9)
    h2 = _mk_hyp(1, ["grep", "read"], q=0.3)
    eu, _, _ = sc.score([h1, h2], np.zeros(4), idle_window=8.0)
    assert eu[0] > eu[1] > 0


def test_critical_path_matches_networkx():
    import networkx as nx
    sc = scoring.Scorer(Machine(), k_max=2, n_max=8)
    h = _mk_hyp(0, ["grep", "read", "parse", "search"])
    pb = scoring.pack_beam([h], 2, 8)
    # ΔU = longest path over post-prefix nodes; make prefix empty to compare
    pb.prefix_mask[:] = 0
    import jax.numpy as jnp
    du = scoring._critical_path(
        jnp.asarray(pb.adj), jnp.asarray(pb.node_lat * pb.node_prob),
        jnp.asarray(pb.node_mask), n_iters=8,
    )
    g = nx.DiGraph()
    for i, n in enumerate(h.nodes):
        g.add_node(i, w=n.est_latency)
    g.add_edges_from(h.edges)
    want = max(
        sum(h.nodes[i].est_latency for i in path)
        for path in (nx.dag_longest_path(g, weight=None),)
    )
    want = 0.0
    for path in nx.all_simple_paths(g, 0, len(h.nodes) - 1):
        want = max(want, sum(h.nodes[i].est_latency for i in path))
    np.testing.assert_allclose(float(du[0]), want, rtol=1e-6)


def test_admission_respects_budget():
    sc = scoring.Scorer(Machine())
    hyps = [_mk_hyp(i, ["test"]) for i in range(4)]   # cpu=2 each
    slack = np.array([12.0, 100.0, 500.0, 1.0])
    budget = np.array([4.0, 100.0, 500.0, 1.0])       # only 2 test jobs fit
    res = admission.greedy_admit(hyps, sc, slack, budget, np.zeros(4))
    assert len(res.admitted) <= 2
    total = sum(admission._prefix_rho(h) for h in res.admitted) if res.admitted else np.zeros(4)
    assert np.all(np.asarray(total) <= budget + 1e-9)


def test_greedy_close_to_exact():
    sc = scoring.Scorer(Machine())
    hyps = [_mk_hyp(i, t) for i, t in enumerate(
        [["grep", "read"], ["search", "visit"], ["test"], ["parse"]])]
    slack = np.array([6.0, 50.0, 200.0, 1.0])
    budget = np.array([6.0, 50.0, 200.0, 1.0])
    res = admission.greedy_admit(hyps, sc, slack, budget, np.zeros(4))
    greedy_total = sum(res.eu.values())
    _, exact_total = admission.exact_admit(hyps, sc, slack, budget, np.zeros(4))
    assert greedy_total >= 0.6 * exact_total  # bounded greedy gap


# ======================================================================
# Sandbox (CoW) properties
# ======================================================================

@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from("MFE"), st.sampled_from("abcdef"),
                          st.integers(0, 99)), max_size=20))
def test_sandbox_isolation(ops_list):
    """Property: sandbox writes NEVER leak to base before commit; squash
    leaves the base bit-identical."""
    base = AgentState(memory={"m0": 1}, fs={"f0": "x"}, env={"e0": True})
    snapshot = (dict(base.memory), dict(base.fs), dict(base.env))
    sb = Sandbox(base, hid=1)
    views = {"M": sb.M, "F": sb.F, "E": sb.E}
    for ns, key, val in ops_list:
        views[ns].set(key, val)
    assert (base.memory, base.fs, base.env) == snapshot
    sb.squash()
    assert (base.memory, base.fs, base.env) == snapshot


def test_sandbox_commit_and_stale():
    base = AgentState(fs={"a": 1})
    sb = Sandbox(base, hid=1)
    sb.F.set("b", 2)
    assert sb.commit()
    assert base.fs == {"a": 1, "b": 2}
    sb2 = Sandbox(base, hid=2)
    sb2.F.set("c", 3)
    base.fs["a"] = 99
    base.bump()
    assert not sb2.commit()       # stale base -> promotion refused
    assert "c" not in base.fs


def test_sandbox_read_through_and_read_set():
    base = AgentState(fs={"a": 1})
    sb = Sandbox(base, hid=1)
    assert sb.F.get("a") == 1
    assert "F:a" in sb.base_read_set
    sb.F.set("a", 5)
    assert sb.F.get("a") == 5       # own write wins
    assert base.fs["a"] == 1


# ======================================================================
# Safety policy
# ======================================================================

def test_safety_levels_and_transforms():
    pol = FULL_POLICY
    assert pol.speculative_form("search") == ("search", False)
    assert pol.speculative_form("edit") == ("edit", False)
    assert pol.speculative_form("deploy") is None or pol.speculative_form("deploy")[1]
    ro = READ_ONLY_POLICY
    assert ro.speculative_form("edit") == ("pip_download", True) or True
    # pip_install under read-only policy degrades to its dry-run transform
    form = ro.speculative_form("pip_install")
    assert form == ("pip_download", True)
    assert ro.speculative_form("search") == ("search", False)


def test_non_speculative_never_eligible_without_transform():
    pol = EligibilityPolicy(max_level=SafetyLevel.STAGED_WRITE, transforms={})
    pol.transforms.pop("deploy", None)
    assert pol.speculative_form("deploy") is None


# ======================================================================
# Pattern engine + hypotheses
# ======================================================================

def _engine():
    eps = make_episodes(WorkloadConfig(seed=1, n_episodes=40))
    return PatternEngine(context_len=2, min_support=3).fit(episodes_to_traces(eps))


def test_bindings_mined():
    pe = _engine()
    by = {(tuple(s[1] for s in pt.context), pt.tool): pt for pt in pe.patterns}
    pt = by[(("search",), "visit")]
    assert any(b.arg_name == "url" for b in pt.bindings)


def test_missing_args_detected():
    pe = _engine()
    edits = [pt for pt in pe.patterns if pt.tool == "edit"]
    assert edits and all("change" in pt.missing_args for pt in edits)


def test_hypothesis_bounded():
    pe = _engine()
    b = HypothesisBuilder(pe, max_depth=3, max_nodes=6)
    eps = make_episodes(WorkloadConfig(seed=5, n_episodes=3))
    traces = episodes_to_traces(eps)
    hyps = b.build(traces[0][:2], beam_width=8)
    assert hyps
    for h in hyps:
        assert len(h.nodes) <= 6 + 2    # + model node & barriers bound
        assert 0 < h.q <= 1.0
        # prefix never contains model nodes or missing-arg tools
        for n in h.safe_prefix():
            assert n.kind != NodeKind.MODEL
            assert not n.missing_args
