"""B-PASTE core: mining, scoring, admission, sandbox, safety — unit +
property tests (hypothesis) on the system's invariants.

The property-testing package ``hypothesis`` (requirements-dev.txt) shares a
name with ``repro.core.hypothesis`` but not an import path; when it is not
installed, the property tests below skip with a reason instead of failing
the whole module at collection (the unit tests still run)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:                     # pragma: no cover
    HYPOTHESIS_SKIP = "hypothesis not installed (pip install -r requirements-dev.txt)"

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            def shim():                          # zero-arg: strategies never run
                pytest.skip(HYPOTHESIS_SKIP)
            shim.__name__ = f.__name__
            shim.__doc__ = f.__doc__
            return shim
        return deco

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.core import admission, interference, scoring
from repro.core.events import (
    DEFAULT_TOOLS, Event, ResourceVector, SafetyLevel, signature,
)
from repro.core.hypothesis import BranchHypothesis, HypothesisBuilder, Node, NodeKind
from repro.core.interference import Machine
from repro.core.mining.prefixspan import conditional_next, prefixspan
from repro.core.patterns import PatternEngine
from repro.core.safety import EligibilityPolicy, FULL_POLICY, READ_ONLY_POLICY
from repro.core.sandbox import AgentState, Sandbox
from repro.core.workload import (
    WorkloadConfig, episodes_to_traces, make_episodes, open_loop_source,
)


# ======================================================================
# PrefixSpan
# ======================================================================

def test_prefixspan_counts_exact():
    seqs = [list("abcab"), list("abc"), list("acb")]
    pats = prefixspan(seqs, min_support=2, max_len=3, max_gap=1)
    by_items = {p.items: p.support for p in pats}
    assert by_items[("a", "b")] == 2        # contiguous in seqs 0,1
    assert by_items[("a", "b", "c")] == 2


def _occurs(seq, items, max_gap=2):
    """Gap-bounded subsequence match over ALL occurrence chains (a greedy
    earliest-occurrence scan is incomplete: in [a b a c] with max_gap=2 only
    the second 'a' reaches 'c').  First item may start anywhere."""
    poss = {j + 1 for j, x in enumerate(seq) if x == items[0]}
    for it in items[1:]:
        nxt = set()
        for pos in poss:
            for j in range(pos, min(len(seq), pos + max_gap)):
                if seq[j] == it:
                    nxt.add(j + 1)
        poss = nxt
        if not poss:
            return False
    return True


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.sampled_from("abcd"), min_size=1, max_size=8),
                min_size=1, max_size=8))
def test_prefixspan_support_sound(seqs):
    """Property: every mined pattern occurs (gap-bounded) in >= support seqs."""
    pats = prefixspan(seqs, min_support=2, max_len=4, max_gap=2)
    for p in pats:
        n = sum(_occurs(s, p.items) for s in seqs)
        assert n >= p.support >= 2


def test_prefixspan_all_occurrences_regression():
    """Gap-bounded projection must track every in-window occurrence: with
    max_gap=2, [a b a c] supports (a, c) via the second 'a' (adjacent to
    'c'); keeping only the earliest 'a' made the pattern invisible."""
    pats = prefixspan([list("abac")], min_support=1, max_len=3, max_gap=2)
    by_items = {p.items: p.support for p in pats}
    assert by_items.get(("a", "c")) == 1
    assert by_items.get(("a", "b", "c")) == 1   # b->c skips one item, in gap
    assert by_items.get(("c", "a")) is None     # order still respected
    # two supporting sequences, one via a late re-occurrence each
    pats2 = prefixspan([list("abac"), list("xaxc")], min_support=2,
                       max_len=2, max_gap=2)
    by2 = {p.items: p.support for p in pats2}
    assert by2.get(("a", "c")) == 2


def test_conditional_next_normalized():
    seqs = [list("abab"), list("abc")]
    tables = conditional_next(seqs, context_len=2, min_count=1)
    for t in tables.values():
        assert abs(sum(t.values()) - 1.0) < 1e-9


# ======================================================================
# Interference model
# ======================================================================

def test_slowdown_bottleneck():
    cap = np.array([4.0, 100.0, 100.0, 1.0])
    jobs = np.array([[4.0, 10, 0, 0], [4.0, 10, 0, 0]])  # 2x cpu-saturating
    s = interference.slowdowns(jobs, cap)
    np.testing.assert_allclose(s, [2.0, 2.0])


@settings(max_examples=50, deadline=None)
@given(st.lists(st.lists(st.floats(0, 5), min_size=4, max_size=4), min_size=1, max_size=6))
def test_slowdown_monotone_in_load(demands):
    """Property: adding a job never speeds anyone up."""
    cap = np.array([4.0, 50.0, 100.0, 1.0])
    d = np.array(demands)
    base = interference.slowdowns(d, cap)
    extra = np.vstack([d, [2.0, 10.0, 10.0, 0.0]])
    after = interference.slowdowns(extra, cap)[: len(d)]
    assert np.all(after + 1e-12 >= base)


# ======================================================================
# Scoring / admission
# ======================================================================

def _mk_hyp(hid, tools, q=0.8):
    nodes, edges = [], []
    for i, t in enumerate(tools):
        spec = DEFAULT_TOOLS[t]
        nodes.append(Node(i, NodeKind.TOOL, t, spec.level, spec.rho,
                          spec.base_latency))
        if i:
            edges.append((i - 1, i))
    return BranchHypothesis(hid, nodes, edges, q, context_key=("x",))


def test_eu_decreases_with_interference():
    sc = scoring.Scorer(Machine())
    h = _mk_hyp(0, ["grep", "read"])
    eu_idle, _, _ = sc.score([h], np.zeros(4), idle_window=8.0)
    eu_busy, _, _ = sc.score([h], np.array([11.9, 99.0, 490.0, 1.0]), idle_window=8.0)
    assert eu_idle[0] > eu_busy[0]


def test_tenant_fairness_weights():
    """w_e = 1/(1 + alpha*share): no share -> no discount, heavier in-flight
    speculative share -> stronger discount, alpha=0 disables, and weights
    stay positive (the eu>0 admission threshold must never flip sign)."""
    w = scoring.tenant_fairness_weights({0: 0.0, 1: 2.0}, alpha=1.0)
    assert w[0] == pytest.approx(1.0)
    assert w[1] == pytest.approx(1.0 / 3.0)
    assert scoring.tenant_fairness_weights({0: 5.0}, alpha=0.0)[0] == 1.0
    assert all(v > 0 for v in
               scoring.tenant_fairness_weights({0: 1e6}, alpha=3.0).values())


def test_eu_scales_with_q():
    sc = scoring.Scorer(Machine())
    h1 = _mk_hyp(0, ["grep", "read"], q=0.9)
    h2 = _mk_hyp(1, ["grep", "read"], q=0.3)
    eu, _, _ = sc.score([h1, h2], np.zeros(4), idle_window=8.0)
    assert eu[0] > eu[1] > 0


def test_critical_path_matches_networkx():
    import networkx as nx
    sc = scoring.Scorer(Machine(), k_max=2, n_max=8)
    h = _mk_hyp(0, ["grep", "read", "parse", "search"])
    pb = scoring.pack_beam([h], 2, 8)
    # ΔU = longest path over post-prefix nodes; make prefix empty to compare
    pb.prefix_mask[:] = 0
    import jax.numpy as jnp
    du = scoring._critical_path(
        jnp.asarray(pb.adj), jnp.asarray(pb.node_lat * pb.node_prob),
        jnp.asarray(pb.node_mask), n_iters=8,
    )
    g = nx.DiGraph()
    for i, n in enumerate(h.nodes):
        g.add_node(i, w=n.est_latency)
    g.add_edges_from(h.edges)
    want = max(
        sum(h.nodes[i].est_latency for i in path)
        for path in (nx.dag_longest_path(g, weight=None),)
    )
    want = 0.0
    for path in nx.all_simple_paths(g, 0, len(h.nodes) - 1):
        want = max(want, sum(h.nodes[i].est_latency for i in path))
    np.testing.assert_allclose(float(du[0]), want, rtol=1e-6)


def test_admission_respects_budget():
    sc = scoring.Scorer(Machine())
    hyps = [_mk_hyp(i, ["test"]) for i in range(4)]   # cpu=2 each
    slack = np.array([12.0, 100.0, 500.0, 1.0])
    budget = np.array([4.0, 100.0, 500.0, 1.0])       # only 2 test jobs fit
    res = admission.greedy_admit(hyps, sc, slack, budget, np.zeros(4))
    assert len(res.admitted) <= 2
    total = sum(admission._prefix_rho(h) for h in res.admitted) if res.admitted else np.zeros(4)
    assert np.all(np.asarray(total) <= budget + 1e-9)


def test_greedy_close_to_exact():
    sc = scoring.Scorer(Machine())
    hyps = [_mk_hyp(i, t) for i, t in enumerate(
        [["grep", "read"], ["search", "visit"], ["test"], ["parse"]])]
    slack = np.array([6.0, 50.0, 200.0, 1.0])
    budget = np.array([6.0, 50.0, 200.0, 1.0])
    res = admission.greedy_admit(hyps, sc, slack, budget, np.zeros(4))
    greedy_total = sum(res.eu.values())
    _, exact_total = admission.exact_admit(hyps, sc, slack, budget, np.zeros(4))
    assert greedy_total >= 0.6 * exact_total  # bounded greedy gap


# ======================================================================
# Sandbox (CoW) properties
# ======================================================================

@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from("MFE"), st.sampled_from("abcdef"),
                          st.integers(0, 99)), max_size=20))
def test_sandbox_isolation(ops_list):
    """Property: sandbox writes NEVER leak to base before commit; squash
    leaves the base bit-identical."""
    base = AgentState(memory={"m0": 1}, fs={"f0": "x"}, env={"e0": True})
    snapshot = (dict(base.memory), dict(base.fs), dict(base.env))
    sb = Sandbox(base, hid=1)
    views = {"M": sb.M, "F": sb.F, "E": sb.E}
    for ns, key, val in ops_list:
        views[ns].set(key, val)
    assert (base.memory, base.fs, base.env) == snapshot
    sb.squash()
    assert (base.memory, base.fs, base.env) == snapshot


def test_sandbox_commit_and_stale():
    base = AgentState(fs={"a": 1})
    sb = Sandbox(base, hid=1)
    sb.F.set("b", 2)
    assert sb.commit()
    assert base.fs == {"a": 1, "b": 2}
    sb2 = Sandbox(base, hid=2)
    sb2.F.set("c", 3)
    base.fs["a"] = 99
    base.bump()
    assert not sb2.commit()       # stale base -> promotion refused
    assert "c" not in base.fs


def test_sandbox_read_through_and_read_set():
    base = AgentState(fs={"a": 1})
    sb = Sandbox(base, hid=1)
    assert sb.F.get("a") == 1
    assert "F:a" in sb.base_read_set
    sb.F.set("a", 5)
    assert sb.F.get("a") == 5       # own write wins
    assert base.fs["a"] == 1


# ======================================================================
# Safety policy
# ======================================================================

def test_safety_levels_and_transforms():
    pol = FULL_POLICY
    assert pol.speculative_form("search") == ("search", False)
    assert pol.speculative_form("edit") == ("edit", False)
    assert pol.speculative_form("deploy") is None or pol.speculative_form("deploy")[1]
    ro = READ_ONLY_POLICY
    # read-only policy has no transform for edit: not speculable at all
    assert ro.speculative_form("edit") is None
    # pip_install under read-only policy degrades to its dry-run transform
    form = ro.speculative_form("pip_install")
    assert form == ("pip_download", True)
    assert ro.speculative_form("search") == ("search", False)


def test_non_speculative_never_eligible_without_transform():
    pol = EligibilityPolicy(max_level=SafetyLevel.STAGED_WRITE, transforms={})
    pol.transforms.pop("deploy", None)
    assert pol.speculative_form("deploy") is None


def test_nonspec_override_is_an_operator_ban():
    """ISSUE 7 satellite: overriding a tool to NON_SPECULATIVE must win over
    its spec transform.  Before the fix, ``__post_init__`` auto-installed
    ``pip_install``'s dry-run transform regardless, so the banned tool kept
    speculating through the degraded variant — the override silently lost."""
    pol = EligibilityPolicy(
        overrides={"pip_install": SafetyLevel.NON_SPECULATIVE})
    assert "pip_install" not in pol.transforms   # auto-install suppressed
    assert pol.speculative_form("pip_install") is None
    assert not pol.eligible("pip_install")
    assert pol.servable("pip_install") is None
    # ... even when the operator ALSO spelled the transform out explicitly
    pol2 = EligibilityPolicy(
        overrides={"pip_install": SafetyLevel.NON_SPECULATIVE},
        transforms={"pip_install": "pip_download"})
    assert pol2.speculative_form("pip_install") is None
    # an unrelated ban leaves pip_install's auto-transform in place
    pol3 = EligibilityPolicy(overrides={"edit": SafetyLevel.NON_SPECULATIVE})
    assert pol3.transforms.get("pip_install") == "pip_download"


def test_operator_transform_reroutes_nonspec_tool():
    pol = EligibilityPolicy(transforms={"deploy": "search"})
    assert pol.speculative_form("deploy") == ("search", True)
    assert pol.eligible("deploy")


_POLICY_TOOLS = sorted(DEFAULT_TOOLS)
_POLICY_LEVELS = list(SafetyLevel)


@settings(max_examples=200, deadline=None)
@given(
    max_level=st.sampled_from(_POLICY_LEVELS),
    overrides=st.dictionaries(st.sampled_from(_POLICY_TOOLS),
                              st.sampled_from(_POLICY_LEVELS), max_size=4),
    transforms=st.dictionaries(st.sampled_from(_POLICY_TOOLS),
                               st.sampled_from(_POLICY_TOOLS), max_size=3),
)
def test_policy_invariants(max_level, overrides, transforms):
    """ISSUE 7 satellite: EligibilityPolicy invariants over random operator
    configurations (presets are just three points of this space):

    * ``eligible(t)`` is definitionally ``speculative_form(t) is not None``;
    * any returned run form clears the policy: its effective level is
      neither NON_SPECULATIVE nor above ``max_level``, and the
      ``transformed`` flag is exactly "the run tool differs";
    * ``servable(t) != None  ⇒  eligible(t)`` (the store never serves a
      result speculation could not have produced);
    * ``servable(t) == "replay"  ⇒  requires_sandbox_write(t)``;
    * a NON_SPECULATIVE override bans both speculation and serving."""
    pol = EligibilityPolicy(max_level=max_level, overrides=dict(overrides),
                            transforms=dict(transforms))
    for tool in _POLICY_TOOLS:
        form = pol.speculative_form(tool)
        assert pol.eligible(tool) == (form is not None)
        if form is not None:
            run_tool, transformed = form
            lvl = pol.level(run_tool)
            assert lvl != SafetyLevel.NON_SPECULATIVE
            assert lvl <= max_level
            assert transformed == (run_tool != tool)
        sv = pol.servable(tool)
        if sv is not None:
            assert pol.eligible(tool)
        if sv == "replay":
            assert pol.requires_sandbox_write(tool)
        if overrides.get(tool) == SafetyLevel.NON_SPECULATIVE:
            assert form is None and sv is None


# ======================================================================
# Pattern engine + hypotheses
# ======================================================================

def _engine():
    eps = make_episodes(WorkloadConfig(seed=1, n_episodes=40))
    return PatternEngine(context_len=2, min_support=3).fit(episodes_to_traces(eps))


def test_bindings_mined():
    pe = _engine()
    by = {(tuple(s[1] for s in pt.context), pt.tool): pt for pt in pe.patterns}
    pt = by[(("search",), "visit")]
    assert any(b.arg_name == "url" for b in pt.bindings)


def test_missing_args_detected():
    pe = _engine()
    edits = [pt for pt in pe.patterns if pt.tool == "edit"]
    assert edits and all("change" in pt.missing_args for pt in edits)


def test_mine_bindings_denominator_over_all_occurrences():
    """Regression: each offset's hit fraction was computed against an
    offset-specific denominator (only occurrences with len(hist) >= off), so
    a rarely-reachable offset could win with frac 1.0 off a tiny sample.
    Here offset -1 reproduces the arg in 2/3 of ALL occurrences while
    offset -2 exists in only one occurrence (where it matches): the biased
    miner scored -2 at 1/1 = 1.0 and picked it; the fixed miner scores it
    1/3 and keeps the reliable -1 binding."""
    from repro.core.patterns import mine_bindings
    u1, u2, u3 = "http://a", "http://b", "http://c"
    t1 = [Event("tool", "search", {"query": "q1"}, {"top": u1}),
          Event("tool", "visit", {"url": u1}, {"path": "p1"})]
    t2 = [Event("tool", "search", {"query": "q2"}, {"top": u2}),
          Event("tool", "visit", {"url": u2}, {"path": "p2"})]
    t3 = [Event("tool", "read", {"path": u3}, u3),        # offset -2 decoy
          Event("tool", "search", {"query": "q3"}, {"top": "http://other"}),
          Event("tool", "visit", {"url": u3}, {"path": "p3"})]
    ctx = (signature(t1[0]),)
    bindings, missing = mine_bindings([t1, t2, t3], ctx, "visit",
                                      min_frac=0.6)
    by = {b.arg_name: b for b in bindings}
    assert "url" in by
    assert by["url"].source_offset == -1
    assert by["url"].source_field == "top"


def test_hypothesis_bounded():
    pe = _engine()
    b = HypothesisBuilder(pe, max_depth=3, max_nodes=6)
    eps = make_episodes(WorkloadConfig(seed=5, n_episodes=3))
    traces = episodes_to_traces(eps)
    hyps = b.build(traces[0][:2], beam_width=8)
    assert hyps
    for h in hyps:
        assert len(h.nodes) <= 6 + 2    # + model node & barriers bound
        assert 0 < h.q <= 1.0
        # prefix never contains model nodes or missing-arg tools
        for n in h.safe_prefix():
            assert n.kind != NodeKind.MODEL
            assert not n.missing_args


def test_tree_builder_emits_branching_subgraphs():
    """Tree assembly: some hypothesis carries a branch point (an interior
    tool node with >1 child), children split the parent's follow mass via
    the empirical conditional probabilities, and every non-MODEL node has
    at most one parent (unique root paths)."""
    pe = _engine()
    b = HypothesisBuilder(pe, assembly="tree", max_nodes=11)
    eps = make_episodes(WorkloadConfig(seed=5, n_episodes=6))
    traces = episodes_to_traces(eps)
    branched = False
    for tr in traces:
        for cut in range(1, min(len(tr), 5)):
            for h in b.build(tr[:cut], beam_width=8):
                outdeg = {}
                for i, _ in h.edges:
                    outdeg[i] = outdeg.get(i, 0) + 1
                model_idx = [n.idx for n in h.nodes if n.kind == NodeKind.MODEL]
                parents = h.parent_map()
                for n in h.nodes:
                    if n.idx not in model_idx:
                        assert len(parents.get(n.idx, ())) <= 1
                def first_tool_below(j, h=h, model_idx=model_idx):
                    # follow PREP/BARRIER helpers down to the branch's tool
                    while h.nodes[j].kind != NodeKind.TOOL:
                        nxt = [b2 for a2, b2 in h.edges if a2 == j
                               and b2 not in model_idx]
                        if not nxt:
                            return None
                        j = nxt[0]
                    return h.nodes[j]
                for i, deg in outdeg.items():
                    if deg > 1 and i not in model_idx:
                        branched = True
                        kids = [first_tool_below(j) for a, j in h.edges
                                if a == i and j not in model_idx]
                        mass = sum(k.cond_prob for k in kids if k is not None)
                        assert mass <= 1.0 + 1e-9
    assert branched


def test_tree_builder_fills_beam_across_roots():
    """Multi-root fill: with >1 predicted root, the beam holds hypotheses
    for more than one distinct root tool (no first-root monopoly)."""
    pe = _engine()
    b = HypothesisBuilder(pe, assembly="tree")
    eps = make_episodes(WorkloadConfig(seed=5, n_episodes=6))
    traces = episodes_to_traces(eps)
    best = 0
    for tr in traces:
        for cut in range(1, min(len(tr), 5)):
            hyps = b.build(tr[:cut], beam_width=8)
            roots = {h.nodes[0].tool if h.nodes[0].kind == NodeKind.TOOL
                     else next(n.tool for n in h.nodes if n.kind == NodeKind.TOOL)
                     for h in hyps}
            best = max(best, len(roots))
    assert best >= 2


def test_safe_prefix_is_per_branch_frontier():
    """A blocked branch (missing-args tool) must not cut off its sibling:
    the prefix is a frontier region over the DAG, not a list prefix."""
    from repro.core.events import ResourceVector
    spec = DEFAULT_TOOLS["read"]
    n0 = Node(0, NodeKind.TOOL, "read", spec.level, spec.rho, 1.0)
    n1 = Node(1, NodeKind.TOOL, "edit", SafetyLevel.STAGED_WRITE,
              ResourceVector(0.5, 1, 10, 0), 1.0, missing_args=("change",))
    n2 = Node(2, NodeKind.TOOL, "parse", DEFAULT_TOOLS["parse"].level,
              DEFAULT_TOOLS["parse"].rho, 2.0)
    n3 = Node(3, NodeKind.TOOL, "grep", DEFAULT_TOOLS["grep"].level,
              DEFAULT_TOOLS["grep"].rho, 1.5)
    # read -> {edit(missing args) -> grep, parse}
    h = BranchHypothesis(0, [n0, n1, n2, n3], [(0, 1), (0, 2), (1, 3)],
                         q=0.9, context_key=("x",))
    ids = {n.idx for n in h.safe_prefix()}
    assert ids == {0, 2}          # sibling parse survives; edit subtree bounded
    assert h.path_to(3) == [0, 1, 3]


# ======================================================================
# Open-loop arrival process (workload.open_loop_source)
# ======================================================================

def _arrival_cfg(seed, n, stagger=0.0, rate=0.0):
    return WorkloadConfig(seed=seed, n_episodes=n,
                          arrival_stagger=stagger, open_loop_rate=rate)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.floats(0.0, 8.0),
       st.floats(0.0, 4.0),
       st.integers(1, 12))
def test_open_loop_arrivals_seeded_deterministic(seed, stagger, rate, n):
    """The arrival process is a pure function of the config: two fresh
    pulls of the lazy source agree episode-for-episode (eid, kind, step
    count, arrival), and the materialised roster is the same stream."""
    def key(e):
        return (e.eid, e.kind, len(e.steps), e.arrival)

    cfg = _arrival_cfg(seed, n, stagger, rate)
    a = list(open_loop_source(cfg))
    b = list(open_loop_source(cfg))
    assert [key(e) for e in a] == [key(e) for e in b]
    assert [key(e) for e in make_episodes(cfg)] == [key(e) for e in a]


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.floats(0.0, 8.0),
       st.floats(0.0, 4.0),
       st.integers(1, 16))
def test_open_loop_arrivals_monotone(seed, stagger, rate, n):
    """Arrivals are nondecreasing in eid (the lazy source's contract: the
    runtime may stop pumping at the first future arrival), and both knobs
    off keeps every tenant at t=0 (the legacy closed-loop roster)."""
    arr = [e.arrival for e in open_loop_source(_arrival_cfg(
        seed, n, stagger, rate))]
    assert all(b >= a for a, b in zip(arr, arr[1:], strict=False))
    assert all(a >= 0.0 for a in arr)
    if stagger == 0.0 and rate == 0.0:
        assert arr == [0.0] * n


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([0.5, 1.0, 4.0]))
def test_open_loop_mean_interarrival_matches_rate(seed, rate):
    """Offered load calibrates: with stagger off, inter-arrival gaps are
    iid Exp(1/rate), so the sample mean lands within 4 standard errors of
    1/rate (gap 0 is eid 0's own draw — every episode is charged)."""
    n = 500
    arr = [e.arrival for e in open_loop_source(_arrival_cfg(
        seed, n, rate=rate))]
    gaps = np.diff([0.0] + arr)
    assert np.all(gaps >= 0.0)
    assert abs(float(np.mean(gaps)) * rate - 1.0) < 4.0 / np.sqrt(n)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_open_loop_stagger_rate_compose_additively(seed):
    """stagger and open_loop_rate compose as independent additive delays:
    eid>0 gaps average stagger + 1/rate, while eid 0 is charged only the
    open-loop draw (stagger never delays the first tenant)."""
    stagger, rate, n = 2.0, 1.0, 500
    arr = [e.arrival for e in open_loop_source(_arrival_cfg(
        seed, n, stagger, rate))]
    gaps = np.diff(arr)
    want = stagger + 1.0 / rate
    sigma = float(np.sqrt(stagger**2 + (1.0 / rate) ** 2))
    assert abs(float(np.mean(gaps)) - want) < 4.0 * sigma / np.sqrt(n - 1)
    # eid 0: one Exp(1/rate) draw, no stagger term -> strictly positive
    # but far below the worst-case combined gap with overwhelming odds
    assert arr[0] > 0.0
