"""Sliding-window rolling-cache decode equivalence + simulator physics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M


def test_rolling_window_decode_matches_full_forward():
    """With a rolling SWA cache (smax == window), decoding token t must equal
    a full forward over the whole prefix with the window mask — softmax over
    a rotated cache is permutation-invariant."""
    base = get_config("granite-8b").reduced()
    cfg = dataclasses.replace(base, sliding_window=8, n_layers=2, max_seq_len=64)
    params = M.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 20), 0, cfg.vocab_size)

    # rolling-cache decode from a 6-token prompt
    lg, cache = M.prefill(params, cfg, {"tokens": toks[:, :6]}, max_len=8)
    assert cache["k"].shape[2] == 8  # rolling buffer capped at window
    for t in range(6, 12):
        lg_dec, cache = M.decode_step(params, cfg, cache, toks[:, t])
        lg_full, _ = M.forward(params, cfg, {"tokens": toks[:, : t + 1]})
        np.testing.assert_allclose(
            np.asarray(jax.nn.log_softmax(lg_dec)),
            np.asarray(jax.nn.log_softmax(lg_full[:, -1])),
            atol=3e-2, rtol=3e-2,
        )


def test_simulator_interference_physics():
    from repro.core.interference import Machine
    from repro.core.events import ResourceVector
    from repro.core.simulator import Simulator

    machine = Machine(ResourceVector(cpu=2, mem_bw=100, io=100, accel=1))
    done = {}

    def tick(sim):
        pass

    sim = Simulator(machine, tick)
    # two jobs each wanting 2 cores on a 2-core box -> 2x stretch each
    for i in range(2):
        j = sim.new_job(f"j{i}", np.array([2.0, 1, 1, 0]), 4.0, speculative=False,
                        on_complete=lambda s, job: done.setdefault(job.name, s.now))
        sim.start(j)
    sim.run()
    assert abs(done["j0"] - 8.0) < 1e-6 and abs(done["j1"] - 8.0) < 1e-6


def test_simulator_preemption_preserves_progress():
    from repro.core.interference import Machine
    from repro.core.simulator import Simulator

    machine = Machine()
    sim = Simulator(machine, lambda s: None)
    finished = {}
    long_job = sim.new_job("long", np.array([1.0, 1, 1, 0]), 10.0, speculative=True,
                           on_complete=lambda s, j: finished.setdefault("long", s.now))
    short = sim.new_job("short", np.array([1.0, 1, 1, 0]), 2.0, speculative=False,
                        on_complete=lambda s, j: finished.setdefault("short", s.now))
    sim.start(long_job)
    sim.start(short)
    sim.run()                       # runs to completion of both (no contention)
    assert abs(finished["short"] - 2.0) < 1e-6
    # now verify preemption bookkeeping
    sim2 = Simulator(machine, lambda s: None)
    j = sim2.new_job("p", np.array([1.0, 1, 1, 0]), 5.0, speculative=True)
    sim2.start(j)
    sim2.step()  # nothing else -> completes
    assert j.finished_at is not None
    j2 = sim2.new_job("q", np.array([1.0, 1, 1, 0]), 5.0, speculative=True)
    sim2.start(j2)
    blocker = sim2.new_job("b", np.array([1.0, 1, 1, 0]), 1.0, speculative=False)
    sim2.start(blocker)
    sim2.step()                      # blocker finishes first
    got = sim2.preempt(j2.jid)
    assert got is j2 and 0 < j2.remaining < 5.0
    sim2.start(j2)                   # resume
    sim2.run()
    assert j2.finished_at is not None
    total_executed = j2.executed_solo_seconds
    assert abs(total_executed - 5.0) < 1e-6  # no work lost or duplicated


def test_simulator_run_reports_truncation():
    """Hitting max_time/max_steps with work outstanding must be reported
    (sim.truncated + RuntimeWarning), not silently swallowed — downstream
    serving-bench makespans would otherwise present a truncated clock as a
    completed run."""
    import pytest
    from repro.core.interference import Machine
    from repro.core.simulator import Simulator

    machine = Machine()

    def tick(sim):                  # endless work: one new job per tick
        if not sim.running:
            sim.start(sim.new_job("w", np.array([1.0, 1, 1, 0]), 1.0,
                                  speculative=False))

    sim = Simulator(machine, tick)
    with pytest.warns(RuntimeWarning, match="max_time"):
        completed = sim.run(max_time=5.0)
    assert not completed and sim.truncated == "max_time"

    sim2 = Simulator(machine, tick)
    with pytest.warns(RuntimeWarning, match="max_steps"):
        completed = sim2.run(max_steps=3)
    assert not completed and sim2.truncated == "max_steps"

    # a drained run reports complete, truncated stays None
    sim3 = Simulator(machine, lambda s: None)
    sim3.start(sim3.new_job("j", np.array([1.0, 1, 1, 0]), 2.0,
                            speculative=False))
    assert sim3.run() and sim3.truncated is None


def test_long_context_hybrid_decode_smoke():
    """zamba2 (hybrid) decode with a longer cache — the long_500k code path
    at reduced scale: SSM state is O(1), shared-attn KV grows with cache."""
    cfg = get_config("zamba2-1.2b").reduced()
    params = M.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 64), 0, cfg.vocab_size)
    lg, cache = M.prefill(params, cfg, {"tokens": toks}, max_len=256)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    for _ in range(4):
        lg, cache = M.decode_step(params, cfg, cache, tok)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        assert not bool(jnp.isnan(lg).any())
    assert int(cache["lengths"][0]) == 64 + 4
    # SSM state stayed O(1): conv/ssm shapes independent of cache length
    assert cache["ssm_state"][3].shape[1] == 1
