"""End-to-end runtime tests: Algorithm 1 semantics, state equivalence,
speedup, QoS protection."""
import numpy as np
import pytest

from repro.core.events import ResourceVector, SafetyLevel
from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import BPasteRuntime, RuntimeConfig, run_mode
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes

THOR = Machine(ResourceVector(cpu=6, mem_bw=50, io=200, accel=1))


@pytest.fixture(scope="module")
def engine():
    eps = make_episodes(WorkloadConfig(seed=1, n_episodes=60))
    return PatternEngine(context_len=2, min_support=3).fit(episodes_to_traces(eps))


@pytest.fixture(scope="module")
def episodes():
    return make_episodes(WorkloadConfig(seed=42, n_episodes=8))


def test_serial_baseline_matches_reference(engine, episodes):
    m = run_mode(episodes, engine, "serial", THOR, seed=7)
    # with one episode at a time and no speculation, makespan == sum of
    # per-episode serial latencies
    np.testing.assert_allclose(m.makespan, m.serial_reference, rtol=1e-9)


def test_bpaste_speedup(engine, episodes):
    serial = run_mode(episodes, engine, "serial", THOR, seed=7)
    bp = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    speedup = serial.makespan / bp.makespan
    assert speedup >= 1.25, speedup            # paper: up to 1.4x
    assert bp.reuses + bp.promotions > 0


def test_bpaste_beats_paste(engine, episodes):
    paste = run_mode(episodes, engine, "paste", THOR, seed=7)
    bp = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    assert bp.makespan <= paste.makespan + 1e-6


def test_state_equivalence(engine, episodes):
    """Speculation must not change the final authoritative state — the
    paper's correctness contract (no externally visible speculative effect
    without authoritative convergence)."""
    rt_s = BPasteRuntime(episodes, engine, THOR, rcfg=RuntimeConfig(mode="serial"))
    rt_s.run()
    rt_b = BPasteRuntime(episodes, engine, THOR, rcfg=RuntimeConfig(mode="bpaste"))
    rt_b.run()
    for es_s, es_b in zip(rt_s.episodes, rt_b.episodes, strict=True):
        assert es_s.state.fs == es_b.state.fs
        assert es_s.state.env == es_b.state.env
        assert [e.tool for e in es_s.history] == [e.tool for e in es_b.history]
        assert [e.args for e in es_s.history] == [e.args for e in es_b.history]


def test_all_episodes_complete(engine, episodes):
    for mode in ("serial", "paste", "bpaste", "parallel"):
        m = run_mode(episodes, engine, mode, THOR, seed=7)
        assert len(m.episode_latencies) == len(episodes)


def test_non_speculative_tools_never_speculated(engine):
    eps = make_episodes(WorkloadConfig(seed=3, n_episodes=6))
    rt = BPasteRuntime(eps, engine, THOR, rcfg=RuntimeConfig(mode="bpaste"))
    rt.run()
    spec_started = [row for row in rt.sim.log
                    if row[1] == "start" and row[4] and "deploy" in row[2]]
    assert not spec_started


def test_read_only_policy_transforms_level2(engine, episodes):
    from repro.core.safety import READ_ONLY_POLICY
    rt = BPasteRuntime(episodes, engine, THOR, policy=READ_ONLY_POLICY,
                       rcfg=RuntimeConfig(mode="bpaste"))
    m = rt.run()
    # no Level-2 tool may have run speculatively; transformed variants OK
    for row in rt.sim.log:
        if row[1] == "start" and row[4]:
            tool = row[2].split(":")[1].split("[")[0]
            lvl = READ_ONLY_POLICY.level(tool)
            assert lvl <= SafetyLevel.READ_ONLY, (tool, lvl)
    # state must still be equivalent to serial
    rt_s = BPasteRuntime(episodes, engine, THOR, rcfg=RuntimeConfig(mode="serial"))
    rt_s.run()
    for es_s, es_b in zip(rt_s.episodes, rt.episodes, strict=True):
        assert es_s.state.fs == es_b.state.fs


def test_preemption_under_pressure(engine):
    """On a machine with almost no slack, speculative jobs must be
    preempted/withheld rather than stretch authoritative work."""
    tight = Machine(ResourceVector(cpu=2.2, mem_bw=12, io=40, accel=1))
    eps = make_episodes(WorkloadConfig(seed=5, n_episodes=6))
    m = run_mode(eps, engine, "bpaste", tight, seed=7, max_concurrent_episodes=2)
    s = m.summary()
    assert s["mean_auth_slowdown"] < 1.25


def test_metrics_consistency(engine, episodes):
    m = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    s = m.summary()
    assert 0.0 <= s["wasted_frac"] <= 1.0
    assert s["p95_latency"] >= s["mean_latency"] * 0.5
    assert m.spec_solo_seconds >= m.wasted_solo_seconds - 1e-6


def test_deterministic_across_runs(engine, episodes):
    m1 = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    m2 = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    assert m1.makespan == m2.makespan
    assert m1.reuses == m2.reuses


def test_beam_occupancy_tree_wider_than_chain(engine, episodes):
    """Tree assembly + multi-root fill must widen the admission-time beam
    over the linear-chain baseline on the default workload."""
    ch = run_mode(episodes, engine, "bpaste", THOR, seed=7, assembly="chain")
    tr = run_mode(episodes, engine, "bpaste", THOR, seed=7, assembly="tree")
    s_ch, s_tr = ch.summary(), tr.summary()
    assert s_tr["beam_occupancy"] > s_ch["beam_occupancy"]
    assert s_tr["reuse_rate"] >= s_ch["reuse_rate"] - 0.05


# ======================================================================
# Concurrent-episode serving: shared cross-episode beam, fairness, QoS
# ======================================================================

def test_two_tenant_fairness_qos_smoke(engine):
    """Staggered two-at-a-time tenants through the shared beam: every
    episode completes, speculation buys makespan over serial at the SAME
    concurrency, the pooled authoritative slowdown stays within the QoS
    bound, and the per-tenant breakdown shows no individually-starved
    tenant behind the pooled mean."""
    eps = make_episodes(WorkloadConfig(seed=9, n_episodes=6,
                                       arrival_stagger=3.0))
    serial = run_mode(eps, engine, "serial", THOR, seed=7,
                      max_concurrent_episodes=2)
    bp = run_mode(eps, engine, "bpaste", THOR, seed=7,
                  max_concurrent_episodes=2)
    assert len(bp.episode_latencies) == len(eps)
    assert bp.makespan <= serial.makespan + 1e-6
    s = bp.summary()
    assert s["mean_auth_slowdown"] <= 1.05
    assert not bp.truncated
    per = bp.per_tenant()
    assert set(per) == {ep.eid for ep in eps}
    assert all(v["mean_auth_slowdown"] <= 1.25 for v in per.values())
    assert all(v["latency"] > 0 for v in per.values())
    # sojourn counts from arrival: never below service latency, and some
    # tenant must actually have queued (sojourn > latency) at concurrency 2
    assert all(v["sojourn"] >= v["latency"] - 1e-9 for v in per.values())
    assert any(v["sojourn"] > v["latency"] + 1e-9 for v in per.values())
    assert s["p95_sojourn"] >= s["p95_latency"] - 1e-9


def test_shared_beam_fused_matches_reference_runtime(engine):
    """End-to-end at concurrency 3: the fused one-dispatch pass over the
    pooled cross-episode beam must make the same admission decisions as the
    reference greedy — identical makespan and reuse/promotion counts."""
    eps = make_episodes(WorkloadConfig(seed=11, n_episodes=6))
    mf = run_mode(eps, engine, "bpaste", THOR, seed=7,
                  max_concurrent_episodes=3, admission="fused")
    mr = run_mode(eps, engine, "bpaste", THOR, seed=7,
                  max_concurrent_episodes=3, admission="reference")
    assert mf.makespan == pytest.approx(mr.makespan, rel=1e-9)
    assert mf.reuses == mr.reuses
    assert mf.promotions == mr.promotions


def test_staggered_arrivals_respected(engine):
    """No episode may start service before its arrival; the zero-demand
    wake-up timer must keep the event-driven sim alive across gaps."""
    eps = make_episodes(WorkloadConfig(seed=3, n_episodes=4,
                                       arrival_stagger=6.0))
    assert any(ep.arrival > 0 for ep in eps)
    from repro.core.runtime import BPasteRuntime as RT
    rt = RT(eps, engine, THOR,
            rcfg=RuntimeConfig(mode="serial", max_concurrent_episodes=4))
    m = rt.run()
    assert len(m.episode_latencies) == len(eps)
    for ep, es in zip(eps, rt.episodes, strict=True):
        assert es.t_start >= ep.arrival - 1e-9
    # timers must not pollute QoS accounting
    assert all(r == pytest.approx(1.0) for r in m.auth_slowdown_samples)


def test_warm_discount_is_per_tenant(engine):
    """One tenant's env_warmup must not discount another tenant's cold
    tools: warmth lives in the episode's own environment."""
    from repro.core.workload import Episode, Step
    eps = [Episode(0, "m", [Step(1.0, "test", {"target": "p"})]),
           Episode(1, "m", [Step(1.0, "test", {"target": "p"})])]
    rt = BPasteRuntime(eps, engine, THOR, rcfg=RuntimeConfig(mode="bpaste"))
    e0, e1 = rt.episodes
    e0.warm_until = 1e9                   # tenant 0 warmed ITS environment
    rt._start_auth_tool(e0, "test", {"target": "p"})
    rt._start_auth_tool(e1, "test", {"target": "p"})
    solo = rt.tools["test"].det_latency({"target": "p"})
    assert e0.auth_queue[0].work == pytest.approx(solo * rt.rcfg.warm_discount)
    assert e1.auth_queue[0].work == pytest.approx(solo)


# ======================================================================
# _finish_action carry-over / squash and _squash_one accounting
# ======================================================================

def _manual_runtime(engine, steps):
    from repro.core.workload import Episode, Step
    ep = Episode(0, "manual", [Step(1.0, t, dict(a)) for t, a in steps])
    rt = BPasteRuntime([ep], engine, THOR, rcfg=RuntimeConfig(mode="bpaste"))
    return rt, rt.episodes[0]


def _mk_hyprun(rt, es, tools, context_key=("stale",)):
    """Active HypRun over a linear hypothesis of READ_ONLY tool nodes."""
    from repro.core.events import DEFAULT_TOOLS
    from repro.core.hypothesis import BranchHypothesis, Node, NodeKind
    from repro.core.runtime import HypRun, NodeRun
    from repro.core.sandbox import Sandbox
    nodes, edges = [], []
    for i, t in enumerate(tools):
        spec = DEFAULT_TOOLS[t]
        nodes.append(Node(i, NodeKind.TOOL, t, spec.level, spec.rho,
                          spec.base_latency))
        if i:
            edges.append((i - 1, i))
    h = BranchHypothesis(9000 + len(es.hyp_runs), nodes, edges, q=0.9,
                         context_key=context_key)
    nrs = [NodeRun(n, {}, run_tool=n.tool) for n in nodes]
    hr = HypRun(h, es.ep.eid, Sandbox(es.state, h.hid), nrs, eu=1.0,
                parents=h.parent_map(), base_len=len(es.history))
    es.hyp_runs.append(hr)
    return hr


def _drive_two_steps(rt, es):
    """Put the episode mid-flight: history holds step 0, step 1 finishing."""
    from repro.core.events import Event
    s0 = es.ep.steps[0]
    es.history.append(Event("tool", s0.tool, dict(s0.args), {"ok": 1}))
    es.step_idx = 1
    es.phase = "executing"


def test_finish_action_keeps_branch_with_predicted_next_tool(engine):
    """Carry-over: a stale-context branch whose next pending tool is still a
    top prediction (and that has work invested) survives _finish_action."""
    rt, es = _manual_runtime(engine, [
        ("grep", {"pattern": "x"}), ("read", {"path": "p"}),
        ("edit", {"path": "p", "change": "fix"}), ("test", {"target": "p"}),
    ])
    _drive_two_steps(rt, es)
    preds = {pt.tool for pt, _ in engine.predict(
        es.history + [__import__("repro.core.events", fromlist=["Event"]).Event(
            "tool", "read", {"path": "p"})], top=8, backoff="merge")}
    assert "edit" in preds and "build" not in preds   # sanity on the tables
    kept = _mk_hyprun(rt, es, ["edit"])
    kept.node_runs[0].status = "running"          # work invested
    gone = _mk_hyprun(rt, es, ["build"])          # not predicted after read
    gone.node_runs[0].status = "running"
    rt._finish_action(es, {"ok": 1}, 1.0)
    assert kept.status == "active"
    assert gone.status == "squashed"


def test_finish_action_squashes_branch_on_write_conflict(engine):
    """State safety: authoritative writes into a branch's base read-set
    invalidate the branch regardless of its predictions."""
    rt, es = _manual_runtime(engine, [
        ("grep", {"pattern": "x"}), ("read", {"path": "p"}),
        ("edit", {"path": "p", "change": "fix"}), ("test", {"target": "p"}),
    ])
    _drive_two_steps(rt, es)
    hr = _mk_hyprun(rt, es, ["edit"])
    hr.node_runs[0].status = "running"
    hr.sandbox.F.get("p")                         # base read -> read set
    assert "F:p" in hr.sandbox.base_read_set
    es.last_writes = {"F:p"}                      # authoritative write hits it
    rt._finish_action(es, {"ok": 1}, 1.0)
    assert hr.status == "squashed"


def test_squash_mid_flight_accounting(engine):
    """Squashing a branch with a running node books the partial burn into
    BOTH spec and wasted seconds: wasted_frac stays in [0, 1] by
    construction and running work is never lost from the denominator."""
    rt, es = _manual_runtime(engine, [("grep", {"pattern": "x"}),
                                      ("read", {"path": "p"})])
    hr = _mk_hyprun(rt, es, ["read", "parse"])
    nr = hr.node_runs[0]
    job = rt.sim.new_job("spec:read[test]", nr.node.rho.as_array(), 5.0,
                         speculative=True)
    rt.sim.start(job)
    job.executed_solo_seconds = 1.7               # mid-flight partial burn
    nr.job, nr.status = job, "running"
    rt._squash_one(es, hr)
    m = rt.metrics
    assert m.spec_solo_seconds == pytest.approx(1.7)
    assert m.wasted_solo_seconds == pytest.approx(1.7)
    assert 0.0 <= m.summary()["wasted_frac"] <= 1.0
    assert nr.status == "pending" and nr.job is None
    assert job.jid not in rt.sim.running          # actually preempted


def test_commit_path_unstrands_promoted_descendants(engine):
    """A committed promotion becomes 'reused': its children must pass the
    launch-frontier ready test afterwards (a permanent 'promoted' status
    stranded the whole subtree below every promotion)."""
    rt, es = _manual_runtime(engine, [("grep", {"pattern": "x"}),
                                      ("read", {"path": "p"})])
    hr = _mk_hyprun(rt, es, ["read", "parse"])
    hr.node_runs[0].status = "promoted"
    hr.node_runs[0].result = {"path": "p"}
    hr.node_runs[0].resolved_args = {"path": "p"}
    assert rt._launch_frontier(es, hr) == []      # child gated pre-commit
    rt._commit_path(es, hr, 0)
    assert hr.node_runs[0].status == "reused"
    assert rt._launch_frontier(es, hr) == [1]     # child launchable now


def test_prune_beam_honors_engine_context_len():
    """Regression: _prune_beam compared hypothesis context keys against a
    hard-coded 2-signature tail.  With an engine mined at context_len=3 the
    builder stamps 3-signature keys, so every carried-over branch
    misclassified as stale-context (and e.g. a pending-only branch got
    squashed even though it was built for exactly this context)."""
    from repro.core.events import Event, signature
    eps = make_episodes(WorkloadConfig(seed=1, n_episodes=40))
    eng3 = PatternEngine(context_len=3, min_support=3).fit(
        episodes_to_traces(eps))
    rt, es = _manual_runtime(eng3, [
        ("grep", {"pattern": "x"}), ("read", {"path": "p"}),
        ("parse", {"path": "p"}), ("test", {"target": "p"}),
    ])
    es.history = [Event("tool", "grep", {"pattern": "x"}, {"path": "p"}),
                  Event("tool", "read", {"path": "p"}, {"text": "t"}),
                  Event("tool", "parse", {"path": "p"}, {"ok": 1})]
    key3 = tuple(signature(e) for e in es.history)
    kept = _mk_hyprun(rt, es, ["build"], context_key=key3)
    gone = _mk_hyprun(rt, es, ["build"], context_key=("stale",))
    rt._prune_beam(es, es.history)
    assert kept.status == "active"        # built for this exact 3-context
    assert gone.status == "squashed"      # genuinely stale key still goes


def test_builder_context_key_matches_engine_context_len():
    """The builder must stamp context keys as long as the engine's mining
    context, or the runtime's carry-over classification has nothing to
    match against."""
    from repro.core.hypothesis import HypothesisBuilder
    eps = make_episodes(WorkloadConfig(seed=1, n_episodes=40))
    traces = episodes_to_traces(eps)
    eng3 = PatternEngine(context_len=3, min_support=3).fit(traces)
    hyps = HypothesisBuilder(eng3).build(traces[0][:3], beam_width=6)
    assert hyps and all(len(h.context_key) == 3 for h in hyps)


def test_event_timestamps_are_wall_start_times(engine):
    """Authoritative Event.t_start must be the job's wall start time, not
    now - solo_work: under co-run interference a stretched job spans more
    wall time than its solo work, so the subtraction placed starts too
    late (and promoted jobs started before the agent even asked)."""
    from repro.core.events import ResourceVector
    eps = make_episodes(WorkloadConfig(seed=5, n_episodes=4))
    tight = Machine(ResourceVector(cpu=2.2, mem_bw=12, io=40, accel=1))
    rt = BPasteRuntime(eps, engine, tight, rcfg=RuntimeConfig(
        mode="serial", max_concurrent_episodes=2))
    rt.run()
    starts = {}
    for t, kind, name, _jid, _spec in rt.sim.log:
        if kind == "start":
            starts.setdefault(name, t)
    stretched = 0
    for es in rt.episodes:
        for i, ev in enumerate(es.history):
            name = f"{ev.tool}[e{es.ep.eid}.{i}]"
            assert ev.t_start == pytest.approx(starts[name]), (name, ev)
            solo = rt.tools[ev.tool].det_latency(ev.args)
            if ev.t_end - ev.t_start > solo * 1.01:
                stretched += 1
    # the co-run regime where the old subtraction was wrong actually occurs
    assert stretched > 0


def test_squash_done_node_books_work_once(engine):
    """A done node's work entered spec_solo at completion; squash adds the
    matching waste only (never a second spec contribution)."""
    rt, es = _manual_runtime(engine, [("grep", {"pattern": "x"}),
                                      ("read", {"path": "p"})])
    hr = _mk_hyprun(rt, es, ["read"])
    nr = hr.node_runs[0]
    job = rt.sim.new_job("spec:read[test]", nr.node.rho.as_array(), 2.0,
                         speculative=True)
    job.executed_solo_seconds = 2.0
    nr.job, nr.status = job, "done"
    rt.metrics.spec_solo_seconds = 2.0            # booked by the done callback
    rt._squash_one(es, hr)
    m = rt.metrics
    assert m.spec_solo_seconds == pytest.approx(2.0)
    assert m.wasted_solo_seconds == pytest.approx(2.0)
    assert m.summary()["wasted_frac"] == pytest.approx(1.0)
