"""End-to-end runtime tests: Algorithm 1 semantics, state equivalence,
speedup, QoS protection."""
import numpy as np
import pytest

from repro.core.events import ResourceVector, SafetyLevel
from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import BPasteRuntime, RuntimeConfig, run_mode
from repro.core.safety import EligibilityPolicy, FULL_POLICY
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes

THOR = Machine(ResourceVector(cpu=6, mem_bw=50, io=200, accel=1))


@pytest.fixture(scope="module")
def engine():
    eps = make_episodes(WorkloadConfig(seed=1, n_episodes=60))
    return PatternEngine(context_len=2, min_support=3).fit(episodes_to_traces(eps))


@pytest.fixture(scope="module")
def episodes():
    return make_episodes(WorkloadConfig(seed=42, n_episodes=8))


def test_serial_baseline_matches_reference(engine, episodes):
    m = run_mode(episodes, engine, "serial", THOR, seed=7)
    # with one episode at a time and no speculation, makespan == sum of
    # per-episode serial latencies
    np.testing.assert_allclose(m.makespan, m.serial_reference, rtol=1e-9)


def test_bpaste_speedup(engine, episodes):
    serial = run_mode(episodes, engine, "serial", THOR, seed=7)
    bp = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    speedup = serial.makespan / bp.makespan
    assert speedup >= 1.25, speedup            # paper: up to 1.4x
    assert bp.reuses + bp.promotions > 0


def test_bpaste_beats_paste(engine, episodes):
    paste = run_mode(episodes, engine, "paste", THOR, seed=7)
    bp = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    assert bp.makespan <= paste.makespan + 1e-6


def test_state_equivalence(engine, episodes):
    """Speculation must not change the final authoritative state — the
    paper's correctness contract (no externally visible speculative effect
    without authoritative convergence)."""
    rt_s = BPasteRuntime(episodes, engine, THOR, rcfg=RuntimeConfig(mode="serial"))
    rt_s.run()
    rt_b = BPasteRuntime(episodes, engine, THOR, rcfg=RuntimeConfig(mode="bpaste"))
    rt_b.run()
    for es_s, es_b in zip(rt_s.episodes, rt_b.episodes):
        assert es_s.state.fs == es_b.state.fs
        assert es_s.state.env == es_b.state.env
        assert [e.tool for e in es_s.history] == [e.tool for e in es_b.history]
        assert [e.args for e in es_s.history] == [e.args for e in es_b.history]


def test_all_episodes_complete(engine, episodes):
    for mode in ("serial", "paste", "bpaste", "parallel"):
        m = run_mode(episodes, engine, mode, THOR, seed=7)
        assert len(m.episode_latencies) == len(episodes)


def test_non_speculative_tools_never_speculated(engine):
    eps = make_episodes(WorkloadConfig(seed=3, n_episodes=6))
    rt = BPasteRuntime(eps, engine, THOR, rcfg=RuntimeConfig(mode="bpaste"))
    rt.run()
    spec_started = [row for row in rt.sim.log
                    if row[1] == "start" and row[4] and "deploy" in row[2]]
    assert not spec_started


def test_read_only_policy_transforms_level2(engine, episodes):
    from repro.core.safety import READ_ONLY_POLICY
    rt = BPasteRuntime(episodes, engine, THOR, policy=READ_ONLY_POLICY,
                       rcfg=RuntimeConfig(mode="bpaste"))
    m = rt.run()
    # no Level-2 tool may have run speculatively; transformed variants OK
    for row in rt.sim.log:
        if row[1] == "start" and row[4]:
            tool = row[2].split(":")[1].split("[")[0]
            lvl = READ_ONLY_POLICY.level(tool)
            assert lvl <= SafetyLevel.READ_ONLY, (tool, lvl)
    # state must still be equivalent to serial
    rt_s = BPasteRuntime(episodes, engine, THOR, rcfg=RuntimeConfig(mode="serial"))
    rt_s.run()
    for es_s, es_b in zip(rt_s.episodes, rt.episodes):
        assert es_s.state.fs == es_b.state.fs


def test_preemption_under_pressure(engine):
    """On a machine with almost no slack, speculative jobs must be
    preempted/withheld rather than stretch authoritative work."""
    tight = Machine(ResourceVector(cpu=2.2, mem_bw=12, io=40, accel=1))
    eps = make_episodes(WorkloadConfig(seed=5, n_episodes=6))
    m = run_mode(eps, engine, "bpaste", tight, seed=7, max_concurrent_episodes=2)
    s = m.summary()
    assert s["mean_auth_slowdown"] < 1.25


def test_metrics_consistency(engine, episodes):
    m = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    s = m.summary()
    assert 0.0 <= s["wasted_frac"] <= 1.0
    assert s["p95_latency"] >= s["mean_latency"] * 0.5
    assert m.spec_solo_seconds >= m.wasted_solo_seconds - 1e-6


def test_deterministic_across_runs(engine, episodes):
    m1 = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    m2 = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    assert m1.makespan == m2.makespan
    assert m1.reuses == m2.reuses
