"""End-to-end runtime tests: Algorithm 1 semantics, state equivalence,
speedup, QoS protection."""
import numpy as np
import pytest

from repro.core.events import ResourceVector, SafetyLevel
from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import BPasteRuntime, RuntimeConfig, run_mode
from repro.core.safety import EligibilityPolicy, FULL_POLICY
from repro.core.workload import WorkloadConfig, episodes_to_traces, make_episodes

THOR = Machine(ResourceVector(cpu=6, mem_bw=50, io=200, accel=1))


@pytest.fixture(scope="module")
def engine():
    eps = make_episodes(WorkloadConfig(seed=1, n_episodes=60))
    return PatternEngine(context_len=2, min_support=3).fit(episodes_to_traces(eps))


@pytest.fixture(scope="module")
def episodes():
    return make_episodes(WorkloadConfig(seed=42, n_episodes=8))


def test_serial_baseline_matches_reference(engine, episodes):
    m = run_mode(episodes, engine, "serial", THOR, seed=7)
    # with one episode at a time and no speculation, makespan == sum of
    # per-episode serial latencies
    np.testing.assert_allclose(m.makespan, m.serial_reference, rtol=1e-9)


def test_bpaste_speedup(engine, episodes):
    serial = run_mode(episodes, engine, "serial", THOR, seed=7)
    bp = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    speedup = serial.makespan / bp.makespan
    assert speedup >= 1.25, speedup            # paper: up to 1.4x
    assert bp.reuses + bp.promotions > 0


def test_bpaste_beats_paste(engine, episodes):
    paste = run_mode(episodes, engine, "paste", THOR, seed=7)
    bp = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    assert bp.makespan <= paste.makespan + 1e-6


def test_state_equivalence(engine, episodes):
    """Speculation must not change the final authoritative state — the
    paper's correctness contract (no externally visible speculative effect
    without authoritative convergence)."""
    rt_s = BPasteRuntime(episodes, engine, THOR, rcfg=RuntimeConfig(mode="serial"))
    rt_s.run()
    rt_b = BPasteRuntime(episodes, engine, THOR, rcfg=RuntimeConfig(mode="bpaste"))
    rt_b.run()
    for es_s, es_b in zip(rt_s.episodes, rt_b.episodes):
        assert es_s.state.fs == es_b.state.fs
        assert es_s.state.env == es_b.state.env
        assert [e.tool for e in es_s.history] == [e.tool for e in es_b.history]
        assert [e.args for e in es_s.history] == [e.args for e in es_b.history]


def test_all_episodes_complete(engine, episodes):
    for mode in ("serial", "paste", "bpaste", "parallel"):
        m = run_mode(episodes, engine, mode, THOR, seed=7)
        assert len(m.episode_latencies) == len(episodes)


def test_non_speculative_tools_never_speculated(engine):
    eps = make_episodes(WorkloadConfig(seed=3, n_episodes=6))
    rt = BPasteRuntime(eps, engine, THOR, rcfg=RuntimeConfig(mode="bpaste"))
    rt.run()
    spec_started = [row for row in rt.sim.log
                    if row[1] == "start" and row[4] and "deploy" in row[2]]
    assert not spec_started


def test_read_only_policy_transforms_level2(engine, episodes):
    from repro.core.safety import READ_ONLY_POLICY
    rt = BPasteRuntime(episodes, engine, THOR, policy=READ_ONLY_POLICY,
                       rcfg=RuntimeConfig(mode="bpaste"))
    m = rt.run()
    # no Level-2 tool may have run speculatively; transformed variants OK
    for row in rt.sim.log:
        if row[1] == "start" and row[4]:
            tool = row[2].split(":")[1].split("[")[0]
            lvl = READ_ONLY_POLICY.level(tool)
            assert lvl <= SafetyLevel.READ_ONLY, (tool, lvl)
    # state must still be equivalent to serial
    rt_s = BPasteRuntime(episodes, engine, THOR, rcfg=RuntimeConfig(mode="serial"))
    rt_s.run()
    for es_s, es_b in zip(rt_s.episodes, rt.episodes):
        assert es_s.state.fs == es_b.state.fs


def test_preemption_under_pressure(engine):
    """On a machine with almost no slack, speculative jobs must be
    preempted/withheld rather than stretch authoritative work."""
    tight = Machine(ResourceVector(cpu=2.2, mem_bw=12, io=40, accel=1))
    eps = make_episodes(WorkloadConfig(seed=5, n_episodes=6))
    m = run_mode(eps, engine, "bpaste", tight, seed=7, max_concurrent_episodes=2)
    s = m.summary()
    assert s["mean_auth_slowdown"] < 1.25


def test_metrics_consistency(engine, episodes):
    m = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    s = m.summary()
    assert 0.0 <= s["wasted_frac"] <= 1.0
    assert s["p95_latency"] >= s["mean_latency"] * 0.5
    assert m.spec_solo_seconds >= m.wasted_solo_seconds - 1e-6


def test_deterministic_across_runs(engine, episodes):
    m1 = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    m2 = run_mode(episodes, engine, "bpaste", THOR, seed=7)
    assert m1.makespan == m2.makespan
    assert m1.reuses == m2.reuses


def test_beam_occupancy_tree_wider_than_chain(engine, episodes):
    """Tree assembly + multi-root fill must widen the admission-time beam
    over the linear-chain baseline on the default workload."""
    ch = run_mode(episodes, engine, "bpaste", THOR, seed=7, assembly="chain")
    tr = run_mode(episodes, engine, "bpaste", THOR, seed=7, assembly="tree")
    s_ch, s_tr = ch.summary(), tr.summary()
    assert s_tr["beam_occupancy"] > s_ch["beam_occupancy"]
    assert s_tr["reuse_rate"] >= s_ch["reuse_rate"] - 0.05


# ======================================================================
# _finish_action carry-over / squash and _squash_one accounting
# ======================================================================

def _manual_runtime(engine, steps):
    from repro.core.workload import Episode, Step
    ep = Episode(0, "manual", [Step(1.0, t, dict(a)) for t, a in steps])
    rt = BPasteRuntime([ep], engine, THOR, rcfg=RuntimeConfig(mode="bpaste"))
    return rt, rt.episodes[0]


def _mk_hyprun(rt, es, tools, context_key=("stale",)):
    """Active HypRun over a linear hypothesis of READ_ONLY tool nodes."""
    from repro.core.events import DEFAULT_TOOLS
    from repro.core.hypothesis import BranchHypothesis, Node, NodeKind
    from repro.core.runtime import HypRun, NodeRun
    from repro.core.sandbox import Sandbox
    nodes, edges = [], []
    for i, t in enumerate(tools):
        spec = DEFAULT_TOOLS[t]
        nodes.append(Node(i, NodeKind.TOOL, t, spec.level, spec.rho,
                          spec.base_latency))
        if i:
            edges.append((i - 1, i))
    h = BranchHypothesis(9000 + len(es.hyp_runs), nodes, edges, q=0.9,
                         context_key=context_key)
    nrs = [NodeRun(n, {}, run_tool=n.tool) for n in nodes]
    hr = HypRun(h, es.ep.eid, Sandbox(es.state, h.hid), nrs, eu=1.0,
                parents=h.parent_map(), base_len=len(es.history))
    es.hyp_runs.append(hr)
    return hr


def _drive_two_steps(rt, es):
    """Put the episode mid-flight: history holds step 0, step 1 finishing."""
    from repro.core.events import Event
    s0 = es.ep.steps[0]
    es.history.append(Event("tool", s0.tool, dict(s0.args), {"ok": 1}))
    es.step_idx = 1
    es.phase = "executing"


def test_finish_action_keeps_branch_with_predicted_next_tool(engine):
    """Carry-over: a stale-context branch whose next pending tool is still a
    top prediction (and that has work invested) survives _finish_action."""
    rt, es = _manual_runtime(engine, [
        ("grep", {"pattern": "x"}), ("read", {"path": "p"}),
        ("edit", {"path": "p", "change": "fix"}), ("test", {"target": "p"}),
    ])
    _drive_two_steps(rt, es)
    preds = {pt.tool for pt, _ in engine.predict(
        es.history + [__import__("repro.core.events", fromlist=["Event"]).Event(
            "tool", "read", {"path": "p"})], top=8, backoff="merge")}
    assert "edit" in preds and "build" not in preds   # sanity on the tables
    kept = _mk_hyprun(rt, es, ["edit"])
    kept.node_runs[0].status = "running"          # work invested
    gone = _mk_hyprun(rt, es, ["build"])          # not predicted after read
    gone.node_runs[0].status = "running"
    rt._finish_action(es, {"ok": 1}, 1.0)
    assert kept.status == "active"
    assert gone.status == "squashed"


def test_finish_action_squashes_branch_on_write_conflict(engine):
    """State safety: authoritative writes into a branch's base read-set
    invalidate the branch regardless of its predictions."""
    rt, es = _manual_runtime(engine, [
        ("grep", {"pattern": "x"}), ("read", {"path": "p"}),
        ("edit", {"path": "p", "change": "fix"}), ("test", {"target": "p"}),
    ])
    _drive_two_steps(rt, es)
    hr = _mk_hyprun(rt, es, ["edit"])
    hr.node_runs[0].status = "running"
    hr.sandbox.F.get("p")                         # base read -> read set
    assert "F:p" in hr.sandbox.base_read_set
    es.last_writes = {"F:p"}                      # authoritative write hits it
    rt._finish_action(es, {"ok": 1}, 1.0)
    assert hr.status == "squashed"


def test_squash_mid_flight_accounting(engine):
    """Squashing a branch with a running node books the partial burn into
    BOTH spec and wasted seconds: wasted_frac stays in [0, 1] by
    construction and running work is never lost from the denominator."""
    rt, es = _manual_runtime(engine, [("grep", {"pattern": "x"}),
                                      ("read", {"path": "p"})])
    hr = _mk_hyprun(rt, es, ["read", "parse"])
    nr = hr.node_runs[0]
    job = rt.sim.new_job("spec:read[test]", nr.node.rho.as_array(), 5.0,
                         speculative=True)
    rt.sim.start(job)
    job.executed_solo_seconds = 1.7               # mid-flight partial burn
    nr.job, nr.status = job, "running"
    rt._squash_one(es, hr)
    m = rt.metrics
    assert m.spec_solo_seconds == pytest.approx(1.7)
    assert m.wasted_solo_seconds == pytest.approx(1.7)
    assert 0.0 <= m.summary()["wasted_frac"] <= 1.0
    assert nr.status == "pending" and nr.job is None
    assert job.jid not in rt.sim.running          # actually preempted


def test_commit_path_unstrands_promoted_descendants(engine):
    """A committed promotion becomes 'reused': its children must pass the
    launch-frontier ready test afterwards (a permanent 'promoted' status
    stranded the whole subtree below every promotion)."""
    rt, es = _manual_runtime(engine, [("grep", {"pattern": "x"}),
                                      ("read", {"path": "p"})])
    hr = _mk_hyprun(rt, es, ["read", "parse"])
    hr.node_runs[0].status = "promoted"
    hr.node_runs[0].result = {"path": "p"}
    hr.node_runs[0].resolved_args = {"path": "p"}
    assert rt._launch_frontier(hr) == []          # child gated pre-commit
    rt._commit_path(es, hr, 0)
    assert hr.node_runs[0].status == "reused"
    assert rt._launch_frontier(hr) == [1]         # child launchable now


def test_squash_done_node_books_work_once(engine):
    """A done node's work entered spec_solo at completion; squash adds the
    matching waste only (never a second spec contribution)."""
    rt, es = _manual_runtime(engine, [("grep", {"pattern": "x"}),
                                      ("read", {"path": "p"})])
    hr = _mk_hyprun(rt, es, ["read"])
    nr = hr.node_runs[0]
    job = rt.sim.new_job("spec:read[test]", nr.node.rho.as_array(), 2.0,
                         speculative=True)
    job.executed_solo_seconds = 2.0
    nr.job, nr.status = job, "done"
    rt.metrics.spec_solo_seconds = 2.0            # booked by the done callback
    rt._squash_one(es, hr)
    m = rt.metrics
    assert m.spec_solo_seconds == pytest.approx(2.0)
    assert m.wasted_solo_seconds == pytest.approx(2.0)
    assert m.summary()["wasted_frac"] == pytest.approx(1.0)
