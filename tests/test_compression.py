"""Gradient compression: unbiasedness via error feedback + multi-device
sync correctness + convergence parity."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import compression as C


def test_error_feedback_unbiased_over_steps():
    """Accumulated quantized updates converge to the true sum (EF property)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32) * 1e-3
    err = jnp.zeros_like(g)
    acc_q = jnp.zeros_like(g)
    steps = 50
    for _ in range(steps):
        q, scale, err = C.quantize_ef(g, err)
        acc_q = acc_q + C.dequantize(q, scale)
    true = g * steps
    rel = float(jnp.abs(acc_q - true).max() / jnp.abs(true).max())
    assert rel < 0.01, rel


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    q, scale, err = C.quantize_ef(g, jnp.zeros_like(g))
    deq = C.dequantize(q, scale)
    assert float(jnp.abs(deq - g).max()) <= float(scale) * 0.5 + 1e-9
    # EF captures exactly the residual
    np.testing.assert_allclose(np.asarray(deq + err), np.asarray(g), atol=1e-6)


def test_compressed_sync_multidevice():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.training import compression as C
        mesh = compat.make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        # per-pod distinct gradients, laid out on the pod axis
        from jax.sharding import NamedSharding, PartitionSpec as P
        g_all = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)

        def per_pod(gp):
            # simulate per-pod local grads via shard_map input
            return gp

        # run sync where each pod holds g_all[rank]
        def body(g_l, e_l):
            q, s, ne = C.quantize_ef(g_l[0], e_l[0])
            q_all = jax.lax.all_gather(q, "pod")
            s_all = jax.lax.all_gather(s, "pod")
            out = jnp.tensordot(s_all, q_all.astype(jnp.float32), axes=([0],[0])) / 4
            return out[None], ne[None]
        fn = compat.shard_map(body, mesh=mesh, in_specs=(P("pod"), P("pod")),
                              out_specs=(P("pod"), P("pod")))
        err0 = jnp.zeros_like(g_all)
        synced, err = fn(g_all, err0)
        want = jnp.mean(g_all, axis=0)
        got = np.asarray(synced)[0]
        rel = np.abs(got - np.asarray(want)).max() / (np.abs(np.asarray(want)).max() + 1e-9)
        assert rel < 0.02, rel
        # every pod ends with the same value
        assert np.allclose(np.asarray(synced), np.asarray(synced)[0], atol=1e-6)
        print("COMPRESS_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert "COMPRESS_OK" in r.stdout, r.stderr[-2000:]


def test_dcn_bytes_accounting():
    g = {"a": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
    comp, bf16 = C.dcn_bytes(g, 2)
    assert comp < bf16 / 3   # ~4x fewer DCN bytes
