"""ISSUE 7: speculation-safety static analyzer + runtime sanitizer.

Three claims under test:

* **Clean defaults** — the default policy / tool registry / workload /
  pattern tables produce ZERO findings, statically (R1-R4, the CLI path)
  and at runtime (S1-S5 on a seeded serving run under ``sanitize=True``).
* **Every rule fires** — each static rule and each sanitizer check has a
  deliberately broken fixture that triggers exactly that rule id (no
  cross-talk, no false positives from the other rules).
* **Observer effect: none** — ``sanitize=True`` changes wall time only:
  the full metrics summary is bit-identical to ``sanitize=False`` on the
  pinned serving config (TIMING_KEYS excepted), and ``race_mask`` stays a
  separate, explicit opt-in.
"""
from dataclasses import replace
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis import main as analysis_cli
from repro.core.analysis import (
    AnalysisError,
    AnalysisReport,
    RuntimeSanitizer,
    _patterns_overlap,
    analyze_static,
    check_barriers,
    check_footprints,
    check_nonspec_reachability,
    check_write_races,
)
from repro.core.events import (
    DEFAULT_TOOLS, RESOURCE_DIMS, ResourceVector, SafetyLevel,
)
from repro.core.executor import AgentState, StateFacade, dry_run_footprint
from repro.core.hypothesis import BranchHypothesis, Node, NodeKind
from repro.core.patterns import PatternEngine
from repro.core.runtime import BPasteRuntime, RuntimeConfig
from repro.core.safety import FULL_POLICY, EligibilityPolicy
from repro.core.workload import (
    WorkloadConfig, episodes_to_traces, make_episodes,
)

# wall-time-derived summary keys (same convention as test_event_scheduler)
TIMING_KEYS = {"sched_us_per_admit", "sched_us_per_tick"}


@pytest.fixture(scope="module")
def engine():
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=20))
    return PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))


class _StubEngine:
    """Pattern tables with exactly the reachable tools a fixture needs."""

    def __init__(self, tools):
        self.patterns = [SimpleNamespace(tool=t) for t in tools]


def _serving_rt(engine, **rcfg_kw):
    eps = make_episodes(WorkloadConfig(seed=42, n_episodes=8,
                                       arrival_stagger=2.0,
                                       shared_frac=0.5, shared_pool=2))
    rcfg = RuntimeConfig(seed=7, max_concurrent_episodes=4,
                         model_max_batch=4, **rcfg_kw)
    return BPasteRuntime(eps, engine, rcfg=rcfg)


# ======================================================================
# clean defaults
# ======================================================================

def test_default_config_is_clean_statically(engine):
    """Acceptance gate: R1-R3 on the default policy + mined tables, R4 on
    real assembled beams — zero findings."""
    from repro.analysis import _build_beams
    traces = episodes_to_traces(make_episodes(
        WorkloadConfig(seed=1, n_episodes=20)))
    hyps = _build_beams(engine, traces)
    report = analyze_static(FULL_POLICY, engine, hyps)
    assert report.ok, report.render()
    assert report.meta["barrier_checked_hyps"] > 0


def test_cli_exits_zero_on_defaults(capsys):
    assert analysis_cli([]) == 0
    assert "clean (0 findings)" in capsys.readouterr().out


def test_sanitized_serving_run_is_clean_and_bit_identical(engine):
    """Seeded serving config under ``sanitize=True``: the sanitizer fires on
    its sampled schedule and finds nothing, and the summary (decisions,
    latencies, memo traffic — everything but wall time) is bit-identical to
    the ``sanitize=False`` run."""
    rt = _serving_rt(engine, sanitize=True, sanitize_every=3)
    a = rt.run().summary()
    assert rt.sanitizer is not None
    assert rt.sanitizer.findings == [], rt.sanitizer.report.render()
    assert rt.sanitizer._tick_no > 3          # the schedule actually sampled
    b = _serving_rt(engine, sanitize=False).run().summary()
    assert b["sanitize_findings"] == 0 and b["race_masked"] == 0
    keys = (set(a) | set(b)) - TIMING_KEYS
    diffs = {k: (a.get(k), b.get(k)) for k in keys if a.get(k) != b.get(k)}
    assert not diffs, diffs


# ======================================================================
# R1: policy–footprint consistency
# ======================================================================

def test_r1_fires_on_misdeclared_read_only_writer():
    """'edit' relabeled READ_ONLY with an empty write declaration: its
    tracked writes are undeclared at a level that may run un-sandboxed."""
    tools = dict(DEFAULT_TOOLS)
    tools["edit"] = replace(tools["edit"], level=SafetyLevel.READ_ONLY,
                            reads=(), writes=())
    report = check_footprints(EligibilityPolicy(tools=tools))
    hits = report.by_rule("R1-footprint")
    assert {f.rule for f in report.findings} == {"R1-footprint"}
    assert any(f.site == "edit" and f.severity == "error" for f in hits)


def test_r1_staged_misdeclaration_is_warn_not_error():
    tools = dict(DEFAULT_TOOLS)
    tools["edit"] = replace(tools["edit"], reads=(), writes=())
    report = check_footprints(EligibilityPolicy(tools=tools))
    edit_hits = [f for f in report.by_rule("R1-footprint") if f.site == "edit"]
    assert edit_hits and all(f.severity == "warn" for f in edit_hits)


def test_r1_unknown_tool_is_info():
    tools = dict(DEFAULT_TOOLS)
    tools["teleport"] = replace(tools["search"], name="teleport")
    report = check_footprints(EligibilityPolicy(tools=tools))
    assert [f.severity for f in report.findings] == ["info"]
    assert report.findings[0].site == "teleport"


def test_dry_run_footprint_tracks_both_directions():
    reads, writes = dry_run_footprint("edit")
    assert any(k.startswith("F:") for k in writes)
    reads, writes = dry_run_footprint("read")
    assert any(k.startswith("F:") for k in reads) and not writes


# ======================================================================
# R2: non-speculative reachability
# ======================================================================

def test_r2_fires_on_banned_reachable_tool():
    pol = EligibilityPolicy(
        overrides={"parse": SafetyLevel.NON_SPECULATIVE})
    report = check_nonspec_reachability(pol, _StubEngine(["parse", "search"]))
    assert [f.rule for f in report.findings] == ["R2-nonspec-reach"]
    assert report.findings[0].site == "parse"
    assert report.findings[0].severity == "warn"


def test_r2_unregistered_pattern_tool_is_error():
    report = check_nonspec_reachability(FULL_POLICY,
                                        _StubEngine(["no_such_tool"]))
    assert [f.severity for f in report.findings] == ["error"]


def test_r2_transformed_tool_is_not_flagged():
    """pip_install is NON_SPECULATIVE-adjacent but degrades to its dry-run
    transform, so reachability is fine."""
    report = check_nonspec_reachability(
        EligibilityPolicy(max_level=SafetyLevel.READ_ONLY),
        _StubEngine(["pip_install"]))
    assert report.ok, report.render()


# ======================================================================
# R3: cross-branch write–write races
# ======================================================================

def test_r3_fires_on_exact_key_collision():
    tools = dict(DEFAULT_TOOLS)
    tools["rebuild"] = replace(tools["build"], name="rebuild")
    pol = EligibilityPolicy(tools=tools)
    report = check_write_races(pol, _StubEngine(["build", "rebuild"]))
    hits = report.by_rule("R3-write-race")
    assert len(hits) == 1 and hits[0].site == "build+rebuild"
    assert ["build", "rebuild", "E:built", "E:built"] in \
        report.meta["write_conflicts"]


def test_r3_glob_overlap_is_matrix_only(engine):
    """Default tables: edit/visit both cover F:* — a may-overlap matrix
    entry, NOT a finding (distinct keys under one glob are not a race)."""
    report = check_write_races(FULL_POLICY, engine)
    assert report.ok, report.render()
    assert any({"edit", "visit"} == {c[0], c[1]}
               for c in report.meta["write_conflicts"])


def test_pattern_overlap_predicate():
    assert _patterns_overlap("E:built", "E:built")
    assert not _patterns_overlap("E:built", "E:pkg")
    assert _patterns_overlap("F:cache/x", "F:cache/*")
    assert not _patterns_overlap("E:built", "F:*")
    assert _patterns_overlap("F:*", "F:cache/*")


# ======================================================================
# R4: commit-barrier placement
# ======================================================================

def _bare_staged_hyp(hid=99):
    n0 = Node(0, NodeKind.TOOL, "search", SafetyLevel.READ_ONLY,
              DEFAULT_TOOLS["search"].rho, 1.0)
    n1 = Node(1, NodeKind.TOOL, "edit", SafetyLevel.STAGED_WRITE,
              DEFAULT_TOOLS["edit"].rho, 1.0)
    return BranchHypothesis(hid=hid, nodes=[n0, n1], edges=[(0, 1)],
                            q=0.5, context_key=())


def test_r4_fires_on_missing_barrier():
    report = check_barriers([_bare_staged_hyp()])
    assert [f.rule for f in report.findings] == ["R4-barrier"]
    assert report.findings[0].site == "hyp 99 node 1"
    assert report.findings[0].severity == "error"
    assert report.meta["barrier_checked_hyps"] == 1


def test_r4_clean_on_barriered_hyp():
    n0 = Node(0, NodeKind.BARRIER, "barrier", SafetyLevel.PREP_ONLY,
              ResourceVector(), 0.0)
    n1 = Node(1, NodeKind.TOOL, "edit", SafetyLevel.STAGED_WRITE,
              DEFAULT_TOOLS["edit"].rho, 1.0)
    h = BranchHypothesis(hid=1, nodes=[n0, n1], edges=[(0, 1)],
                         q=0.5, context_key=())
    assert check_barriers([h]).ok


# ======================================================================
# constructor wiring (RuntimeConfig.analysis)
# ======================================================================

def _broken_policy():
    tools = dict(DEFAULT_TOOLS)
    tools["edit"] = replace(tools["edit"], level=SafetyLevel.READ_ONLY,
                            reads=(), writes=())
    return EligibilityPolicy(tools=tools)


def test_constructor_strict_raises_on_error_findings(engine):
    eps = make_episodes(WorkloadConfig(seed=42, n_episodes=2))
    with pytest.raises(AnalysisError) as ei:
        BPasteRuntime(eps, engine, policy=_broken_policy(),
                      rcfg=RuntimeConfig(analysis="strict"))
    assert ei.value.report.by_rule("R1-footprint")


def test_constructor_warn_warns_and_records(engine):
    eps = make_episodes(WorkloadConfig(seed=42, n_episodes=2))
    with pytest.warns(RuntimeWarning, match="speculation-safety analysis"):
        rt = BPasteRuntime(eps, engine, policy=_broken_policy(),
                           rcfg=RuntimeConfig(analysis="warn"))
    assert rt.analysis_report is not None
    assert rt.analysis_report.errors()


def test_constructor_off_skips_analysis(engine):
    eps = make_episodes(WorkloadConfig(seed=42, n_episodes=2))
    rt = BPasteRuntime(eps, engine, policy=_broken_policy(),
                       rcfg=RuntimeConfig(analysis="off"))
    assert rt.analysis_report is None
    with pytest.raises(ValueError):
        BPasteRuntime(eps, engine, rcfg=RuntimeConfig(analysis="loud"))


def test_default_runtime_construction_is_warning_free(engine):
    import warnings
    eps = make_episodes(WorkloadConfig(seed=42, n_episodes=2))
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # any warning -> test failure
        rt = BPasteRuntime(eps, engine)
    assert rt.analysis_report is not None and rt.analysis_report.ok


# ======================================================================
# runtime sanitizer: tamper fixtures (S1-S5)
# ======================================================================

def _mid_run_rt(engine, max_steps=4000, want=lambda rt: True):
    """Drive a sanitized serving run event-by-event until ``want`` is
    satisfied mid-flight (active branches, populated caches), then hand the
    live runtime to a tamper fixture."""
    rt = _serving_rt(engine, sanitize=True, sanitize_every=10 ** 9)
    rt._launch_wave()
    rt.sim.tick(rt.sim)              # mirror Simulator.run's step/tick loop
    for _ in range(max_steps):
        if not rt.sim.step():
            break
        rt.sim.tick(rt.sim)
        if want(rt):
            return rt
    raise AssertionError("mid-run predicate never satisfied")


def _active_cached_node(rt):
    for es in rt.episodes:
        for hr in es.hyp_runs:
            if hr.status != "active":
                continue
            for i, nr in enumerate(hr.node_runs):
                if nr.args_epoch == es.epoch and nr.args_cache is not None:
                    return es, hr, i
    return None


def test_s1_fires_on_tampered_args_cache(engine):
    rt = _mid_run_rt(engine, want=lambda rt: _active_cached_node(rt))
    es, hr, i = _active_cached_node(rt)
    hr.node_runs[i].args_cache = {"bogus": "tampered"}
    rt.sanitizer.check_epoch_caches()
    rules = {f.rule for f in rt.sanitizer.findings}
    assert rules == {"S1-stale-cache"}, rt.sanitizer.report.render()
    assert rt.metrics.sanitize_findings > 0


def test_s1_fires_on_tampered_memo_key(engine):
    def has_mkey(rt):
        return any(nr.mkey_epoch == es.epoch and nr.mkey_cache is not None
                   for es in rt.episodes for hr in es.hyp_runs
                   if hr.status == "active" for nr in hr.node_runs)
    rt = _mid_run_rt(engine, want=has_mkey)
    for es in rt.episodes:
        for hr in es.hyp_runs:
            if hr.status != "active":
                continue
            for nr in hr.node_runs:
                if nr.mkey_epoch == es.epoch and nr.mkey_cache is not None:
                    nr.mkey_cache = ("bogus", "key")
    rt.sanitizer.check_epoch_caches()
    assert {f.rule for f in rt.sanitizer.findings} == {"S1-stale-cache"}


def test_s2_fires_on_tampered_frontier_cache(engine):
    def clean_cached_episode(rt):
        return [es for es in rt.episodes
                if es.idx >= 0 and es.idx not in rt._dirty
                and es.idx in rt._nact]
    rt = _mid_run_rt(engine, want=clean_cached_episode)
    es = clean_cached_episode(rt)[0]
    rt._nact[es.idx] = rt._nact[es.idx] + 1
    rt.sanitizer.check_dirty_sets()
    hits = rt.sanitizer.findings
    assert hits and {f.rule for f in hits} == {"S2-dirty-set"}
    assert any("active-branch count" in f.detail for f in hits)
    # marking the episode dirty legitimizes the pending rebuild: no finding
    rt.sanitizer.report.findings.clear()
    rt._mark_dirty(es)
    rt.sanitizer.check_dirty_sets()
    assert not any(f.site == f"e{es.ep.eid}" for f in rt.sanitizer.findings)


def test_s3_fires_on_tampered_counter_group(engine):
    rt = _mid_run_rt(engine, want=lambda rt: rt.sim.running)
    rt.sim._groups[b"__tampered__"] = [np.ones(RESOURCE_DIMS), 1, 0]
    rt.sim._demand_cache.clear()
    rt.sanitizer.check_demand_counters()
    assert {f.rule for f in rt.sanitizer.findings} == {"S3-slack-drift"}


def test_s4_fires_on_undeclared_runtime_write(engine):
    rt = _serving_rt(engine, sanitize=True)
    fac = StateFacade(AgentState())
    fac.begin_call()
    fac.write_values["E:rogue"] = 1
    rt.sanitizer.check_footprint("read", fac, "tamper-test")
    hits = rt.sanitizer.findings
    assert [f.rule for f in hits] == ["S4-footprint"]
    assert hits[0].severity == "error"       # READ_ONLY tool writing


def test_s5_fires_on_corrupted_store_index(engine):
    rt = _serving_rt(engine, sanitize=True)
    rt.run()
    rt.sanitizer.report.findings.clear()
    rt.store._tools["phantom"] = 3
    rt.sanitizer.check_store_integrity()
    assert {f.rule for f in rt.sanitizer.findings} == {"S5-store-index"}
    assert "phantom" in rt.sanitizer.findings[0].detail


# ======================================================================
# race masking (R3 threaded into admission)
# ======================================================================

def _fake_branch(hid, eu, tool):
    node = SimpleNamespace(kind=NodeKind.TOOL)
    nr = SimpleNamespace(node=node, run_tool=tool)
    hr = SimpleNamespace(meta_admitted=True, eu=eu,
                         hyp=SimpleNamespace(hid=hid), node_runs=[nr])
    return (SimpleNamespace(ep=SimpleNamespace(eid=0)), hr, [0])


def test_race_mask_deadmits_lower_eu_claimant(engine):
    tools = dict(DEFAULT_TOOLS)
    tools["rebuild"] = replace(tools["build"], name="rebuild")
    eps = make_episodes(WorkloadConfig(seed=42, n_episodes=2))
    rt = BPasteRuntime(eps, engine, tools=tools,
                       rcfg=RuntimeConfig(race_mask=True, sanitize=True))
    winner = _fake_branch(1, eu=2.0, tool="build")
    loser = _fake_branch(2, eu=1.0, tool="rebuild")
    rt._check_write_races([loser, winner])
    assert winner[1].meta_admitted is True
    assert loser[1].meta_admitted is False
    assert rt.metrics.race_masked == 1
    assert any(f.rule == "R3-write-race" for f in rt.sanitizer.findings)


def test_race_check_reports_without_masking_under_sanitize(engine):
    tools = dict(DEFAULT_TOOLS)
    tools["rebuild"] = replace(tools["build"], name="rebuild")
    eps = make_episodes(WorkloadConfig(seed=42, n_episodes=2))
    rt = BPasteRuntime(eps, engine, tools=tools,
                       rcfg=RuntimeConfig(race_mask=False, sanitize=True))
    a, b = _fake_branch(1, eu=2.0, tool="build"), \
        _fake_branch(2, eu=1.0, tool="rebuild")
    rt._check_write_races([a, b])
    assert a[1].meta_admitted and b[1].meta_admitted   # report-only
    assert rt.metrics.race_masked == 0
    assert any(f.rule == "R3-write-race" for f in rt.sanitizer.findings)


def test_same_tool_claims_are_benign(engine):
    eps = make_episodes(WorkloadConfig(seed=42, n_episodes=2))
    rt = BPasteRuntime(eps, engine,
                       rcfg=RuntimeConfig(race_mask=True, sanitize=True))
    a, b = _fake_branch(1, eu=2.0, tool="build"), \
        _fake_branch(2, eu=1.0, tool="build")
    rt._check_write_races([a, b])
    assert a[1].meta_admitted and b[1].meta_admitted
    assert rt.metrics.race_masked == 0
    assert not rt.sanitizer.findings


# ======================================================================
# report plumbing
# ======================================================================

def test_report_render_json_and_extend():
    r1 = AnalysisReport()
    r1.add("R1-footprint", "error", "edit", "boom")
    r2 = AnalysisReport()
    r2.add("S5-store-index", "warn", "store", "drift")
    r2.meta["x"] = 1
    r1.extend(r2)
    assert len(r1) == 2 and not r1.ok and r1.meta == {"x": 1}
    assert "R1-footprint" in r1.render() and "2 finding(s)" in r1.render()
    js = r1.to_json()
    assert js["findings"][0]["site"] == "edit" and js["meta"] == {"x": 1}


def test_sanitizer_tick_sampling(engine):
    rt = _serving_rt(engine, sanitize=True, sanitize_every=5)
    calls = []
    rt.sanitizer.check_all = lambda: calls.append(rt.sanitizer._tick_no)
    for _ in range(12):
        rt.sanitizer.on_tick()
    assert calls == [5, 10]
    assert isinstance(rt.sanitizer, RuntimeSanitizer)
