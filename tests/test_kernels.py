"""Per-kernel correctness: Pallas (interpret=True) and jnp-chunked
implementations vs the pure-jnp oracles, swept over shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.slow      # compile-heavy; fast loop: -m "not slow"

RNG = np.random.default_rng(0)


def _mk(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


ATTN_SHAPES = [
    # (B, S, H, KV, D)
    (1, 128, 4, 4, 64),
    (2, 200, 8, 2, 32),
    (1, 64, 6, 3, 128),
]


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 48), (False, None)])
def test_flash_attention(impl, shape, dtype, causal, window):
    b, s, h, kv, d = shape
    q, k, v = _mk((b, s, h, d), dtype), _mk((b, s, kv, d), dtype), _mk((b, s, kv, d), dtype)
    want = ref.mha_reference(q, k, v, causal=causal, window=window)
    got = ops.flash_attention(q, k, v, causal=causal, window=window, impl=impl,
                              block_q=64, block_k=64)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


DECODE_SHAPES = [
    # (B, H, KV, D, Smax)
    (2, 8, 2, 64, 256),
    (3, 4, 4, 32, 100),
    (1, 6, 2, 128, 513),
]


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 40])
def test_decode_attention(impl, shape, dtype, window):
    b, h, kv, d, smax = shape
    q = _mk((b, h, d), dtype)
    kc, vc = _mk((b, smax, kv, d), dtype), _mk((b, smax, kv, d), dtype)
    lens = jnp.asarray(RNG.integers(1, smax + 1, size=(b,)), jnp.int32)
    want = ref.decode_attention_reference(q, kc, vc, lens, window=window)
    got = ops.decode_attention(q, kc, vc, lens, window=window, impl=impl, block_k=64)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


SSD_SHAPES = [
    # (B, S, H, P, G, N, chunk)
    (1, 96, 2, 16, 1, 16, 32),
    (2, 130, 4, 32, 2, 16, 64),
    (1, 64, 4, 64, 1, 64, 32),
]


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("with_h0", [False, True])
def test_ssd_scan(impl, shape, with_h0):
    b, s, h, p, g, n, chunk = shape
    x = _mk((b, s, h, p), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = _mk((b, s, g, n), jnp.float32)
    C = _mk((b, s, g, n), jnp.float32)
    D = _mk((h,), jnp.float32)
    h0 = _mk((b, h, p, n), jnp.float32) if with_h0 else None
    want_y, want_h = ref.ssd_reference(x, dt, A, B, C, D, initial_state=h0)
    got_y, got_h = ops.ssd_scan(x, dt, A, B, C, D, chunk=chunk, impl=impl,
                                initial_state=h0)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h), atol=3e-4, rtol=3e-4)


def test_ssm_decode_matches_scan():
    """Recurrent decode steps must agree with the chunked scan."""
    b, s, h, p, g, n = 2, 17, 2, 8, 1, 8
    x = _mk((b, s, h, p), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = _mk((b, s, g, n), jnp.float32)
    C = _mk((b, s, g, n), jnp.float32)
    D = _mk((h,), jnp.float32)
    want_y, want_h = ref.ssd_reference(x, dt, A, B, C, D)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y, state = ops.ssm_decode_step(x[:, t], dt[:, t], A, B[:, t], C[:, t], D, state)
        ys.append(y)
    got_y = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(want_h), atol=2e-4, rtol=2e-4)


def test_hypothesis_streaming_softmax_invariance():
    """Property: flash attention must be invariant to KV block size."""
    pytest.importorskip("hypothesis",
                        reason="hypothesis not installed (requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(
        s=st.integers(16, 96),
        bk=st.sampled_from([16, 32, 64]),
        causal=st.booleans(),
    )
    def prop(s, bk, causal):
        q = _mk((1, s, 2, 16), jnp.float32)
        k = _mk((1, s, 2, 16), jnp.float32)
        v = _mk((1, s, 2, 16), jnp.float32)
        want = ref.mha_reference(q, k, v, causal=causal)
        got = ops.flash_attention(q, k, v, causal=causal, impl="pallas_interpret",
                                  block_q=bk, block_k=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)

    prop()


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_decode_attention_partials_combine(impl):
    """Split-KV partials from two half-caches must combine to the oracle —
    the distributed flash-decode identity used by attn_decode_sharded."""
    b, h, kv, d, s = 2, 8, 2, 64, 300
    q = _mk((b, h, d), jnp.float32)
    kc, vc = _mk((b, s, kv, d), jnp.float32), _mk((b, s, kv, d), jnp.float32)
    lens = jnp.asarray([120, 300], jnp.int32)
    want = ref.decode_attention_reference(q, kc, vc, lens)
    halves = []
    for lo, hi in ((0, 150), (150, 300)):
        eff = jnp.clip(lens - lo, 0, hi - lo)
        halves.append(ops.decode_attention_partials(
            q, kc[:, lo:hi], vc[:, lo:hi], eff, impl=impl, block_k=64))
    m_g = jnp.maximum(halves[0][1], halves[1][1])
    l_g = sum(jnp.exp(m - m_g) * l for a, m, l in halves)
    acc_g = sum(jnp.exp(m - m_g)[..., None] * a for a, m, l in halves)
    out = (acc_g / jnp.maximum(l_g[..., None], 1e-30)).reshape(b, h, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)
