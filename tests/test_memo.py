"""Cross-episode result store (memo.py), per-call footprint tracking
(executor.StateFacade), cache-served commits through the runtime, and the
sandbox CoW ride-along fixes."""
import numpy as np
import pytest

from repro.core.events import ResourceVector, SafetyLevel
from repro.core.executor import StateFacade, execute_tool
from repro.core.interference import Machine
from repro.core.memo import ABSENT, ResultStore, memo_key
from repro.core.patterns import PatternEngine
from repro.core.runtime import BPasteRuntime, RuntimeConfig, run_mode
from repro.core.safety import (
    EligibilityPolicy, FULL_POLICY, PREP_ONLY_POLICY, READ_ONLY_POLICY,
)
from repro.core.sandbox import AgentState, Sandbox, _TOMBSTONE
from repro.core.workload import (
    Episode, Step, WorkloadConfig, episodes_to_traces, make_episodes,
)

THOR = Machine(ResourceVector(cpu=6, mem_bw=50, io=200, accel=1))


@pytest.fixture(scope="module")
def engine():
    eps = make_episodes(WorkloadConfig(seed=1, n_episodes=60))
    return PatternEngine(context_len=2, min_support=3).fit(episodes_to_traces(eps))


# ======================================================================
# Per-call footprint tracking (executor.StateFacade)
# ======================================================================

def test_facade_records_read_footprint_with_values():
    st = AgentState(fs={"p": "hello"})
    fac = StateFacade(st)
    execute_tool("read", {"path": "p"}, fac)
    assert fac.reads == {"F:p": "hello"}
    assert fac.write_values == {}


def test_facade_records_absent_reads():
    """A read that falls through to the tool's internal default must be
    distinguishable from a read of a stored None/'' value."""
    fac = StateFacade(AgentState())
    execute_tool("read", {"path": "q"}, fac)
    assert fac.reads["F:q"] is ABSENT


def test_facade_records_write_overlay():
    fac = StateFacade(AgentState())
    execute_tool("edit", {"path": "p", "change": "fix"}, fac)
    assert fac.write_values == {"F:p": "edited::fix"}
    assert fac.writes == {"F:p"}


def test_facade_excludes_self_reads():
    """visit writes F:url then test-style reads of the same key within ONE
    call must not enter the read footprint (replay reproduces them)."""
    fac = StateFacade(AgentState())
    execute_tool("visit", {"url": "u"}, fac)
    # simulate a same-call read of the just-written key
    v = fac.F.get("u")
    assert v.startswith("content::")
    assert "F:u" not in fac.reads
    assert "F:u" in fac.write_values


def test_facade_begin_call_resets_per_call_footprint():
    st = AgentState(fs={"p": "x"})
    fac = StateFacade(st)
    execute_tool("read", {"path": "p"}, fac)
    execute_tool("edit", {"path": "p", "change": "a"}, fac)
    fac.begin_call()
    assert fac.reads == {} and fac.write_values == {}
    assert "F:p" in fac.writes                 # cumulative set survives
    execute_tool("read", {"path": "p"}, fac)
    assert fac.reads == {"F:p": "edited::a"}   # post-reset reads re-record


def test_facade_sandbox_footprint_tracks_per_call():
    """Sandboxed runs get the same per-call footprint (CowView.base_reads is
    sandbox-lifetime — over-broad for store entries)."""
    base = AgentState(fs={"a": 1, "b": 2})
    sb = Sandbox(base, hid=1)
    fac = StateFacade(sb)
    fac.F.get("a")
    fac.begin_call()
    fac.F.get("b")
    assert fac.reads == {"F:b": 2}             # per-call: only b
    assert sb.F.base_reads == {"a", "b"}       # sandbox-lifetime: both


# ======================================================================
# Satellite: live-write version bumps (visit/fetch/pip_download + prep)
# ======================================================================

@pytest.mark.parametrize("tool,args", [
    ("visit", {"url": "u"}),
    ("fetch", {"url": "u"}),
    ("pip_download", {"pkg": "p"}),
    # prep tools write E:warm:* into the live base; PREP_ONLY also dodges
    # the runtime's level>=STAGED_WRITE bump, so the executor must bump
    ("session_init", {}),
    ("env_warmup", {}),
])
def test_authoritative_live_write_bumps_version(tool, args):
    """Regression: these tools mutate the live base without bumping the
    version, so Sandbox.is_stale() missed the mutation and replay validity
    went unchecked."""
    st = AgentState()
    sb = Sandbox(st, hid=1)
    assert not sb.is_stale()
    execute_tool(tool, args, StateFacade(st))
    assert st.version > 0
    assert sb.is_stale()


def test_sandboxed_write_never_bumps_live_version():
    st = AgentState()
    sb = Sandbox(st, hid=1)
    execute_tool("visit", {"url": "u"}, StateFacade(sb))
    assert st.version == 0


# ======================================================================
# Satellite: Sandbox.fork read-set seeding + CoW edge cases
# ======================================================================

def test_fork_seeds_base_reads():
    """Regression: fork seeded overlays but dropped base_reads, so the
    write-conflict check missed conflicts on keys only the parent read."""
    base = AgentState(fs={"k": 1}, memory={"m": 2}, env={"e": 3})
    parent = Sandbox(base, hid=1)
    parent.F.get("k")
    parent.M.get("m")
    parent.E.get("e")
    child = parent.fork(hid=2)
    assert {"F:k", "M:m", "E:e"} <= child.base_read_set


def test_fork_conflict_detected_on_parent_only_read(engine):
    """Runtime-level: an authoritative write to a key only the PARENT prefix
    read must squash the forked child branch."""
    from tests.test_runtime import _manual_runtime, _mk_hyprun
    rt, es = _manual_runtime(engine, [("grep", {"pattern": "x"}),
                                      ("read", {"path": "p"})])
    hr = _mk_hyprun(rt, es, ["read"])
    hr.sandbox.F.get("p")                      # parent-read key
    forked = hr.sandbox.fork(hid=77)
    hr.sandbox = forked                        # branch continues on the fork
    hr.node_runs[0].status = "running"
    es.last_writes = {"F:p"}
    rt._finish_action(es, {"ok": 1}, 1.0)
    assert hr.status == "squashed"


def test_tombstone_delete_through_fork_and_commit():
    base = AgentState(fs={"gone": 1, "kept": 2})
    parent = Sandbox(base, hid=1)
    parent.F.delete("gone")
    child = parent.fork(hid=2)
    assert "gone" not in child.F
    assert child.F.get("gone", "dflt") == "dflt"
    assert child.commit()
    assert base.fs == {"kept": 2}


def test_cowview_keys_under_overlay_deletes():
    base = AgentState(fs={"a": 1, "b": 2})
    sb = Sandbox(base, hid=1)
    sb.F.delete("a")
    sb.F.set("c", 3)
    assert sb.F.keys() == {"b", "c"}
    sb.F.set("a", 9)                           # resurrect over the tombstone
    assert sb.F.keys() == {"a", "b", "c"}
    assert sb.F.get("a") == 9


def test_squash_then_reuse_resets_read_set():
    base = AgentState(fs={"a": 1})
    sb = Sandbox(base, hid=1)
    sb.F.get("a")
    sb.F.set("x", 1)
    assert sb.base_read_set == {"F:a"}
    sb.squash()
    assert sb.base_read_set == set()
    assert sb.write_set == set()
    sb.F.get("a")                              # post-squash reads re-track
    assert sb.base_read_set == {"F:a"}


# ======================================================================
# ResultStore unit semantics
# ======================================================================

def _publish(store, tool="read", args=None, result=None, reads=None,
             writes=None, level=SafetyLevel.READ_ONLY, eid=0):
    return store.publish(tool, args or {"path": "p"},
                         result if result is not None else {"ok": 1},
                         reads=reads or {}, writes=writes or {},
                         level=level, solo_work=1.0, eid=eid)


def test_store_publish_peek_roundtrip():
    store = ResultStore()
    e = _publish(store, args={"path": "p"}, result={"content": "c"})
    assert store.peek("read", {"path": "p"}) is e
    assert store.peek("read", {"path": "q"}) is None
    # canonical args: order-free
    store.publish("edit", {"path": "p", "change": "x"}, {"ok": True},
                  reads={}, writes={}, level=SafetyLevel.STAGED_WRITE,
                  solo_work=1.0, eid=0)
    assert store.peek("edit", {"change": "x", "path": "p"}) is not None


def test_store_validate_by_value_and_absence():
    store = ResultStore()
    e = _publish(store, reads={"F:p": "v1", "F:q": ABSENT})
    ok = AgentState(fs={"p": "v1"})
    assert store.validate(e, ok)
    assert not store.validate(e, AgentState(fs={"p": "OTHER"}))
    assert not store.validate(e, AgentState(fs={"p": "v1", "q": "appeared"}))
    assert not store.validate(e, AgentState())          # p missing


def test_store_validation_cache_expires_on_version_bump():
    store = ResultStore()
    e = _publish(store, reads={"F:p": "v1"})
    st = AgentState(fs={"p": "v1"})
    assert store.validate(e, st, eid=5)
    assert e.validated_at[5] == store.version
    store.note_writes({"F:unrelated": "x"})             # version bump
    assert e.validated_at[5] != store.version
    assert store.validate(e, st, eid=5)                 # revalidates fine


def test_store_footprint_invalidation_on_conflicting_write():
    store = ResultStore()
    _publish(store, args={"path": "p"}, reads={"F:p": "v1"})
    _publish(store, tool="parse", args={"path": "z"}, reads={"F:z": "zz"})
    store.note_writes({"F:p": "CHANGED"})
    assert store.peek("read", {"path": "p"}) is None    # intersecting: killed
    assert store.peek("parse", {"path": "z"}) is not None
    assert store.invalidations == 1


def test_store_consistent_write_keeps_entry_valid():
    """A write that re-asserts the observed value must NOT invalidate."""
    store = ResultStore()
    _publish(store, args={"path": "p"}, reads={"F:p": "v1"})
    store.note_writes({"F:p": "v1"})
    assert store.peek("read", {"path": "p"}) is not None
    assert store.invalidations == 0


def test_store_absent_read_invalidated_by_value_write():
    store = ResultStore()
    _publish(store, args={"path": "p"}, reads={"F:p": ABSENT})
    store.note_writes({"F:p": "now exists"})
    assert store.peek("read", {"path": "p"}) is None
    # tombstone write is consistent with an ABSENT read
    store2 = ResultStore()
    _publish(store2, args={"path": "p"}, reads={"F:p": ABSENT})
    store2.note_writes({"F:p": _TOMBSTONE})
    assert store2.peek("read", {"path": "p"}) is not None


def test_store_apply_writes_live_and_sandbox():
    store = ResultStore()
    e = _publish(store, tool="edit", args={"path": "p", "change": "x"},
                 writes={"F:p": "edited::x", "F:old": _TOMBSTONE},
                 level=SafetyLevel.STAGED_WRITE)
    live = AgentState(fs={"old": 1})
    assert store.apply_writes(e, live) == {"F:p", "F:old"}
    assert live.fs == {"p": "edited::x"}
    base = AgentState(fs={"old": 1})
    sb = Sandbox(base, hid=1)
    store.apply_writes(e, sb)
    assert base.fs == {"old": 1}                    # overlay-isolated
    assert sb.F.get("p") == "edited::x"
    assert "old" not in sb.F


def test_store_pending_subscribe_publish_and_abort():
    store = ResultStore()
    key = memo_key("read", {"path": "p"})
    store.begin(key, owner_jid=11)
    got = []
    assert store.subscribe(key, got.append)
    assert store.is_pending(key)
    store.abort(key, owner_jid=99)                  # wrong owner: no-op
    assert store.is_pending(key)
    e = _publish(store, args={"path": "p"})
    assert got == [e]
    assert not store.is_pending(key)
    # abort path: subscribers woken with None
    store.begin(key, owner_jid=12)
    got2 = []
    store.subscribe(key, got2.append)
    store.abort(key, owner_jid=12)
    assert got2 == [None]
    assert not store.is_pending(key)


def test_store_has_tool_tracks_live_entries():
    store = ResultStore()
    assert not store.has_tool("read")
    _publish(store, args={"path": "p"}, reads={"F:p": "v"})
    assert store.has_tool("read")
    store.note_writes({"F:p": "x"})
    assert not store.has_tool("read")


# ======================================================================
# Safety gating of serves
# ======================================================================

def test_servable_levels():
    assert FULL_POLICY.servable("search") == "direct"
    assert FULL_POLICY.servable("env_warmup") == "direct"
    assert FULL_POLICY.servable("edit") == "replay"
    assert FULL_POLICY.servable("deploy") is None
    assert READ_ONLY_POLICY.servable("search") == "direct"
    assert READ_ONLY_POLICY.servable("edit") is None     # staged not admitted
    assert PREP_ONLY_POLICY.servable("pip_install") is None


# ======================================================================
# Runtime integration: cache-served commits
# ======================================================================

def _two_identical_episodes(tool_steps):
    return [Episode(eid, "manual", [Step(1.0, t, dict(a)) for t, a in tool_steps])
            for eid in (0, 1)]


def test_authoritative_serve_cross_episode(engine):
    """Tenant 1 repeats tenant 0's read-only action: the second invocation
    is served from the store at zero execution cost."""
    eps = _two_identical_episodes([("grep", {"pattern": "shared"}),
                                   ("read", {"path": "doc"})])
    m = run_mode(eps, engine, "bpaste", THOR, seed=7,
                 max_concurrent_episodes=1)
    assert m.memo_serves >= 1
    assert m.memo_saved_seconds > 0
    assert m.tenant_memo_saved.get(1, 0.0) > 0


def test_staged_write_serve_replays_overlay(engine):
    """A served STAGED_WRITE entry must replay its write overlay onto the
    live state (commit-barrier semantics), leaving the state exactly as
    execution would."""
    eps = _two_identical_episodes([("edit", {"path": "p", "change": "fix"}),
                                   ("test", {"target": "p"})])
    rt = BPasteRuntime(eps, engine, THOR,
                       rcfg=RuntimeConfig(mode="bpaste", seed=7))
    m = rt.run()
    for es in rt.episodes:
        assert es.state.fs.get("p") == "edited::fix"
        assert es.history[1].result["pass"] is True
    # serial reference: identical final state
    rt_s = BPasteRuntime(_two_identical_episodes(
        [("edit", {"path": "p", "change": "fix"}), ("test", {"target": "p"})]),
        engine, THOR, rcfg=RuntimeConfig(mode="serial", seed=7))
    rt_s.run()
    for es_b, es_s in zip(rt.episodes, rt_s.episodes, strict=True):
        assert es_b.state.fs == es_s.state.fs


def test_serve_refused_when_read_footprint_diverges(engine):
    """test(target=p) read F:p='edited::a' when published; tenant 1's F:p
    differs, so the entry must NOT be served there."""
    eps = [Episode(0, "m", [Step(1.0, "edit", {"path": "p", "change": "a"}),
                            Step(1.0, "test", {"target": "p"})]),
           Episode(1, "m", [Step(1.0, "edit", {"path": "p", "change": "b"}),
                            Step(1.0, "test", {"target": "p"})])]
    rt = BPasteRuntime(eps, engine, THOR,
                       rcfg=RuntimeConfig(mode="bpaste", seed=7))
    rt.run()
    # both tenants' test results reflect THEIR own file content
    assert rt.episodes[0].history[1].result["pass"] is False
    assert rt.episodes[1].history[1].result["pass"] is False
    assert rt.episodes[0].state.fs["p"] == "edited::a"
    assert rt.episodes[1].state.fs["p"] == "edited::b"


def test_non_speculative_tools_never_served(engine):
    eps = _two_identical_episodes([("deploy", {})])
    rt = BPasteRuntime(eps, engine, THOR,
                       rcfg=RuntimeConfig(mode="bpaste", seed=7))
    m = rt.run()
    assert m.memo_serves == 0
    assert m.auth_actions == 2


def test_state_equivalence_with_memo_shared_workload(engine):
    """The correctness contract under the store: cache-served commits must
    leave every tenant's final state exactly as serial execution would —
    including the shared-corpus workload where cross-tenant serves fire."""
    eps = make_episodes(WorkloadConfig(seed=13, n_episodes=6,
                                       shared_frac=0.6, shared_pool=2))
    rt_s = BPasteRuntime(eps, engine, THOR, rcfg=RuntimeConfig(mode="serial"))
    rt_s.run()
    rt_b = BPasteRuntime(eps, engine, THOR, rcfg=RuntimeConfig(
        mode="bpaste", max_concurrent_episodes=3))
    mb = rt_b.run()
    for es_s, es_b in zip(rt_s.episodes, rt_b.episodes, strict=True):
        assert es_s.state.fs == es_b.state.fs
        assert es_s.state.env == es_b.state.env
        assert [e.tool for e in es_s.history] == [e.tool for e in es_b.history]
        assert [e.args for e in es_s.history] == [e.args for e in es_b.history]
        assert [e.result for e in es_s.history] == [e.result for e in es_b.history]


def test_memo_off_matches_pre_store_runtime(engine):
    """memo=False must be the exact pre-store runtime (no serve, no dedup,
    no mask)."""
    eps = make_episodes(WorkloadConfig(seed=42, n_episodes=6))
    m = run_mode(eps, engine, "bpaste", THOR, seed=7, memo=False)
    assert m.memo_serves == m.memo_hits == m.memo_dedups == 0
    assert m.memo_entries == 0


def test_memo_deterministic(engine):
    eps = make_episodes(WorkloadConfig(seed=9, n_episodes=6, shared_frac=0.5,
                                       shared_pool=2))
    m1 = run_mode(eps, engine, "bpaste", THOR, seed=7,
                  max_concurrent_episodes=2)
    m2 = run_mode(eps, engine, "bpaste", THOR, seed=7,
                  max_concurrent_episodes=2)
    assert m1.makespan == m2.makespan
    assert m1.memo_serves == m2.memo_serves
    assert m1.memo_hits == m2.memo_hits


def test_memo_fused_matches_reference_runtime(engine):
    """The memo-mask reuse term must thread identically through the fused
    kernel and the reference greedy end-to-end."""
    eps = make_episodes(WorkloadConfig(seed=11, n_episodes=6, shared_frac=0.5,
                                       shared_pool=2))
    mf = run_mode(eps, engine, "bpaste", THOR, seed=7,
                  max_concurrent_episodes=3, admission="fused")
    mr = run_mode(eps, engine, "bpaste", THOR, seed=7,
                  max_concurrent_episodes=3, admission="reference")
    assert mf.makespan == pytest.approx(mr.makespan, rel=1e-9)
    assert mf.reuses == mr.reuses
    assert mf.memo_serves == mr.memo_serves
    assert mf.memo_hits == mr.memo_hits


# ======================================================================
# Satellite: in-flight launch dedup
# ======================================================================

def test_inflight_dedup_subscribes_second_launch(engine):
    """Two branches speculating the same (tool, args): the second must
    subscribe to the first run instead of starting a twin job, and be fed
    the result at publish."""
    from tests.test_runtime import _manual_runtime, _mk_hyprun
    rt, es = _manual_runtime(engine, [("grep", {"pattern": "x"}),
                                      ("read", {"path": "p"})])
    h1 = _mk_hyprun(rt, es, ["read"])
    h2 = _mk_hyprun(rt, es, ["read"])
    for hr in (h1, h2):
        hr.node_runs[0].resolved_args = {"path": "pp"}
        hr.meta_admitted = True
    assert rt._start_spec_node(es, h1, 0)
    assert h1.node_runs[0].status == "running"
    started = rt._start_spec_node(es, h2, 0)
    assert not started
    assert h2.node_runs[0].waiting
    assert h2.node_runs[0].status == "pending"
    assert rt.metrics.memo_dedups == 1
    n_spec_jobs = sum(1 for j in rt.sim.running.values() if j.speculative)
    assert n_spec_jobs == 1                     # no twin job burning slack
    while h1.node_runs[0].status == "running":  # drive to completion
        assert rt.sim.step()
    assert h1.node_runs[0].status == "done"
    assert h2.node_runs[0].status == "done"     # fed by publish
    assert not h2.node_runs[0].waiting
    assert h2.node_runs[0].result == h1.node_runs[0].result
    assert rt.metrics.memo_hits == 1


def test_inflight_dedup_rearms_on_owner_abort(engine):
    """If the owning job is squashed/preempted, subscribers are woken with
    None and must be launchable again (no permanently-stuck waiters)."""
    from tests.test_runtime import _manual_runtime, _mk_hyprun
    rt, es = _manual_runtime(engine, [("grep", {"pattern": "x"}),
                                      ("read", {"path": "p"})])
    h1 = _mk_hyprun(rt, es, ["read"])
    h2 = _mk_hyprun(rt, es, ["read"])
    for hr in (h1, h2):
        hr.node_runs[0].resolved_args = {"path": "pp"}
        hr.meta_admitted = True
    assert rt._start_spec_node(es, h1, 0)
    assert not rt._start_spec_node(es, h2, 0)
    rt._squash_one(es, h1)                      # owner dies
    assert not h2.node_runs[0].waiting          # woken with None
    assert rt._start_spec_node(es, h2, 0)       # re-arms and launches itself
    assert h2.node_runs[0].status == "running"


def test_spec_serve_into_sandbox(engine):
    """A node whose (tool, args) is already memoized completes instantly in
    the sandbox — no job, zero slack."""
    from tests.test_runtime import _manual_runtime, _mk_hyprun
    rt, es = _manual_runtime(engine, [("grep", {"pattern": "x"}),
                                      ("read", {"path": "p"})])
    rt.store.publish("read", {"path": "pp"}, {"path": "pp", "content": "c"},
                     reads={}, writes={}, level=SafetyLevel.READ_ONLY,
                     solo_work=0.8, eid=0)
    hr = _mk_hyprun(rt, es, ["read"])
    hr.node_runs[0].resolved_args = {"path": "pp"}
    hr.meta_admitted = True
    assert rt._start_spec_node(es, hr, 0)
    nr = hr.node_runs[0]
    assert nr.status == "done" and nr.served and nr.job is None
    assert nr.result == {"path": "pp", "content": "c"}
    assert rt.metrics.memo_hits == 1
    assert not any(j.speculative for j in rt.sim.running.values())
