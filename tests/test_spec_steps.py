"""Speculative reasoning steps (RuntimeConfig.spec_model_steps): passenger
mechanics on the batch service, free-rider timing, validate-on-arrival
lifecycle accounting, the edge-regime makespan win, and the spec-off /
adaptive-linger-off bit-identity pins."""
import json
import os

import numpy as np
import pytest

from repro.core.events import DEFAULT_TOOLS, ResourceVector
from repro.core.interference import Machine, batched_step_latency
from repro.core.model_service import (
    ModelStepRequest, ModelStepService, SpecStepTicket,
)
from repro.core.patterns import PatternEngine
from repro.core.runtime import Metrics, run_mode
from repro.core.simulator import Simulator
from repro.core.workload import (
    WorkloadConfig, episodes_to_traces, make_episodes,
)

MODEL_RHO = DEFAULT_TOOLS["model_step"].rho.as_array()
THOR = Machine()                            # accel=1 edge box
PINNED = os.path.join(os.path.dirname(__file__), "data",
                      "pr9_pinned_serving.json")
# wall-clock self-measurements: the only summary keys legitimately allowed
# to differ between bit-identical schedules
WALL_CLOCK_KEYS = {"sched_us_per_admit", "sched_us_per_tick"}


def _bare_service(**kw):
    sim = Simulator(THOR, lambda s: None)
    m = Metrics()
    svc = ModelStepService(sim, MODEL_RHO, metrics=m, **kw)
    return sim, svc, m


def _ticket(eid=90, work=2.0, eu=1.0, on_done=None, on_evict=None):
    return SpecStepTicket(eid=eid, work=work, eu=eu,
                          on_done=on_done or (lambda s, j: None),
                          on_evict=on_evict or (lambda: None))


# ----------------------------------------------------------------------
# passenger mechanics (service driven directly on a bare simulator)
# ----------------------------------------------------------------------
def test_spec_submit_needs_open_window_and_free_slot():
    """Passengers never open windows: submission is refused with no batch
    forming, with every slot claimed, and on the max_batch=1 baseline."""
    sim, svc, _ = _bare_service(max_batch=2, linger=2.0)
    assert not svc.spec_slot_free
    assert not svc.submit_speculative(_ticket())      # no window open
    svc.submit(ModelStepRequest(0, "model[e0.0]", 2.0, lambda s, j: None))
    assert svc.spec_slot_free
    assert svc.submit_speculative(_ticket())          # rides the idle slot
    assert not svc.spec_slot_free
    assert not svc.submit_speculative(_ticket())      # batch is now full

    _, svc1, _ = _bare_service(max_batch=1, linger=2.0)
    assert not svc1.spec_slot_free
    assert not svc1.submit_speculative(_ticket())


def test_passenger_rides_free():
    """Batch duration comes from the authoritative works ONLY — a heavy
    passenger adds zero marginal latency — and the passenger's completion
    fires after the authoritative continuations, same instant."""
    sim, svc, m = _bare_service(max_batch=4, linger=1.0)
    order = []
    svc.submit(ModelStepRequest(0, "model[e0.0]", 2.0,
                                lambda s, j: order.append(("auth", s.now))))
    assert svc.submit_speculative(_ticket(
        work=50.0, on_done=lambda s, j: order.append(("spec", s.now))))
    sim.run()
    done_t = 1.0 + batched_step_latency([2.0], svc.marginal)
    assert [k for k, _ in order] == ["auth", "spec"]
    for _, t in order:
        np.testing.assert_allclose(t, done_t)
    # QoS attribution stays authoritative-only
    assert m.model_batch_occupancy_samples == [1]
    assert m.spec_slot_fill_samples == [1]


def test_lowest_eu_passenger_evicted_when_auth_fill_needs_the_slot():
    """Authoritative fill always wins: overflowing the batch evicts the
    lowest-EU passenger (never delays or drops an authoritative member)."""
    sim, svc, _ = _bare_service(max_batch=2, linger=5.0)
    evicted = []
    svc.submit(ModelStepRequest(0, "model[e0.0]", 2.0, lambda s, j: None))
    assert svc.submit_speculative(_ticket(
        eu=0.3, on_evict=lambda: evicted.append("low")))
    fired = []
    svc.submit(ModelStepRequest(1, "model[e1.0]", 2.0,
                                lambda s, j: fired.append(s.now)))
    assert evicted == ["low"]               # slot reclaimed
    assert svc.forming_size == 0            # fill-triggered dispatch
    sim.run()
    np.testing.assert_allclose(
        fired[0], batched_step_latency([2.0, 2.0], svc.marginal))


def test_eviction_picks_the_minimum_eu_among_passengers():
    sim, svc, _ = _bare_service(max_batch=3, linger=5.0)
    evicted = []
    svc.submit(ModelStepRequest(0, "model[e0.0]", 2.0, lambda s, j: None))
    assert svc.submit_speculative(_ticket(
        eu=0.9, on_evict=lambda: evicted.append("high")))
    assert svc.submit_speculative(_ticket(
        eu=0.1, on_evict=lambda: evicted.append("low")))
    svc.submit(ModelStepRequest(1, "model[e1.0]", 2.0, lambda s, j: None))
    assert evicted == ["low"]
    sim.run()
    assert evicted == ["low"]               # the survivor rode to completion


def test_withdraw_and_promote_spec():
    """Withdraw removes a forming passenger (squash-before-dispatch);
    promote turns one into a regular member — which may fill-trigger."""
    sim, svc, _ = _bare_service(max_batch=2, linger=5.0)
    svc.submit(ModelStepRequest(0, "model[e0.0]", 2.0, lambda s, j: None))
    t = _ticket()
    assert svc.submit_speculative(t)
    assert svc.withdraw_spec(t)
    assert not svc.withdraw_spec(t)         # already gone
    assert svc.spec_slot_free               # slot reopened

    t2 = _ticket()
    assert svc.submit_speculative(t2)
    fired = []
    svc.promote_spec(t2, ModelStepRequest(
        1, "model[e1.0]", 2.0, lambda s, j: fired.append(s.now)))
    assert svc.forming_size == 0            # promotion filled the batch
    sim.run()
    np.testing.assert_allclose(
        fired[0], batched_step_latency([2.0, 2.0], svc.marginal))


def test_adaptive_linger_shrinks_window_under_trickle():
    """Fixed path returns `linger` untouched; the adaptive window shrinks
    proportionally once the EMA inter-arrival gap passes the moderate
    regime (coalescing unlikely — stop paying the full admission tax)."""
    _, fixed, _ = _bare_service(max_batch=4, linger=1.5)
    fixed._ema_gap = 30.0                   # ignored: adaptive off
    assert fixed._window_len() == 1.5
    _, ad, _ = _bare_service(max_batch=4, linger=1.5, adaptive=True)
    assert ad._window_len() == 1.5          # no signal yet
    ad._ema_gap = 1.0                       # denser than the window: keep
    assert ad._window_len() == 1.5
    ad._ema_gap = 3.5                       # trickle (> 2·linger): shrink
    np.testing.assert_allclose(ad._window_len(), 1.5 * (1.5 / 3.5))
    ad._ema_gap = 1e9
    assert ad._window_len() >= 1e-9         # floored, never zero


def test_adaptive_linger_stretches_window_in_moderate_regime():
    """Arrivals landing just past the fixed window stretch it toward the
    expected gap (capped at 2·linger): the window catches the next tenant
    instead of dispatching solo after paying the full linger tax."""
    _, ad, _ = _bare_service(max_batch=4, linger=1.5, adaptive=True)
    ad._ema_gap = 2.0                       # moderate: stretch to 1.25·gap
    np.testing.assert_allclose(ad._window_len(), 2.5)
    ad._ema_gap = 2.9                       # cap binds at 2·linger
    np.testing.assert_allclose(ad._window_len(), 3.0)
    ad._ema_gap = 3.0                       # moderate edge: still capped
    np.testing.assert_allclose(ad._window_len(), 3.0)
    # monotone hand-off: just past the edge the trickle regime takes over
    ad._ema_gap = 3.0 + 1e-9
    assert ad._window_len() < 1.5


def test_adaptive_linger_window_restores_under_burst_fill():
    """A burst pulling the EMA gap back under the window restores the full
    fixed linger — shrink is load-following, not a ratchet.  Driven through
    the real EMA update (submit path), not by poking the field."""
    sim, ad, _ = _bare_service(max_batch=8, linger=1.5, adaptive=True)

    def sub(i):
        ad.submit(ModelStepRequest(i, f"model[e{i}.0]", 2.0,
                                   lambda s, j: None))

    # trickle: two submits 40 s apart drive the EMA way past 2·linger
    sub(0)
    sim.run()
    sim.now += 40.0
    sub(1)
    assert ad._ema_gap > 2.0 * ad.linger
    assert ad._window_len() < ad.linger
    sim.run()
    # burst fill: back-to-back submits at one instant hammer the EMA with
    # zero gaps until it drops inside the window — full linger restored
    # (full batches fill-dispatch along the way; the EMA rides the submit
    # path, so it keeps decaying across batch boundaries)
    for i in range(2, 16):
        sub(i)
    assert ad._ema_gap <= ad.linger
    assert ad._window_len() == ad.linger
    sim.run()


# ----------------------------------------------------------------------
# end-to-end: the edge-regime cell (shared fixtures, module scope)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def serving_setup():
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=20))
    engine = PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))
    test = make_episodes(WorkloadConfig(
        seed=42, n_episodes=8, arrival_stagger=4.0,
        shared_frac=0.5, shared_pool=2))
    return engine, test


@pytest.fixture(scope="module")
def spec_cell(serving_setup) -> Metrics:
    engine, test = serving_setup
    return run_mode(test, engine, "bpaste", THOR, seed=7,
                    max_concurrent_episodes=8, memo=True,
                    model_max_batch=8, spec_model_steps=True)


def test_spec_steps_beat_the_batched_edge_cell(serving_setup, spec_cell):
    """PR 9 headline at test scale: filling under-full batch dispatches
    with drafted reasoning boundaries beats the plain batched cell — and
    does it for FREE (authoritative slowdown exactly 1, zero QoS
    violations: passengers may never delay the batch)."""
    engine, test = serving_setup
    base = run_mode(test, engine, "bpaste", THOR, seed=7,
                    max_concurrent_episodes=8, memo=True,
                    model_max_batch=8).summary()
    s = spec_cell.summary()
    assert s["spec_steps_accepted"] > 0
    assert s["spec_step_saved_seconds"] > 0
    assert s["makespan"] < base["makespan"]
    assert s["mean_auth_slowdown"] == 1.0
    assert s["qos_violations"] == 0
    assert s["worst_tenant_slowdown"] == 1.0


def test_spec_step_lifecycle_closes(spec_cell):
    """Every submission reaches exactly one terminal outcome, and waste
    bookkeeping preserves wasted_frac <= 1 (each wasted-second increment
    had a matching spec-solo increment)."""
    s = spec_cell.summary()
    assert s["spec_steps_submitted"] > 0
    assert s["spec_steps_submitted"] == (s["spec_steps_accepted"]
                                         + s["spec_steps_squashed"]
                                         + s["spec_steps_evicted"])
    assert 0.0 <= s["spec_squash_rate"] <= 1.0
    assert s["wasted_frac"] <= 1.0
    assert spec_cell.spec_solo_seconds >= spec_cell.wasted_solo_seconds * 0
    assert s["spec_slot_fill"] > 0          # passengers actually rode


def test_spec_off_bit_identical_to_pinned_summaries(serving_setup):
    """spec_model_steps=False (the default) must not move a single summary
    value against the pinned pre-feature captures — the gated frontier
    branch, the builder's segment-2 path, and the admission spec-cost term
    are all exactly inert when off."""
    engine, test = serving_setup
    with open(PINNED) as f:
        pinned = json.load(f)
    serve = Machine(ResourceVector(cpu=12, mem_bw=100, io=500, accel=4))
    cells = {
        "bpaste_memo_thor_c8_b8": (THOR, "bpaste", True, 8),
        "serial_thor_c8_b8": (THOR, "serial", False, 8),
        "bpaste_memo_serve_c8_b1": (serve, "bpaste", True, 1),
        "bpaste_memo_thor_c8_b1": (THOR, "bpaste", True, 1),
    }
    for name, (machine, mode, memo, max_batch) in cells.items():
        got = run_mode(test, engine, mode, machine, seed=7,
                       max_concurrent_episodes=8, memo=memo,
                       model_max_batch=max_batch).summary()
        want = pinned[name]
        diffs = {k: (got.get(k), v) for k, v in want.items()
                 if k not in WALL_CLOCK_KEYS and got.get(k) != v}
        assert not diffs, f"{name}: {diffs}"


def test_adaptive_linger_default_off_is_inert(serving_setup):
    """adaptive_linger=False (the default) is bit-identical to an
    explicit-default run; turned on, the cell still completes cleanly
    with authoritative protection intact."""
    engine, test = serving_setup
    kw = dict(seed=7, max_concurrent_episodes=8, memo=True,
              model_max_batch=8)
    base = run_mode(test, engine, "bpaste", THOR, **kw).summary()
    off = run_mode(test, engine, "bpaste", THOR,
                   adaptive_linger=False, **kw).summary()
    assert {k: v for k, v in base.items() if k not in WALL_CLOCK_KEYS} \
        == {k: v for k, v in off.items() if k not in WALL_CLOCK_KEYS}
    on = run_mode(test, engine, "bpaste", THOR,
                  adaptive_linger=True, **kw).summary()
    assert on["makespan"] > 0
    assert on["qos_violations"] == 0
    assert on["mean_auth_slowdown"] == 1.0
