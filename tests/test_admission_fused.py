"""Fused admission (one-dispatch ``admit_beam``) vs. the numpy reference
greedy: identical admitted sets, matching EU-at-admit, bounded gap to the
exact optimum, and the wide-beam (> k_max) truncation regression."""
import numpy as np
import pytest

from repro.core import admission, scoring
from repro.core.events import DEFAULT_TOOLS, RESOURCE_DIMS
from repro.core.hypothesis import BranchHypothesis, Node, NodeKind
from repro.core.interference import Machine

READ_TOOLS = ["grep", "read", "parse", "search", "fetch", "visit"]


def _mk_hyp(hid, tools, q=0.8):
    nodes, edges = [], []
    for i, t in enumerate(tools):
        spec = DEFAULT_TOOLS[t]
        nodes.append(Node(i, NodeKind.TOOL, t, spec.level, spec.rho,
                          spec.base_latency))
        if i:
            edges.append((i - 1, i))
    return BranchHypothesis(hid, nodes, edges, q, context_key=("x",))


def _random_beam(rng, k):
    hyps = []
    for hid in range(k):
        depth = int(rng.integers(1, 5))
        tools = [READ_TOOLS[int(rng.integers(0, len(READ_TOOLS)))]
                 for _ in range(depth)]
        q = float(rng.uniform(0.1, 0.95))
        hyps.append(_mk_hyp(hid, tools, q=q))
    return hyps


def _assert_equivalent(ref, fus, hyps):
    assert sorted(h.hid for h in ref.admitted) == sorted(h.hid for h in fus.admitted), (
        f"admitted sets differ: ref={[h.hid for h in ref.admitted]} "
        f"fused={[h.hid for h in fus.admitted]}"
    )
    for hid, val in ref.eu.items():
        np.testing.assert_allclose(fus.eu[hid], val, rtol=1e-4, atol=1e-4)
    assert len(ref.rejected) == len(fus.rejected)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
@pytest.mark.parametrize("k", [3, 5, 8])
def test_fused_matches_reference_randomized(seed, k):
    rng = np.random.default_rng(seed)
    sc = scoring.Scorer(Machine())
    hyps = _random_beam(rng, k)
    # slack/budget away from exact feasibility boundaries (f32 vs f64)
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    ref = admission.greedy_admit(hyps, sc, slack, budget, auth)
    fus = admission.fused_admit(hyps, sc, slack, budget, auth)
    _assert_equivalent(ref, fus, hyps)


def test_fused_respects_budget():
    sc = scoring.Scorer(Machine())
    hyps = [_mk_hyp(i, ["test"]) for i in range(4)]   # cpu=2 each
    slack = np.array([12.0, 100.0, 500.0, 1.0])
    budget = np.array([4.0, 100.0, 500.0, 1.0])       # only 2 test jobs fit
    res = admission.fused_admit(hyps, sc, slack, budget, np.zeros(4))
    assert 0 < len(res.admitted) <= 2
    total = sum(admission._prefix_rho(h) for h in res.admitted)
    assert np.all(np.asarray(total) <= budget + 1e-6)


def test_fused_close_to_exact():
    """Fused greedy stays within the same gap bound as the reference."""
    sc = scoring.Scorer(Machine())
    hyps = [_mk_hyp(i, t) for i, t in enumerate(
        [["grep", "read"], ["search", "visit"], ["test"], ["parse"]])]
    slack = np.array([6.0, 50.0, 200.0, 1.0])
    budget = np.array([6.0, 50.0, 200.0, 1.0])
    res = admission.fused_admit(hyps, sc, slack, budget, np.zeros(4))
    fused_total = sum(res.eu.values())
    _, exact_total = admission.exact_admit(hyps, sc, slack, budget, np.zeros(4))
    assert fused_total >= 0.6 * exact_total


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("k", [1, 2])
def test_small_beam_numpy_path_matches_reference(seed, k):
    """Beams at/below small_beam_threshold run host-side numpy; decisions
    must still match the reference greedy."""
    rng = np.random.default_rng(100 + seed)
    sc = scoring.Scorer(Machine())
    hyps = _random_beam(rng, k)
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    ref = admission.greedy_admit(hyps, sc, slack, budget, auth)
    fus = admission.fused_admit(hyps, sc, slack, budget, auth)
    _assert_equivalent(ref, fus, hyps)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_numpy_path_matches_kernel_path(seed):
    """Force the same beam through both fused implementations: the numpy
    fast path and the jitted while_loop kernel must agree."""
    rng = np.random.default_rng(200 + seed)
    sc = scoring.Scorer(Machine())
    hyps = _random_beam(rng, 6)
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    via_np = admission.fused_admit(hyps, sc, slack, budget, auth,
                                   small_beam_threshold=len(hyps))
    via_krn = admission.fused_admit(hyps, sc, slack, budget, auth,
                                    small_beam_threshold=0)
    _assert_equivalent(via_np, via_krn, hyps)


def test_boundary_fit_large_magnitude():
    """Non-dyadic demands at io-dimension scale, limit at the exact-fit
    boundary: the f32 kernel, the numpy path, and the f64 reference must
    agree (relative fit tolerance; absolute slop alone is too tight at
    magnitude ~150)."""
    from repro.core.events import ResourceVector, SafetyLevel, ToolSpec
    spec = ToolSpec("io_heavy", SafetyLevel.READ_ONLY,
                    ResourceVector(0.5, 1.0, 49.9, 0), 2.0)
    sc = scoring.Scorer(Machine())
    hyps = []
    for hid in range(4):
        n = Node(0, NodeKind.TOOL, "io_heavy", spec.level, spec.rho,
                 spec.base_latency)
        hyps.append(BranchHypothesis(hid, [n], [], 0.9 - 0.1 * hid,
                                     context_key=("x",)))
    slack = np.array([12.0, 100.0, 500.0, 1.0])
    budget = np.array([12.0, 100.0, 149.7, 1.0])   # exactly 3 * 49.9
    ref = admission.greedy_admit(hyps, sc, slack, budget, np.zeros(4))
    krn = admission.fused_admit(hyps, sc, slack, budget, np.zeros(4),
                                small_beam_threshold=0)
    npy = admission.fused_admit(hyps, sc, slack, budget, np.zeros(4),
                                small_beam_threshold=len(hyps))
    assert len(ref.admitted) == 3
    _assert_equivalent(ref, krn, hyps)
    _assert_equivalent(ref, npy, hyps)


def test_fused_empty_beam():
    sc = scoring.Scorer(Machine())
    res = admission.fused_admit([], sc, np.ones(4), np.ones(4), np.zeros(4))
    assert res.admitted == [] and res.rejected == []


# ======================================================================
# Shared cross-episode beams (candidates pooled from several tenants,
# per-tenant fairness weights)
# ======================================================================

def _two_tenant_beam(rng, k):
    """Interleaved candidates from two tenants (hids globally unique, as the
    runtime's single builder guarantees) plus per-candidate fairness
    weights: tenant 1 carries in-flight speculative share, so its weight
    is < 1."""
    hyps = _random_beam(rng, k)
    w_by_tenant = {0: 1.0, 1: float(rng.uniform(0.4, 0.9))}
    weights = np.array([w_by_tenant[hid % 2] for hid in range(k)])
    return hyps, weights


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("k", [4, 7, 10])
def test_shared_beam_fused_matches_reference(seed, k):
    """Fused vs reference when candidates span episodes: the weighted EU
    objective must produce identical admitted sets and EU-at-admit through
    every admission path."""
    rng = np.random.default_rng(500 + seed)
    sc = scoring.Scorer(Machine())
    hyps, weights = _two_tenant_beam(rng, k)
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    ref = admission.greedy_admit(hyps, sc, slack, budget, auth, weights=weights)
    fus = admission.fused_admit(hyps, sc, slack, budget, auth, weights=weights)
    _assert_equivalent(ref, fus, hyps)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_shared_beam_numpy_path_matches_kernel(seed):
    rng = np.random.default_rng(600 + seed)
    sc = scoring.Scorer(Machine())
    hyps, weights = _two_tenant_beam(rng, 6)
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    via_np = admission.fused_admit(hyps, sc, slack, budget, auth,
                                   weights=weights,
                                   small_beam_threshold=len(hyps))
    via_krn = admission.fused_admit(hyps, sc, slack, budget, auth,
                                    weights=weights, small_beam_threshold=0)
    _assert_equivalent(via_np, via_krn, hyps)


def test_uniform_weights_change_nothing():
    """EU is linear in q: a uniform weight vector is a common positive
    factor and must admit exactly the unweighted set (single-tenant pools
    skip weighting entirely on this guarantee)."""
    rng = np.random.default_rng(7)
    sc = scoring.Scorer(Machine())
    hyps = _random_beam(rng, 8)
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    plain = admission.fused_admit(hyps, sc, slack, budget, auth)
    halves = admission.fused_admit(hyps, sc, slack, budget, auth,
                                   weights=np.full(len(hyps), 0.5))
    assert sorted(h.hid for h in plain.admitted) == sorted(
        h.hid for h in halves.admitted)
    for hid, val in plain.eu.items():
        np.testing.assert_allclose(halves.eu[hid], 0.5 * val, rtol=1e-4)


def test_fairness_weight_flips_starved_tenant_in():
    """Two equal candidates, room for one: unweighted, the higher-q tenant
    wins; with its share discounted below the other's, admission flips —
    the mechanism that stops one tenant monopolizing the shared beam."""
    sc = scoring.Scorer(Machine())
    rich = _mk_hyp(0, ["grep", "read"], q=0.8)     # tenant with spec share
    poor = _mk_hyp(1, ["grep", "read"], q=0.7)     # starved tenant
    slack = np.array([1.2, 10.0, 60.0, 1.0])       # one grep-prefix fits
    budget = slack.copy()
    plain = admission.fused_admit([rich, poor], sc, slack, budget, np.zeros(4))
    assert [h.hid for h in plain.admitted] == [0]
    weighted = admission.fused_admit(
        [rich, poor], sc, slack, budget, np.zeros(4),
        weights=np.array([0.5, 1.0]))
    assert [h.hid for h in weighted.admitted] == [1]


# ======================================================================
# Result-store reuse term (memo mask: memoized prefix nodes contribute EU
# at zero demand) — must thread identically through every admission path
# ======================================================================

def _random_memo(rng, hyps, n_max=12):
    """Random per-node memo masks over each hypothesis' safe prefix, plus
    the matching memo-excluded prefix demand (what the runtime computes)."""
    masks = np.zeros((len(hyps), n_max))
    rhos = np.zeros((len(hyps), RESOURCE_DIMS))
    for i, h in enumerate(hyps):
        excl = set()
        for n in h.safe_prefix():
            if n.idx < n_max and rng.random() < 0.5:
                masks[i, n.idx] = 1.0
                excl.add(n.idx)
        rhos[i] = scoring.prefix_rho(h, frozenset(excl))
    return masks, rhos


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
@pytest.mark.parametrize("k", [3, 6, 10])
def test_memo_mask_fused_matches_reference(seed, k):
    rng = np.random.default_rng(700 + seed)
    sc = scoring.Scorer(Machine())
    hyps = _random_beam(rng, k)
    masks, rhos = _random_memo(rng, hyps)
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    ref = admission.greedy_admit(hyps, sc, slack, budget, auth,
                                 memo_masks=masks, memo_rho=rhos)
    fus = admission.fused_admit(hyps, sc, slack, budget, auth,
                                memo_masks=masks, memo_rho=rhos)
    _assert_equivalent(ref, fus, hyps)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_memo_mask_numpy_path_matches_kernel(seed):
    rng = np.random.default_rng(800 + seed)
    sc = scoring.Scorer(Machine())
    hyps = _random_beam(rng, 6)
    masks, rhos = _random_memo(rng, hyps)
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    via_np = admission.fused_admit(hyps, sc, slack, budget, auth,
                                   memo_masks=masks, memo_rho=rhos,
                                   small_beam_threshold=len(hyps))
    via_krn = admission.fused_admit(hyps, sc, slack, budget, auth,
                                    memo_masks=masks, memo_rho=rhos,
                                    small_beam_threshold=0)
    _assert_equivalent(via_np, via_krn, hyps)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_memo_mask_tree_beam_with_weights(seed):
    """Memo + fairness weights together, on tree-shaped beams: the full
    shared-beam configuration the runtime actually runs."""
    rng = np.random.default_rng(900 + seed)
    sc = scoring.Scorer(Machine())
    hyps = [_mk_tree_hyp(h, rng) for h in range(6)]
    masks, rhos = _random_memo(rng, hyps)
    weights = np.array([1.0 if h % 2 else 0.7 for h in range(6)])
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    ref = admission.greedy_admit(hyps, sc, slack, budget, auth,
                                 weights=weights, memo_masks=masks,
                                 memo_rho=rhos)
    fus = admission.fused_admit(hyps, sc, slack, budget, auth,
                                weights=weights, memo_masks=masks,
                                memo_rho=rhos)
    _assert_equivalent(ref, fus, hyps)


def test_memo_zero_mask_changes_nothing():
    """An all-zero memo mask with the unmodified prefix ρ must reproduce the
    no-memo decisions exactly (the no-store path stays bit-identical)."""
    rng = np.random.default_rng(13)
    sc = scoring.Scorer(Machine())
    hyps = _random_beam(rng, 8)
    masks = np.zeros((8, 12))
    rhos = np.stack([scoring.prefix_rho(h) for h in hyps])
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    plain = admission.fused_admit(hyps, sc, slack, budget, auth)
    memo = admission.fused_admit(hyps, sc, slack, budget, auth,
                                 memo_masks=masks, memo_rho=rhos)
    assert sorted(h.hid for h in plain.admitted) == sorted(
        h.hid for h in memo.admitted)
    for hid, val in plain.eu.items():
        np.testing.assert_allclose(memo.eu[hid], val, rtol=1e-5)


def test_memo_mask_admits_zero_demand_branch_at_capacity():
    """A fully-memoized prefix demands nothing: it must be admitted even
    when the limit is exhausted — the reuse term's whole point."""
    sc = scoring.Scorer(Machine())
    h = _mk_hyp(0, ["grep", "read"], q=0.8)
    masks = np.zeros((1, 12))
    for n in h.safe_prefix():
        masks[0, n.idx] = 1.0
    rhos = np.zeros((1, RESOURCE_DIMS))
    tight = np.array([1e-6, 1e-6, 1e-6, 1e-6])     # nothing fits
    none = admission.fused_admit([h], sc, tight, tight, np.zeros(4))
    assert none.admitted == []
    served = admission.fused_admit([h], sc, tight, tight, np.zeros(4),
                                   memo_masks=masks, memo_rho=rhos)
    assert [x.hid for x in served.admitted] == [0]
    ref = admission.greedy_admit([h], sc, tight, tight, np.zeros(4),
                                 memo_masks=masks, memo_rho=rhos)
    assert [x.hid for x in ref.admitted] == [0]


# ======================================================================
# Wide-beam truncation regression (k_max silently dropped hypotheses)
# ======================================================================

def test_wide_beam_scores_every_hypothesis():
    """score_all must return a real EU for all 12 hypotheses (the padded
    score() tables only hold k_max=8 rows)."""
    sc = scoring.Scorer(Machine())
    hyps = [_mk_hyp(i, ["grep", "read"], q=0.5) for i in range(12)]
    eu = sc.score_all(hyps, np.zeros(4), idle_window=8.0)
    assert eu.shape == (12,)
    assert np.all(eu > 0)


def test_wide_beam_best_hypothesis_beyond_kmax_is_admitted():
    """Regression: with 12 candidates and k_max=8, the clearly-best
    hypothesis sitting at index 11 used to rank on garbage/padded zeros and
    could never win a round.  Both paths must admit it."""
    sc = scoring.Scorer(Machine())
    hyps = [_mk_hyp(i, ["grep", "read"], q=0.1) for i in range(11)]
    hyps.append(_mk_hyp(11, ["grep", "read", "parse"], q=0.95))
    # tight limit: roughly two grep-class prefixes fit
    slack = np.array([2.3, 11.0, 120.0, 1.0])
    budget = slack.copy()
    ref = admission.greedy_admit(hyps, sc, slack, budget, np.zeros(4))
    fus = admission.fused_admit(hyps, sc, slack, budget, np.zeros(4))
    assert 11 in {h.hid for h in ref.admitted}
    assert 11 in {h.hid for h in fus.admitted}


def test_wide_beam_fused_matches_reference():
    """Beams wider than k_max are bucketed (padded), not dropped, and still
    match the reference greedy."""
    rng = np.random.default_rng(42)
    sc = scoring.Scorer(Machine())
    hyps = _random_beam(rng, 12)
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    ref = admission.greedy_admit(hyps, sc, slack, budget, np.zeros(4))
    fus = admission.fused_admit(hyps, sc, slack, budget, np.zeros(4))
    _assert_equivalent(ref, fus, hyps)


def test_bucket_k():
    assert admission.bucket_k(1, 8) == 8
    assert admission.bucket_k(8, 8) == 8
    assert admission.bucket_k(9, 8) == 16
    assert admission.bucket_k(12, 8) == 16
    # geometric above 2*k_max: the compiled-shape set stays log-bounded as
    # the pooled cross-episode beam width moves tick to tick
    assert admission.bucket_k(17, 8) == 32
    assert admission.bucket_k(32, 8) == 32
    assert admission.bucket_k(33, 8) == 64
    assert admission.bucket_k(100, 8) == 128
    # every bucket still holds its beam
    for n in range(1, 200):
        assert admission.bucket_k(n, 8) >= n


# ======================================================================
# Tree-shaped beams (branching subgraphs; prefix = per-branch frontier)
# ======================================================================

def _mk_tree_hyp(hid, rng, q=None):
    """Random bounded tree: each tool node gets 0-2 children, probability
    mass split across siblings, terminal MODEL join behind the leaves."""
    from repro.core.events import ResourceVector
    q = float(rng.uniform(0.2, 0.95)) if q is None else q
    nodes, edges = [], []
    idx = 0

    def emit(parent, cond, depth):
        nonlocal idx
        t = READ_TOOLS[int(rng.integers(0, len(READ_TOOLS)))]
        spec = DEFAULT_TOOLS[t]
        me = idx
        nodes.append(Node(me, NodeKind.TOOL, t, spec.level, spec.rho,
                          spec.base_latency, cond_prob=cond))
        if parent is not None:
            edges.append((parent, me))
        idx += 1
        leaves = []
        if depth < 3 and idx < 7:
            n_kids = int(rng.integers(0, 3))
            if n_kids:
                probs = rng.dirichlet(np.ones(n_kids)) * float(rng.uniform(0.6, 1.0))
                for p in probs:
                    leaves += emit(me, float(p), depth + 1)
        return leaves or [me]

    leaves = emit(None, 1.0, 1)
    m = DEFAULT_TOOLS["model_step"]
    nodes.append(Node(idx, NodeKind.MODEL, "model_step", m.level, m.rho,
                      m.base_latency))
    for leaf in leaves:
        edges.append((leaf, idx))
    return BranchHypothesis(hid, nodes, edges, q, context_key=("x",))


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
@pytest.mark.parametrize("k", [3, 6, 10])
def test_tree_beam_fused_matches_reference(seed, k):
    """Fused vs reference on tree-shaped beams: identical admitted sets and
    EU-at-admit — the frontier prefix mask and the DAG critical path must
    agree across every admission path."""
    rng = np.random.default_rng(300 + seed)
    sc = scoring.Scorer(Machine())
    hyps = [_mk_tree_hyp(h, rng) for h in range(k)]
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    ref = admission.greedy_admit(hyps, sc, slack, budget, auth)
    fus = admission.fused_admit(hyps, sc, slack, budget, auth)
    _assert_equivalent(ref, fus, hyps)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_tree_beam_numpy_path_matches_kernel(seed):
    rng = np.random.default_rng(400 + seed)
    sc = scoring.Scorer(Machine())
    hyps = [_mk_tree_hyp(h, rng) for h in range(5)]
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    via_np = admission.fused_admit(hyps, sc, slack, budget, auth,
                                   small_beam_threshold=len(hyps))
    via_krn = admission.fused_admit(hyps, sc, slack, budget, auth,
                                    small_beam_threshold=0)
    _assert_equivalent(via_np, via_krn, hyps)


def test_prefix_rho_serial_through_barrier_is_max_not_sum():
    """BARRIER nodes are prefix-transparent: a serial read->BARRIER->edit
    path is one chain, so its demand is the element-wise max — summing the
    post-barrier subtree as a disconnected root overstated every
    staged-write branch's rho."""
    from repro.core.events import ResourceVector, SafetyLevel
    r, e = DEFAULT_TOOLS["read"], DEFAULT_TOOLS["edit"]
    nodes = [Node(0, NodeKind.TOOL, "read", r.level, r.rho, 0.8),
             Node(1, NodeKind.BARRIER, "barrier", SafetyLevel.READ_ONLY,
                  ResourceVector(), 0.0),
             Node(2, NodeKind.TOOL, "edit", e.level, e.rho, 1.2)]
    h = BranchHypothesis(0, nodes, [(0, 1), (1, 2)], 0.9, ("x",))
    got = scoring.prefix_rho(h)
    np.testing.assert_allclose(
        got, np.maximum(r.rho.as_array(), e.rho.as_array()))


def test_prefix_rho_sums_concurrent_siblings():
    """Sibling branches of a tree prefix can run concurrently: their conc
    demand sums under the branch point (chains still reduce to the max)."""
    g = DEFAULT_TOOLS["grep"]
    nodes = [Node(0, NodeKind.TOOL, "grep", g.level, g.rho, 1.5),
             Node(1, NodeKind.TOOL, "read", DEFAULT_TOOLS["read"].level,
                  DEFAULT_TOOLS["read"].rho, 0.8),
             Node(2, NodeKind.TOOL, "parse", DEFAULT_TOOLS["parse"].level,
                  DEFAULT_TOOLS["parse"].rho, 2.0)]
    h = BranchHypothesis(0, nodes, [(0, 1), (0, 2)], 0.9, ("x",))
    got = scoring.prefix_rho(h)
    sibs = DEFAULT_TOOLS["read"].rho.as_array() + DEFAULT_TOOLS["parse"].rho.as_array()
    np.testing.assert_allclose(got, np.maximum(g.rho.as_array(), sibs))


def test_tree_prefix_mask_matches_safe_prefix():
    """pack_beam's prefix mask must be exactly the frontier safe_prefix of
    each tree (branch-blocked subtrees excluded, siblings kept)."""
    rng = np.random.default_rng(7)
    hyps = [_mk_tree_hyp(h, rng) for h in range(4)]
    pb = scoring.pack_beam(hyps, 4, 12)
    for kk, h in enumerate(hyps):
        want = {n.idx for n in h.safe_prefix()}
        got = {i for i in range(12) if pb.prefix_mask[kk, i] > 0}
        assert got == want

# ======================================================================
# Model-step queue-delay term (ΔU discount from the batched model service)
# ======================================================================

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("k", [3, 6])
def test_model_delay_fused_matches_reference(seed, k):
    """The ΔU queue-delay discount threads identically through the fused
    kernel and the reference greedy."""
    rng = np.random.default_rng(500 + seed)
    sc = scoring.Scorer(Machine())
    hyps = [_mk_tree_hyp(h, rng) for h in range(k)]
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    delay = float(rng.uniform(0.5, 4.0))
    ref = admission.greedy_admit(hyps, sc, slack, budget, auth,
                                 model_delay=delay)
    fus = admission.fused_admit(hyps, sc, slack, budget, auth,
                                model_delay=delay)
    _assert_equivalent(ref, fus, hyps)


@pytest.mark.parametrize("seed", [0, 1])
def test_model_delay_numpy_path_matches_kernel(seed):
    rng = np.random.default_rng(600 + seed)
    sc = scoring.Scorer(Machine())
    hyps = [_mk_tree_hyp(h, rng) for h in range(5)]
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    via_np = admission.fused_admit(hyps, sc, slack, budget, auth,
                                   model_delay=2.0,
                                   small_beam_threshold=len(hyps))
    via_krn = admission.fused_admit(hyps, sc, slack, budget, auth,
                                    model_delay=2.0, small_beam_threshold=0)
    _assert_equivalent(via_np, via_krn, hyps)


def test_model_delay_discounts_delta_u_monotonically():
    """A growing batch-window delay strictly shrinks ΔU down to zero and
    never touches ΔO; zero delay is bit-identical to the no-delay call."""
    sc = scoring.Scorer(Machine())
    # a tree hypothesis carries a post-prefix MODEL join, so delta_u > 0
    rng = np.random.default_rng(3)
    ht = _mk_tree_hyp(1, rng, q=0.8)
    base, _, d0 = sc.score([ht], np.zeros(4), idle_window=8.0)
    plain, _, _ = sc.score([ht], np.zeros(4), idle_window=8.0,
                           model_delay=0.0)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(plain))
    prev_du = d0["delta_u"][0]
    assert prev_du > 0
    for delay in (0.5, 1.5, 4.0, 1e3):
        _, _, d = sc.score([ht], np.zeros(4), idle_window=8.0,
                           model_delay=delay)
        assert d["delta_u"][0] <= prev_du + 1e-6
        np.testing.assert_allclose(d["delta_o"][0], d0["delta_o"][0],
                                   rtol=1e-6)
        prev_du = d["delta_u"][0]
    assert prev_du == 0.0                    # huge delay exhausts the unlock


# ======================================================================
# Slot-marginal spec-step cost (ΔO discount for drafted reasoning steps)
# ======================================================================

def _spec_costs_for(hyps, rng):
    """Per-branch slot-marginal cost: positive only where the hypothesis
    carries a MODEL join (mirrors the runtime, which charges branches
    whose reasoning boundary would claim the contended last batch slot)."""
    has_model = np.array([any(n.kind == NodeKind.MODEL for n in h.nodes)
                          for h in hyps])
    return np.where(has_model, rng.uniform(0.5, 3.0, len(hyps)), 0.0)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("k", [3, 6])
def test_spec_costs_fused_matches_reference(seed, k):
    """The slot-marginal ΔO discount threads identically through the fused
    kernel and the reference greedy."""
    rng = np.random.default_rng(700 + seed)
    sc = scoring.Scorer(Machine())
    hyps = [_mk_tree_hyp(h, rng) for h in range(k)]
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    costs = _spec_costs_for(hyps, rng)
    ref = admission.greedy_admit(hyps, sc, slack, budget, auth,
                                 spec_costs=costs)
    fus = admission.fused_admit(hyps, sc, slack, budget, auth,
                                spec_costs=costs)
    _assert_equivalent(ref, fus, hyps)


@pytest.mark.parametrize("seed", [0, 1])
def test_spec_costs_numpy_path_matches_kernel(seed):
    rng = np.random.default_rng(800 + seed)
    sc = scoring.Scorer(Machine())
    hyps = [_mk_tree_hyp(h, rng) for h in range(5)]
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    costs = _spec_costs_for(hyps, rng)
    via_np = admission.fused_admit(hyps, sc, slack, budget, auth,
                                   spec_costs=costs,
                                   small_beam_threshold=len(hyps))
    via_krn = admission.fused_admit(hyps, sc, slack, budget, auth,
                                    spec_costs=costs,
                                    small_beam_threshold=0)
    _assert_equivalent(via_np, via_krn, hyps)


@pytest.mark.parametrize("seed", [0, 1])
def test_spec_costs_compose_with_model_delay(seed):
    """Both per-branch discounts active at once — the ΔU queue-delay term
    and the ΔO slot-marginal term must not interfere across paths."""
    rng = np.random.default_rng(900 + seed)
    sc = scoring.Scorer(Machine())
    hyps = [_mk_tree_hyp(h, rng) for h in range(6)]
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    costs = _spec_costs_for(hyps, rng)
    ref = admission.greedy_admit(hyps, sc, slack, budget, auth,
                                 model_delay=1.7, spec_costs=costs)
    fus = admission.fused_admit(hyps, sc, slack, budget, auth,
                                model_delay=1.7, spec_costs=costs)
    _assert_equivalent(ref, fus, hyps)


def test_spec_costs_discount_delta_o_only():
    """A growing slot-marginal cost strictly shrinks ΔO (through the EU)
    and never touches ΔU; an explicit zero-cost vector is bit-identical
    to the no-cost call (the runtime's None fast path relies on it)."""
    sc = scoring.Scorer(Machine())
    rng = np.random.default_rng(5)
    ht = _mk_tree_hyp(1, rng, q=0.8)
    base, _, d0 = sc.score([ht], np.zeros(4), idle_window=8.0)
    zero, _, dz = sc.score([ht], np.zeros(4), idle_window=8.0,
                           spec_costs=np.zeros(1))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(zero))
    np.testing.assert_array_equal(np.asarray(d0["delta_o"]),
                                  np.asarray(dz["delta_o"]))
    prev_eu = float(np.asarray(base)[0])
    for cost in (0.5, 1.5, 4.0):
        eu, _, d = sc.score([ht], np.zeros(4), idle_window=8.0,
                            spec_costs=np.array([cost]))
        assert float(np.asarray(eu)[0]) < prev_eu
        np.testing.assert_allclose(d["delta_u"][0], d0["delta_u"][0],
                                   rtol=1e-6)
        prev_eu = float(np.asarray(eu)[0])


def test_spec_costs_change_admission_signature():
    """The warm-start signature must distinguish spec-cost vectors — a
    slot freeing up between ticks changes the discount, so replaying the
    previous admitted set would be stale."""
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = np.zeros(RESOURCE_DIMS)
    w = np.ones(2)
    base = admission.admission_signature(
        (1, 2), slack, budget, auth, w, None, None, 0.0)
    with_costs = admission.admission_signature(
        (1, 2), slack, budget, auth, w, None, None, 0.0,
        spec_costs=np.array([1.0, 0.0]))
    assert base != with_costs


# ======================================================================
# Load-shed penalty (backlog-proportional ΔO tax under open-loop load)
# ======================================================================

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("k", [3, 6])
def test_shed_penalty_fused_matches_reference(seed, k):
    """The backlog shed tax threads identically through the fused kernel
    and the reference greedy."""
    rng = np.random.default_rng(1000 + seed)
    sc = scoring.Scorer(Machine())
    hyps = [_mk_tree_hyp(h, rng) for h in range(k)]
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    shed = float(rng.uniform(0.2, 3.0))
    ref = admission.greedy_admit(hyps, sc, slack, budget, auth,
                                 shed_penalty=shed)
    fus = admission.fused_admit(hyps, sc, slack, budget, auth,
                                shed_penalty=shed)
    _assert_equivalent(ref, fus, hyps)


@pytest.mark.parametrize("seed", [0, 1])
def test_shed_penalty_numpy_path_matches_kernel(seed):
    rng = np.random.default_rng(1100 + seed)
    sc = scoring.Scorer(Machine())
    hyps = [_mk_tree_hyp(h, rng) for h in range(5)]
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    via_np = admission.fused_admit(hyps, sc, slack, budget, auth,
                                   shed_penalty=1.3,
                                   small_beam_threshold=len(hyps))
    via_krn = admission.fused_admit(hyps, sc, slack, budget, auth,
                                    shed_penalty=1.3,
                                    small_beam_threshold=0)
    _assert_equivalent(via_np, via_krn, hyps)


@pytest.mark.parametrize("seed", [0, 1])
def test_shed_penalty_composes_with_other_per_tick_terms(seed):
    """All three per-tick terms at once — queue delay (ΔU), slot-marginal
    spec cost (ΔO, per branch) and the shed tax (ΔO, uniform) — must not
    interfere across the reference and fused paths."""
    rng = np.random.default_rng(1200 + seed)
    sc = scoring.Scorer(Machine())
    hyps = [_mk_tree_hyp(h, rng) for h in range(6)]
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = rng.uniform(0.0, 2.0, RESOURCE_DIMS)
    costs = _spec_costs_for(hyps, rng)
    ref = admission.greedy_admit(hyps, sc, slack, budget, auth,
                                 model_delay=1.7, spec_costs=costs,
                                 shed_penalty=0.9)
    fus = admission.fused_admit(hyps, sc, slack, budget, auth,
                                model_delay=1.7, spec_costs=costs,
                                shed_penalty=0.9)
    _assert_equivalent(ref, fus, hyps)


def test_shed_penalty_discounts_delta_o_only():
    """A growing shed tax strictly shrinks the EU (through ΔO) and never
    touches ΔU; an explicit zero tax is bit-identical to the no-tax call
    (the runtime's zero-backlog fast path relies on it)."""
    sc = scoring.Scorer(Machine())
    rng = np.random.default_rng(6)
    ht = _mk_tree_hyp(1, rng, q=0.8)
    base, _, d0 = sc.score([ht], np.zeros(4), idle_window=8.0)
    zero, _, dz = sc.score([ht], np.zeros(4), idle_window=8.0,
                           shed_penalty=0.0)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(zero))
    np.testing.assert_array_equal(np.asarray(d0["delta_o"]),
                                  np.asarray(dz["delta_o"]))
    prev_eu = float(np.asarray(base)[0])
    for shed in (0.5, 1.5, 4.0):
        eu, _, d = sc.score([ht], np.zeros(4), idle_window=8.0,
                            shed_penalty=shed)
        assert float(np.asarray(eu)[0]) < prev_eu
        np.testing.assert_allclose(d["delta_u"][0], d0["delta_u"][0],
                                   rtol=1e-6)
        prev_eu = float(np.asarray(eu)[0])


def test_shed_penalty_changes_admission_signature():
    """The warm-start signature must distinguish shed levels — the
    backlog moves between ticks, so replaying an admitted set computed
    under a different tax would be stale."""
    slack = np.array([5.7, 41.0, 180.0, 1.0])
    budget = np.array([4.3, 33.0, 150.0, 1.0])
    auth = np.zeros(RESOURCE_DIMS)
    w = np.ones(2)
    base = admission.admission_signature(
        (1, 2), slack, budget, auth, w, None, None, 0.0)
    with_shed = admission.admission_signature(
        (1, 2), slack, budget, auth, w, None, None, 0.0,
        shed_penalty=0.7)
    assert base != with_shed
