"""Batched model-step service (model_service.py): batch-window edge cases,
``max_batch=1`` bit-identity against the pre-service runtime, queue-delay
QoS attribution, and the batched edge-regime separation."""
import numpy as np
import pytest

from repro.core.events import DEFAULT_TOOLS, ResourceVector
from repro.core.interference import (
    Machine, batch_efficiency, batched_step_latency,
)
from repro.core.model_service import ModelStepRequest, ModelStepService
from repro.core.patterns import PatternEngine
from repro.core.runtime import Metrics, run_mode
from repro.core.simulator import Simulator
from repro.core.workload import (
    WorkloadConfig, episodes_to_traces, make_episodes,
)

MODEL_RHO = DEFAULT_TOOLS["model_step"].rho.as_array()
THOR = Machine()                            # accel=1 edge box
SERVE = Machine(ResourceVector(cpu=12, mem_bw=100, io=500, accel=4))


# ----------------------------------------------------------------------
# batch latency model (interference.batched_step_latency)
# ----------------------------------------------------------------------
def test_singleton_batch_latency_is_exact():
    """b=1 must cost exactly the solo work — the property that keeps
    max_batch=1 bit-identical to the pre-service runtime."""
    assert batched_step_latency([2.5]) == 2.5
    assert batched_step_latency([0.7], marginal=0.9) == 0.7


def test_batch_latency_sublinear_but_not_free():
    works = [2.0, 3.0, 2.5, 1.5]
    lat = batched_step_latency(works, marginal=0.3)
    assert lat < sum(works)                 # strictly beats the serial queue
    assert lat > max(works)                 # but is not free
    np.testing.assert_allclose(lat, 3.0 + 0.3 * 6.0)


def test_batch_latency_monotone_in_members():
    base = batched_step_latency([2.0, 2.0], marginal=0.3)
    assert batched_step_latency([2.0, 2.0, 2.0], marginal=0.3) > base
    assert batched_step_latency([2.0, 4.0], marginal=0.3) > base
    assert batched_step_latency([], marginal=0.3) == 0.0


def test_batch_efficiency_curve():
    assert batch_efficiency(1) == 1.0
    # per-step cost falls toward the marginal fraction as b grows
    assert batch_efficiency(8, 0.3) < batch_efficiency(2, 0.3) < 1.0
    np.testing.assert_allclose(batch_efficiency(8, 0.3), (1 + 0.3 * 7) / 8)


# ----------------------------------------------------------------------
# batch-window mechanics (service driven directly on a bare simulator)
# ----------------------------------------------------------------------
def _bare_service(**kw):
    sim = Simulator(THOR, lambda s: None)
    m = Metrics()
    svc = ModelStepService(sim, MODEL_RHO, metrics=m, **kw)
    return sim, svc, m


def test_linger_expiry_with_single_request():
    """A lone request must not wait forever: the linger window expires and
    dispatches a singleton batch, completing at linger + work."""
    sim, svc, m = _bare_service(max_batch=4, linger=1.0)
    fired = []
    svc.submit(ModelStepRequest(0, "model[e0.0]", 2.5,
                                lambda s, j: fired.append(s.now)))
    assert svc.forming_size == 1
    sim.run()
    assert fired and np.isclose(fired[0], 1.0 + 2.5)
    assert m.model_batches == 1 and m.model_solo_steps == 1
    assert m.model_batch_occupancy_samples == [1]
    np.testing.assert_allclose(m.tenant_model_queue_delay[0], 1.0)


def test_batch_forms_across_tenants():
    """Two tenants' steps inside one linger window coalesce into ONE
    simulator job tagged with both eids, and both continuations fire."""
    sim, svc, m = _bare_service(max_batch=4, linger=2.0)
    fired = {}
    svc.submit(ModelStepRequest(0, "model[e0.0]", 2.0,
                                lambda s, j, e=0: fired.setdefault(e, s.now)))
    svc.submit(ModelStepRequest(1, "model[e1.0]", 3.0,
                                lambda s, j, e=1: fired.setdefault(e, s.now)))
    sim.run()
    assert set(fired) == {0, 1}
    assert m.model_batches == 1 and m.model_batched_steps == 2
    assert m.model_batch_occupancy_samples == [2]
    # ONE batch job (plus the linger timer) ran; it carried both eids
    batch_log = [r for r in sim.log if r[1] == "finish"
                 and r[2].startswith("model_batch[")]
    assert len(batch_log) == 1
    done_t = 2.0 + batched_step_latency([2.0, 3.0], svc.marginal)
    np.testing.assert_allclose(fired[0], done_t)
    np.testing.assert_allclose(fired[1], done_t)


def test_full_batch_dispatches_before_linger_expiry():
    """Reaching max_batch cancels the linger timer and dispatches NOW — a
    full batch must not keep paying the admission window."""
    sim, svc, m = _bare_service(max_batch=2, linger=50.0)
    fired = []
    svc.submit(ModelStepRequest(0, "model[e0.0]", 2.0,
                                lambda s, j: fired.append(s.now)))
    svc.submit(ModelStepRequest(1, "model[e1.0]", 2.0,
                                lambda s, j: fired.append(s.now)))
    assert svc.forming_size == 0            # dispatched on fill
    sim.run()
    assert fired and fired[0] < 50.0        # did NOT wait out the linger
    np.testing.assert_allclose(
        fired[0], batched_step_latency([2.0, 2.0], svc.marginal))
    # the cancelled timer is logged as "cancel", never as "preempt"
    assert any(r[1] == "cancel" for r in sim.log)
    assert not any(r[1] == "preempt" for r in sim.log)


def test_non_batchable_request_dispatches_solo():
    """Step.batchable=False pins the step to a solo dispatch even while a
    batch is forming (latency-critical steps skip the admission window)."""
    sim, svc, m = _bare_service(max_batch=4, linger=5.0)
    fired = {}
    svc.submit(ModelStepRequest(0, "model[e0.0]", 2.0,
                                lambda s, j, e=0: fired.setdefault(e, s.now)))
    svc.submit(ModelStepRequest(1, "model[e1.0]", 2.0,
                                lambda s, j, e=1: fired.setdefault(e, s.now),
                                batchable=False))
    assert svc.forming_size == 1            # the solo one bypassed the queue
    sim.run()
    # the non-batchable step never waited: zero queue delay attributed
    assert 1 not in m.tenant_model_queue_delay
    assert m.model_solo_steps == 2          # solo dispatch + expired singleton


def test_queue_delay_attributed_to_the_tenant_that_waited():
    """The window-opening tenant pays (nearly) the whole linger; a late
    joiner pays only the remainder — per-tenant, never pooled."""
    sim, svc, m = _bare_service(max_batch=4, linger=3.0)
    svc.submit(ModelStepRequest(7, "model[e7.0]", 2.0, lambda s, j: None))
    # advance the clock 1s with an unrelated job, then tenant 9 joins
    filler = sim.new_job("filler", np.zeros(4), 1.0, speculative=False)
    sim.start(filler)
    sim.step()
    assert sim.now == 1.0
    svc.submit(ModelStepRequest(9, "model[e9.0]", 2.0, lambda s, j: None))
    sim.run()
    np.testing.assert_allclose(m.tenant_model_queue_delay[7], 3.0)
    np.testing.assert_allclose(m.tenant_model_queue_delay[9], 2.0)
    np.testing.assert_allclose(m.model_queue_delay_seconds, 5.0)


def test_expected_unlock_delay():
    """0 under the pinned baseline; a full window when idle with batching
    on; the REMAINING window while a batch is forming."""
    sim0, svc0, _ = _bare_service(max_batch=1, linger=2.0)
    assert svc0.expected_unlock_delay() == 0.0
    sim, svc, _ = _bare_service(max_batch=4, linger=2.0)
    assert svc.expected_unlock_delay() == 2.0          # would open a window
    svc.submit(ModelStepRequest(0, "model[e0.0]", 2.0, lambda s, j: None))
    np.testing.assert_allclose(svc.expected_unlock_delay(), 2.0)
    filler = sim.new_job("filler", np.zeros(4), 0.5, speculative=False)
    sim.start(filler)
    sim.step()
    np.testing.assert_allclose(svc.expected_unlock_delay(), 1.5)


def test_service_rejects_bad_config():
    sim = Simulator(THOR, lambda s: None)
    with pytest.raises(ValueError):
        ModelStepService(sim, MODEL_RHO, max_batch=0)
    with pytest.raises(ValueError):
        ModelStepService(sim, MODEL_RHO, linger=-1.0)


# ----------------------------------------------------------------------
# runtime integration: bit-identity and the edge-regime separation
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def serving_setup():
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=20))
    engine = PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))
    test = make_episodes(WorkloadConfig(seed=42, n_episodes=8,
                                        arrival_stagger=4.0,
                                        shared_frac=0.5, shared_pool=2))
    return engine, test


# summaries of the PRE-SERVICE runtime (captured at PR-4 HEAD d7ac806 on
# exactly the serving_setup configuration): model_max_batch=1 must
# reproduce them bit-for-bit — the service's solo fast path is a
# synchronous pass-through, so ANY drift here is a regression
_PINNED = {
    ("serial", False, 8, "thor"): {
        "makespan": 158.642488348, "mean_latency": 124.4674555425,
        "p95_sojourn": 149.2761862243, "worst_tenant_latency": 154.5503378327,
        "promotions": 0, "reuses": 0, "memo_serves": 0,
    },
    ("bpaste", True, 8, "thor"): {
        "makespan": 148.6440524884, "mean_latency": 115.6193011231,
        "p95_sojourn": 141.1002291033, "promotions": 2, "reuses": 28,
        "prefix_reuses": 34, "memo_serves": 5, "memo_hits": 39,
        "memo_dedups": 10, "spec_solo_seconds": 149.1987885892,
        "wasted_frac": 0.4491430528, "beam_occupancy": 21.5887850467,
    },
    ("bpaste", True, 8, "serve"): {
        "makespan": 49.9548251308, "mean_latency": 34.217733166,
        "p95_sojourn": 43.9043222271, "promotions": 10, "reuses": 15,
        "prefix_reuses": 30, "memo_serves": 4, "memo_hits": 42,
        "memo_dedups": 21, "spec_solo_seconds": 142.2316664026,
        "wasted_frac": 0.4757485715,
    },
    ("serial", False, 1, "serve"): {
        "makespan": 336.2090035222, "p95_sojourn": 310.2519340599,
        "worst_tenant_sojourn": 323.0032584244,
    },
}


@pytest.mark.parametrize("mode,memo,conc,box", list(_PINNED))
def test_max_batch_one_bit_identical_to_pre_service_runtime(
        serving_setup, mode, memo, conc, box):
    engine, test = serving_setup
    machine = THOR if box == "thor" else SERVE
    m = run_mode(test, engine, mode, machine, seed=7,
                 max_concurrent_episodes=conc, memo=memo, model_max_batch=1)
    s = m.summary()
    for key, want in _PINNED[(mode, memo, conc, box)].items():
        np.testing.assert_allclose(s[key], want, rtol=1e-8, err_msg=key)
    # and the service never batched, lingered, or delayed anything
    assert s["model_batches"] == s["model_solo_steps"]
    assert s["model_batched_steps"] == 0
    assert s["model_queue_delay_seconds"] == 0.0


def test_batching_separates_the_edge_regime(serving_setup):
    """The acceptance headline at test scale: on the accel=1 Thor box at
    c=8 — where PR 3/4 measured every mode converged on the model-step
    floor — batching the model-step queue separates the modes again:
    bpaste+memo+batch beats serial (and serial+batch) on makespan while
    holding the authoritative-protection invariant."""
    engine, test = serving_setup
    serial = run_mode(test, engine, "serial", THOR, seed=7,
                      max_concurrent_episodes=8).summary()
    serial_b = run_mode(test, engine, "serial", THOR, seed=7,
                        max_concurrent_episodes=8,
                        model_max_batch=8).summary()
    full = run_mode(test, engine, "bpaste", THOR, seed=7,
                    max_concurrent_episodes=8, memo=True,
                    model_max_batch=8).summary()
    assert full["makespan"] < serial["makespan"]
    assert full["makespan"] < serial_b["makespan"]
    assert full["mean_auth_slowdown"] <= 1.05
    assert full["qos_violations"] == 0
    assert full["worst_tenant_slowdown"] <= 1.05
    assert full["model_batched_steps"] > 0
    assert full["model_batch_occupancy"] > 1.0


def test_batch_queue_delay_attributed_per_tenant_in_runtime(serving_setup):
    """End-to-end QoS attribution: with batching on, the linger waits land
    in per-tenant buckets that sum to the pooled total."""
    engine, test = serving_setup
    m = run_mode(test, engine, "serial", THOR, seed=7,
                 max_concurrent_episodes=8, model_max_batch=8)
    assert m.model_queue_delay_seconds > 0
    np.testing.assert_allclose(
        sum(m.tenant_model_queue_delay.values()),
        m.model_queue_delay_seconds)
    # every delayed tenant is a real episode id
    eids = {ep.eid for ep in test}
    assert set(m.tenant_model_queue_delay) <= eids
    # per_tenant() surfaces the attribution
    pt = m.per_tenant()
    for eid, d in m.tenant_model_queue_delay.items():
        np.testing.assert_allclose(pt[eid]["model_queue_delay"], d)


def test_non_batchable_steps_dispatch_solo_in_runtime(serving_setup):
    """Workload batchable-step metadata reaches the service through the
    runtime: marking every step non-batchable disables coalescing even
    with batching configured on."""
    engine, test = serving_setup
    import copy
    pinned = copy.deepcopy(test)
    for ep in pinned:
        for st in ep.steps:
            st.batchable = False
    m = run_mode(pinned, engine, "serial", THOR, seed=7,
                 max_concurrent_episodes=8, model_max_batch=8)
    assert m.model_batched_steps == 0
    assert m.model_queue_delay_seconds == 0.0
    # and the run is identical to the unbatched baseline
    base = run_mode(test, engine, "serial", THOR, seed=7,
                    max_concurrent_episodes=8, model_max_batch=1)
    np.testing.assert_allclose(m.makespan, base.makespan, rtol=1e-12)
