"""Infrastructure tests: checkpoint, data pipeline, HLO analyzer, serving
engine, elastic restore (subprocess with a multi-device CPU mesh)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ck
from repro.configs import get_config
from repro.data.pipeline import DataConfig, batch_at
from repro.models import model as M


# ======================================================================
# checkpoint
# ======================================================================

def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
              "d": jnp.asarray(3, jnp.int32)},
    }
    ck.save(tree, str(tmp_path), 7)
    like = jax.eval_shape(lambda: tree)
    out = ck.restore(str(tmp_path), 7, like)
    assert out["b"]["c"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(
        np.asarray(out["b"]["c"], np.float32), np.asarray(tree["b"]["c"], np.float32))


def test_checkpoint_atomicity(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    ck.save(tree, str(tmp_path), 1)
    # a crashed write leaves only .tmp — must be ignored
    os.makedirs(tmp_path / "step_9.tmp")
    assert ck.latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    tree = {"x": jnp.arange(4.0)}
    ac = ck.AsyncCheckpointer(str(tmp_path))
    ac.save(tree, 3)
    ac.wait()
    assert ck.latest_step(str(tmp_path)) == 3


def test_elastic_restore_across_meshes(tmp_path):
    """Save on an 8-device (4,2) mesh, restore onto a (2,2) survivor mesh —
    the elastic re-mesh path after losing half the nodes."""
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.checkpoint import checkpoint as ck
        mesh8 = compat.make_mesh((4, 2), ("data", "model"))
        spec = {{"w": P(None, "model")}}
        w = jax.device_put(np.arange(32, dtype=np.float32).reshape(4, 8),
                           NamedSharding(mesh8, spec["w"]))
        ck.save({{"w": w}}, r"{tmp_path}", 1)
        # survivors: 4 devices
        devs = np.array(jax.devices()[:4]).reshape(2, 2)
        mesh4 = jax.sharding.Mesh(devs, ("data", "model"))
        like = jax.eval_shape(lambda: {{"w": w}})
        out = ck.restore(r"{tmp_path}", 1, like, mesh=mesh4, spec_tree=spec)
        assert out["w"].sharding.mesh.shape["model"] == 2
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
        print("ELASTIC_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(__file__)) or ".",
                       env=env, timeout=300)
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]


# ======================================================================
# data pipeline
# ======================================================================

def test_data_deterministic_and_resumable():
    cfg = get_config("granite-8b").reduced()
    dc = DataConfig(seed=5, seq_len=33, global_batch=4)
    b1 = batch_at(cfg, dc, 10)
    b2 = batch_at(cfg, dc, 10)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at(cfg, dc, 11)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_host_sharding_disjoint():
    cfg = get_config("granite-8b").reduced()
    a = batch_at(cfg, DataConfig(seed=5, seq_len=17, global_batch=8, n_hosts=2, host_index=0), 3)
    b = batch_at(cfg, DataConfig(seed=5, seq_len=17, global_batch=8, n_hosts=2, host_index=1), 3)
    assert a["tokens"].shape[0] == 4
    assert not np.array_equal(a["tokens"], b["tokens"])


# ======================================================================
# HLO analyzer
# ======================================================================

def test_hlo_analyzer_scales_scan_bodies():
    from repro.launch import hlo

    def one(x, w):
        return jnp.tanh(x @ w)

    def scanned(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w1 = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    wL = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
    f1 = hlo.analyze(jax.jit(one).lower(x, w1).compile().as_text())["flops"]
    fL = hlo.analyze(jax.jit(scanned).lower(x, wL).compile().as_text())["flops"]
    assert abs(fL / f1 - 12.0) < 0.2, (f1, fL)


def test_hlo_shape_bytes():
    from repro.launch.hlo import shape_bytes
    assert shape_bytes("f32[4,8]{1,0}") == 128
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(s32[], f32[2,2])") == 4 + 16


# ======================================================================
# serving engine
# ======================================================================

@pytest.fixture(scope="module")
def small_engine():
    from repro.serving.engine import ServingEngine
    cfg = get_config("musicgen-medium").reduced()
    params = M.init_params(jax.random.key(0), cfg)
    return cfg, params, ServingEngine(cfg, params, max_batch=4, max_len=64)


def test_engine_batched_matches_single(small_engine):
    """A batched engine slot must track a standalone prefill+decode loop.
    Teacher-forced (identical token stream fed to both) so the check probes
    CACHE correctness, not bf16 argmax tie-breaking."""
    cfg, params, eng = small_engine
    prompt = [5, 6, 7, 8]
    # standalone reference
    lg, cache = M.prefill(params, cfg, {"tokens": jnp.asarray([prompt], jnp.int32)}, max_len=64)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    forced = [int(tok[0])]
    toks_single = []
    for _ in range(6):
        lg, cache = M.decode_step(params, cfg, cache, tok)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        toks_single.append(int(tok[0]))
        forced.append(int(tok[0]))
    # batched, teacher-forced with the same stream
    slot = eng.add_request(prompt, request_id=1)
    toks_batched = []
    for i in range(6):
        eng.pending_tokens[slot] = forced[i]
        out = eng.step()
        toks_batched.append(out[slot])
    # allow isolated argmax ties under bf16: >=5 of 6 must agree exactly
    agree = sum(a == b for a, b in zip(toks_batched, toks_single, strict=True))
    assert agree >= 5, (toks_batched, toks_single)
    eng.slots[slot].active = False


def test_engine_speculative_promote_and_preempt(small_engine):
    from repro.serving.spec_serving import SlotSpeculator, render_observation
    cfg, params, eng = small_engine
    for s in eng.slots:
        s.active = False
    spec = SlotSpeculator(eng, budget_slots=2)
    from repro.core.hypothesis import BranchHypothesis, Node, NodeKind
    from repro.core.events import DEFAULT_TOOLS
    n = Node(0, NodeKind.TOOL, "search", DEFAULT_TOOLS["search"].level,
             DEFAULT_TOOLS["search"].rho, 1.0)
    h = BranchHypothesis(77, [n], [], q=0.9, context_key=())
    spec.admit([(h, 1.0)], history_prompt=[2, 3])
    assert spec.spec_slots_used() == 1
    obs = render_observation("search", {}, "pred:77:0", cfg.vocab_size)
    got = spec.match_and_promote(obs, request_id=5)
    assert got is not None
    assert not eng.slots[got].speculative
    # preemption path
    spec.admit([(h, 1.0)], history_prompt=[2, 3])
    spec.ensure_authoritative_room(len(eng.free_slots()) + 1)
    assert spec.spec_slots_used() == 0
