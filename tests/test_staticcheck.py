"""ISSUE 8: cache-coherence & trace-discipline static checker + the
verified admission warm-start it gates.

Three claims under test:

* **Clean tree** — the checker's four rule families (C1 mutation
  coverage, C2 trace discipline, C3 compat bypass, C4 dispatch shape)
  produce ZERO findings on the repo's own source, and the CLI strict
  gate exits 0 (this is what CI runs).
* **Every rule fires** — each family has deliberately broken fixtures
  that trigger exactly that rule id (no cross-talk), plus matched clean
  fixtures showing the idioms the rules accept, and the BASELINE
  mechanism routes known-good sites to ``meta`` instead of findings.
* **Warm-start equivalence** — ``RuntimeConfig.warm_admit`` replays the
  previous admission pass only behind a byte-exact signature, so the
  full metrics summary is bit-identical to ``warm_admit=False`` on
  pinned serving configs (TIMING_KEYS excepted), for both admission
  kernels, with the sanitizer on, and event ≡ its own dense-equivalence
  guarantees untouched.
"""
import json

import pytest

from repro.core.patterns import PatternEngine
from repro.core.runtime import BPasteRuntime, RuntimeConfig
from repro.core.workload import (
    WorkloadConfig, episodes_to_traces, make_episodes,
)
from repro.staticcheck import (
    BASELINE,
    MUTATION_RULES,
    check_source,
    check_tree,
    main as staticcheck_cli,
)

TIMING_KEYS = {"sched_us_per_admit", "sched_us_per_tick"}


@pytest.fixture(scope="module")
def engine():
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=20))
    return PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))


def _serving_rt(engine, **rcfg_kw):
    eps = make_episodes(WorkloadConfig(seed=42, n_episodes=8,
                                       arrival_stagger=2.0,
                                       shared_frac=0.5, shared_pool=2))
    rcfg = RuntimeConfig(seed=7, max_concurrent_episodes=4,
                         model_max_batch=4, **rcfg_kw)
    return BPasteRuntime(eps, engine, rcfg=rcfg)


def _rules(report):
    return [f.rule for f in report.findings]


# ======================================================================
# clean tree (the acceptance gate CI runs)
# ======================================================================

def test_tree_is_clean():
    report = check_tree()
    assert not report.findings, report.render()
    assert report.meta["files_checked"] > 30


def test_cli_strict_exits_zero(tmp_path, capsys):
    out = tmp_path / "report.json"
    assert staticcheck_cli(["--strict", "--json", str(out)]) == 0
    payload = json.loads(out.read_text())
    assert payload["findings"] == []
    assert "clean" in capsys.readouterr().out


def test_cli_reports_broken_tree(tmp_path):
    pkg = tmp_path / "core"
    pkg.mkdir()
    (pkg / "runtime.py").write_text(
        "class R:\n    def bad(self, es):\n        es.history = []\n")
    assert staticcheck_cli(["--root", str(tmp_path)]) == 1
    assert staticcheck_cli(["--root", str(tmp_path), "--strict"]) == 2


# ======================================================================
# C1: mutation coverage
# ======================================================================

def test_c1_unmarked_write_fires():
    src = (
        "class R:\n"
        "    def bad(self, es):\n"
        "        es.pending_action = None\n"
    )
    report = check_source(src, "core/runtime.py")
    assert _rules(report) == ["C1-mutation"]
    assert "pending_action" in report.findings[0].detail


def test_c1_marked_write_is_clean():
    src = (
        "class R:\n"
        "    def good(self, es):\n"
        "        es.pending_action = None\n"
        "        self._mark_dirty(es)\n"
    )
    assert not check_source(src, "core/runtime.py").findings


def test_c1_mutator_method_counts_as_write():
    src = (
        "class R:\n"
        "    def bad(self, es):\n"
        "        es.history.append(1)\n"
    )
    report = check_source(src, "core/runtime.py")
    assert _rules(report) == ["C1-mutation"]


def test_c1_one_branch_unmarked_fires():
    # invalidation on only one path: the else-branch write escapes
    src = (
        "class R:\n"
        "    def bad(self, es, flag):\n"
        "        es.phase = 1\n"
        "        if flag:\n"
        "            self._mark_dirty(es)\n"
    )
    report = check_source(src, "core/runtime.py")
    assert _rules(report) == ["C1-mutation"]


def test_c1_early_return_path_checked():
    src = (
        "class R:\n"
        "    def bad(self, es, flag):\n"
        "        es.phase = 1\n"
        "        if flag:\n"
        "            return\n"          # escapes without the mark
        "        self._mark_dirty(es)\n"
    )
    report = check_source(src, "core/runtime.py")
    assert _rules(report) == ["C1-mutation"]


def test_c1_init_exempt():
    src = (
        "class R:\n"
        "    def __init__(self):\n"
        "        self.history = []\n"
    )
    assert not check_source(src, "core/runtime.py").findings


def test_c1_pair_group_partial_update_fires():
    # noderun-pairs: touching one of a paired cache/epoch duo without the
    # other is exactly the stale-read bug the rule exists for
    src = (
        "class NR:\n"
        "    def bad(self, nr):\n"
        "        nr.args_cache = {}\n"
    )
    report = check_source(src, "core/runtime.py")
    assert "C1-mutation" in _rules(report)
    assert any("args_epoch" in f.detail for f in report.findings)


def test_c1_pair_group_full_update_is_clean():
    src = (
        "class NR:\n"
        "    def good(self, nr):\n"
        "        nr.args_cache = {}\n"
        "        nr.args_epoch = -1\n"
    )
    assert not check_source(src, "core/runtime.py").findings


def test_c1_ban_rule_exempt_site_only():
    src = (
        "class Simulator:\n"
        "    def set_speculative(self, job):\n"
        "        job.speculative = True\n"
        "    def other(self, job):\n"
        "        job.speculative = True\n"
    )
    report = check_source(src, "core/simulator.py")
    assert _rules(report) == ["C1-mutation"]
    assert "Simulator.other" in report.findings[0].site


def test_c1_baseline_routes_to_meta_not_findings():
    # a known-justified site lands in meta["baselined"], not findings
    src = (
        "class BPasteRuntime:\n"
        "    def _launch_frontier(self, nr):\n"
        "        nr.status = 'reused'\n"
    )
    report = check_source(src, "core/runtime.py")
    assert not report.findings
    hits = report.meta["baselined"]
    assert len(hits) == 1 and hits[0]["rule"] == "C1-mutation"
    assert ("C1-mutation",
            "core/runtime.py:BPasteRuntime._launch_frontier") in BASELINE


def test_c1_registry_covers_runtime_and_stores():
    mods = {m for r in MUTATION_RULES for m in r.modules}
    assert {"core/runtime.py", "core/simulator.py",
            "core/memo.py", "core/executor.py"} <= mods


# ======================================================================
# C2: trace discipline
# ======================================================================

def test_c2_branch_on_traced_value_fires():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x > 0:\n"
        "        return x\n"
        "    return -x\n"
    )
    report = check_source(src, "core/scoring.py")
    assert _rules(report) == ["C2-trace"]


def test_c2_float_cast_in_lax_body_fires():
    src = (
        "import jax\n"
        "def step(carry, x):\n"
        "    return carry + float(x), None\n"
        "def outer(xs):\n"
        "    return jax.lax.scan(step, 0.0, xs)\n"
    )
    report = check_source(src, "core/scoring.py")
    assert _rules(report) == ["C2-trace"]


def test_c2_static_argnames_not_tainted():
    src = (
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnames=('n',))\n"
        "def f(x, n):\n"
        "    if n > 2:\n"
        "        return x * n\n"
        "    return x\n"
    )
    assert not check_source(src, "core/scoring.py").findings


def test_c2_shape_access_launders_taint():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x.shape[0] > 2:\n"
        "        return x[:2]\n"
        "    return x\n"
    )
    assert not check_source(src, "core/scoring.py").findings


def test_c2_pallas_kwonly_params_are_static():
    # keyword-only kernel params are functools.partial-bound config, not
    # traced refs — the decode-attention window/partials idiom
    src = (
        "import functools\n"
        "from jax.experimental import pallas as pl\n"
        "def _kernel(x_ref, o_ref, *, window):\n"
        "    if window is not None:\n"
        "        o_ref[...] = x_ref[...]\n"
        "def run(x):\n"
        "    return pl.pallas_call(functools.partial(_kernel, window=3))(x)\n"
    )
    assert not check_source(src, "core/kernels/k.py").findings


def test_c2_host_tree_map_not_traced():
    # jax.tree.map is a host-side pytree walk, not a lax loop body
    src = (
        "import jax\n"
        "def f(specs):\n"
        "    def zero(s):\n"
        "        if s is None:\n"
        "            return 0\n"
        "        return s\n"
        "    return jax.tree.map(zero, specs)\n"
    )
    assert not check_source(src, "launch/shardings.py").findings


# ======================================================================
# C3: compat bypass
# ======================================================================

def test_c3_direct_shard_map_import_fires():
    src = "from jax.experimental.shard_map import shard_map\n"
    report = check_source(src, "core/runtime.py")
    assert _rules(report) == ["C3-compat"]


def test_c3_direct_compiler_params_fires():
    src = (
        "from jax.experimental import pallas as pl\n"
        "import jax.experimental.pallas.tpu as pltpu\n"
        "def f(k, x):\n"
        "    return pl.pallas_call(\n"
        "        k, compiler_params=pltpu.TPUCompilerParams())(x)\n"
    )
    report = check_source(src, "kernels/bad.py")
    assert "C3-compat" in _rules(report)


def test_c3_compat_module_itself_exempt():
    src = "from jax.experimental.shard_map import shard_map\n"
    assert not check_source(src, "compat.py").findings


# ======================================================================
# C4: dispatch shape
# ======================================================================

def test_c4_unbucketed_pack_fires():
    src = (
        "def repack(hyps, n_max):\n"
        "    return pack_beam(hyps, len(hyps), n_max)\n"
    )
    report = check_source(src, "core/admission.py")
    assert _rules(report) == ["C4-dispatch"]


def test_c4_bucketed_pack_is_clean():
    src = (
        "def repack(hyps, k_max, n_max):\n"
        "    return pack_beam(hyps, bucket_k(len(hyps), k_max), n_max)\n"
    )
    assert not check_source(src, "core/admission.py").findings


def test_c4_kernel_call_outside_wrapper_fires():
    src = (
        "def sneaky(packed):\n"
        "    return admit_beam(packed.node_lat, n_nodes=8)\n"
    )
    report = check_source(src, "core/runtime.py")
    assert _rules(report) == ["C4-dispatch"]


def test_c4_kernel_call_in_wrapper_is_clean():
    src = (
        "def fused_admit(packed):\n"
        "    return admit_beam(packed.node_lat, n_nodes=8)\n"
    )
    assert not check_source(src, "core/admission.py").findings


def test_syntax_error_reported_not_raised():
    report = check_source("def broken(:\n", "core/x.py")
    assert _rules(report) == ["C0-syntax"]


# ======================================================================
# admission warm-start equivalence
# ======================================================================

@pytest.mark.parametrize("admission", ["reference", "fused"])
def test_warm_admit_summary_bit_identical(engine, admission):
    """The signed replay + per-hid static-terms cache change wall time
    only: every non-timing summary key matches warm_admit=False exactly."""
    rt_warm = _serving_rt(engine, warm_admit=True, admission=admission)
    rt_cold = _serving_rt(engine, warm_admit=False, admission=admission)
    rt_warm.run()
    rt_cold.run()
    a, b = rt_warm.metrics.summary(), rt_cold.metrics.summary()
    keys = (set(a) | set(b)) - TIMING_KEYS
    assert {k: a.get(k) for k in keys} == {k: b.get(k) for k in keys}


def test_warm_admit_counters_track_passes(engine):
    rt = _serving_rt(engine, warm_admit=True)
    rt.run()
    m = rt.metrics
    assert m.sched_warm_hits + m.sched_warm_misses == m.sched_admit_calls
    assert m.sched_warm_misses > 0          # first pass is always a miss
    # the counters are diagnostics, not behavior: summaries must stay
    # comparable across warm on/off, so they are deliberately excluded
    assert "sched_warm_hits" not in m.summary()


def test_warm_admit_off_runs_no_warm_machinery(engine):
    rt = _serving_rt(engine, warm_admit=False)
    rt.run()
    assert rt.metrics.sched_warm_hits == 0
    assert rt.metrics.sched_warm_misses == 0
    assert rt._warm_sig is None and not rt._static_rows


def test_warm_admit_sanitizer_clean(engine):
    """S1-S5 on a warm run: the replayed admitted sets keep every cache,
    dirty set, and counter group coherent."""
    rt = _serving_rt(engine, warm_admit=True, sanitize=True,
                     sanitize_every=3, analysis="off")
    rt.run()
    assert rt.sanitizer is not None
    assert not rt.sanitizer.report.findings, rt.sanitizer.report.render()
