"""Per-architecture smoke tests (reduced configs) + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES_BY_NAME, cell_supported
from repro.models import model as M
from repro.training import optimizer as O
from repro.training import steps

pytestmark = pytest.mark.slow      # compile-heavy; fast loop: -m "not slow"

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=48):
    if cfg.frontend == "tokens":
        toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    return {
        "embeds": jax.random.normal(KEY, (b, s, cfg.d_model), jnp.bfloat16) * 0.02,
        "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch):
    """Reduced same-family config: forward + train step, shapes + no NaN."""
    cfg = ARCHS[arch].reduced()
    params = M.init_params(KEY, cfg)
    batch = _batch(cfg)
    b, s = batch["labels"].shape
    logits, aux = M.forward(params, cfg, batch)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    opt = O.init_opt_state(params)
    oc = O.AdamWConfig(total_steps=10, warmup_steps=2)
    p2, o2, mets = steps.train_step(params, opt, batch, cfg=cfg, opt_cfg=oc)
    assert np.isfinite(float(mets["loss"]))
    assert float(mets["grad_norm"]) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_prefill_decode(arch):
    cfg = ARCHS[arch].reduced()
    params = M.init_params(KEY, cfg)
    batch = {k: v for k, v in _batch(cfg).items() if k != "labels"}
    b = 2
    lg, cache = M.prefill(params, cfg, batch, max_len=64)
    assert lg.shape == (b, cfg.padded_vocab)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    lg2, cache = M.decode_step(params, cfg, cache, tok)
    assert lg2.shape == (b, cfg.padded_vocab)
    assert not bool(jnp.isnan(lg2).any())
    assert int(cache["lengths"][0]) == batch[next(iter(batch))].shape[1] + 1


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-2.7b", "zamba2-1.2b", "mixtral-8x7b"])
def test_decode_consistency(arch):
    """prefill(x[:-1]) + decode(x[-1]) must equal forward(x) at the last pos."""
    cfg = ARCHS[arch].reduced()
    params = M.init_params(KEY, cfg)
    toks = jax.random.randint(jax.random.key(3), (1, 24), 0, cfg.vocab_size)
    lg_full, _ = M.forward(params, cfg, {"tokens": toks})
    lg_pre, cache = M.prefill(params, cfg, {"tokens": toks[:, :-1]}, max_len=40)
    np.testing.assert_allclose(
        np.asarray(jax.nn.log_softmax(lg_full[:, -2])),
        np.asarray(jax.nn.log_softmax(lg_pre)), atol=1e-2, rtol=1e-2,
    )
    lg_dec, _ = M.decode_step(params, cfg, cache, toks[:, -1])
    np.testing.assert_allclose(
        np.asarray(jax.nn.log_softmax(lg_full[:, -1])),
        np.asarray(jax.nn.log_softmax(lg_dec)), atol=2e-2, rtol=2e-2,
    )


def test_long_500k_support_flags():
    """long_500k applicability must match DESIGN.md §Arch-applicability."""
    runnable = {a for a, c in ARCHS.items()
                if cell_supported(c, SHAPES_BY_NAME["long_500k"])[0]}
    assert runnable == {"mamba2-2.7b", "zamba2-1.2b", "mixtral-8x7b"}


def test_vocab_padding_masked():
    cfg = ARCHS["mamba2-2.7b"].reduced()
    assert cfg.padded_vocab % 256 == 0
    params = M.init_params(KEY, cfg)
    logits, _ = M.forward(params, cfg, _batch(cfg))
    tail = np.asarray(logits[..., cfg.vocab_size:])
    if tail.size:
        assert (tail <= -1e29).all()


def test_moe_load_balance_aux():
    cfg = ARCHS["mixtral-8x7b"].reduced()
    params = M.init_params(KEY, cfg)
    logits, aux = M.forward(params, cfg, _batch(cfg))
    # lb loss for E experts is ~1 at uniform routing; must be finite positive
    assert 0.0 < float(aux) < 10.0


def test_training_reduces_loss():
    """A few hundred steps on the bigram stream must actually learn."""
    from repro.launch.train import train
    _, _, losses = train("granite-8b", reduced=True, steps=100, seq_len=64,
                         global_batch=8, log_every=0, lr=3e-3)
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
