"""Open-loop serving (sustained-load SLO regime): lazy episode injection
from an arrival source during ``run``, the load-shedding admission ladder
(``shed_alpha``), closed-loop bit-identity pins under BOTH schedulers, and
the roster-vs-source equivalence invariant."""
import json
import os

import pytest

from repro.core.interference import Machine
from repro.core.patterns import PatternEngine
from repro.core.runtime import run_mode
from repro.core.workload import (
    WorkloadConfig, episodes_to_traces, make_episodes, open_loop_source,
)

THOR = Machine()                            # accel=1 edge box
PINNED = os.path.join(os.path.dirname(__file__), "data",
                      "pr9_pinned_serving.json")
# wall-clock self-measurements: the only summary keys legitimately allowed
# to differ between bit-identical schedules
WALL_CLOCK_KEYS = {"sched_us_per_admit", "sched_us_per_tick"}
# the full serving stack, as swept by benchmarks/bench_serving.py
STACK = dict(memo=True, model_max_batch=8, spec_model_steps=True,
             shed_alpha=1.0, adaptive_linger=True)


def _open_cfg(rate: float, n: int = 16) -> WorkloadConfig:
    return WorkloadConfig(seed=42, n_episodes=n, open_loop_rate=rate,
                          shared_frac=0.5, shared_pool=2)


def _open_run(engine, rate: float, **kw):
    merged = {**STACK, **kw}
    return run_mode([], engine, "bpaste", THOR, seed=7,
                    max_concurrent_episodes=4,
                    episode_source=open_loop_source(_open_cfg(rate)),
                    **merged)


@pytest.fixture(scope="module")
def engine():
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=20))
    return PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))


# ----------------------------------------------------------------------
# closed-loop bit-identity: the open-loop knobs at zero are exact no-ops
# ----------------------------------------------------------------------
@pytest.mark.parametrize("scheduler", ["event", "dense"])
def test_rate_zero_shed_off_reproduces_pinned_serving(engine, scheduler):
    """``open_loop_rate=0`` + ``shed_alpha=0`` (both explicit) must
    reproduce the pinned pre-feature serving summaries value-for-value
    under BOTH schedulers: the extra workload draw, the shed fold in every
    admission path, and the simulator's drain-tick loop are all exactly
    inert when off."""
    test = make_episodes(WorkloadConfig(
        seed=42, n_episodes=8, arrival_stagger=4.0, open_loop_rate=0.0,
        shared_frac=0.5, shared_pool=2))
    with open(PINNED) as f:
        pinned = json.load(f)
    got = run_mode(test, engine, "bpaste", THOR, seed=7,
                   max_concurrent_episodes=8, memo=True, model_max_batch=8,
                   shed_alpha=0.0, scheduler=scheduler).summary()
    want = pinned["bpaste_memo_thor_c8_b8"]
    diffs = {k: (got.get(k), v) for k, v in want.items()
             if k not in WALL_CLOCK_KEYS and got.get(k) != v}
    assert not diffs, f"{scheduler}: {diffs}"
    assert got["shed_passes"] == 0
    assert got["shed_rejections"] == 0


def test_source_with_rate_zero_matches_frozen_roster(engine):
    """Feeding the SAME episodes through ``episode_source`` (lazy, pumped
    mid-run, arrival timers armed by the runtime) must reproduce the
    frozen-roster run summary-for-summary: injection changes WHEN episode
    state materialises, never what gets scheduled."""
    cfg = WorkloadConfig(seed=42, n_episodes=8, arrival_stagger=4.0,
                         shared_frac=0.5, shared_pool=2)
    kw = dict(seed=7, max_concurrent_episodes=8, memo=True,
              model_max_batch=8)
    roster = run_mode(make_episodes(cfg), engine, "bpaste", THOR,
                      **kw).summary()
    source = run_mode([], engine, "bpaste", THOR,
                      episode_source=open_loop_source(cfg), **kw).summary()
    assert {k: v for k, v in roster.items() if k not in WALL_CLOCK_KEYS} \
        == {k: v for k, v in source.items() if k not in WALL_CLOCK_KEYS}


# ----------------------------------------------------------------------
# open-loop end-to-end invariants
# ----------------------------------------------------------------------
@pytest.mark.parametrize("scheduler", ["event", "dense"])
def test_open_loop_serves_every_tenant_to_completion(engine, scheduler):
    """Sustained arrivals at a moderate rate: every injected tenant runs
    to completion (no stranded pending actions at quiescence — the
    simulator drain loop's contract), the run is not truncated, and
    authoritative work rides tax-free."""
    m = _open_run(engine, 0.1, scheduler=scheduler)
    s = m.summary()
    assert len(m.tenant_sojourn) == 16
    assert s["truncated"] == 0.0
    assert s["mean_auth_slowdown"] == 1.0
    assert s["qos_violations"] == 0


def test_shed_prices_out_speculation_before_any_qos_violation(engine):
    """The graceful-degradation ladder: past the knee the backlog tax
    fires (shed passes with real rejections), yet authoritative QoS stays
    untouched — speculation sheds strictly before authoritative work
    queues behind it."""
    s = _open_run(engine, 0.2).summary()
    assert s["shed_passes"] > 0
    assert s["shed_peak_backlog"] > 0
    assert s["shed_rejections"] > 0
    assert s["mean_auth_slowdown"] == 1.0
    assert s["qos_violations"] == 0


def test_shed_inert_without_backlog(engine):
    """At a rate the box absorbs, the backlog never forms and the shed
    term never fires — the ladder's first rung is 'do nothing'."""
    s = _open_run(engine, 0.05).summary()
    assert s["shed_passes"] == 0
    assert s["shed_rejections"] == 0
    assert s["mean_auth_slowdown"] == 1.0


def test_adaptive_linger_improves_occupancy_under_open_loop(engine):
    """At a low open-loop rate the adaptive window's moderate-regime
    stretch collects more riders per dispatch: batch occupancy improves
    over the fixed window, with every tenant still served."""
    off = _open_run(engine, 0.1, adaptive_linger=False)
    on = _open_run(engine, 0.1, adaptive_linger=True)
    assert len(off.tenant_sojourn) == len(on.tenant_sojourn) == 16
    assert on.summary()["model_batch_occupancy"] > \
        off.summary()["model_batch_occupancy"]
