"""Perf-feature correctness (EXPERIMENTS.md §Perf levers): each optimized
path must match the baseline numerically."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M

KEY = jax.random.key(0)


def test_head_padding_exact_equivalence():
    """Zero-init padded heads: bit-identical forward."""
    cfg = get_config("qwen2-7b").reduced()
    cfg = dataclasses.replace(cfg, n_heads=3, n_kv_heads=3, head_dim=16, d_model=48)
    cfgp = dataclasses.replace(cfg, head_pad_multiple=4)
    params = M.init_params(KEY, cfg)
    paramsp = M.init_params(KEY, cfgp)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    l1, _ = M.forward(params, cfg, {"tokens": toks})
    l2, _ = M.forward(paramsp, cfgp, {"tokens": toks})
    assert float(jnp.abs(l1 - l2).max()) == 0.0


def test_head_padding_grads_stay_zero():
    """Padded wo rows receive zero gradient (exact semantics forever)."""
    from repro.training import steps, optimizer as O
    cfg = dataclasses.replace(get_config("qwen2-7b").reduced(),
                              n_heads=3, n_kv_heads=3, head_dim=16, d_model=48,
                              head_pad_multiple=4)
    params = M.init_params(KEY, cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    opt = O.init_opt_state(params)
    p2, _, _ = steps.train_step(params, opt, batch, cfg=cfg,
                                opt_cfg=O.AdamWConfig(total_steps=5, warmup_steps=1))
    hd = cfg.resolved_head_dim
    pad_rows = np.asarray(p2["blocks"]["attn"]["wo"][:, 3 * hd:, :], np.float32)
    assert np.abs(pad_rows).max() == 0.0


def test_int8_kv_cache_decode_close():
    cfg = get_config("granite-8b").reduced()
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = M.init_params(KEY, cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 20), 0, cfg.vocab_size)
    lg, cache = M.prefill(params, cfg, {"tokens": toks}, max_len=40)
    lg8, cache8 = M.prefill(params, cfg8, {"tokens": toks}, max_len=40)
    t = jnp.argmax(lg, -1).astype(jnp.int32)
    d1, c1 = M.decode_step(params, cfg, cache, t)
    d2, c2 = M.decode_step(params, cfg8, cache8, t)
    err = float(jnp.abs(jax.nn.log_softmax(d1) - jax.nn.log_softmax(d2)).max())
    assert err < 0.15, err
    # cache stays quantized across steps
    assert c2["k"][0].dtype == jnp.int8
    t2 = jnp.argmax(d2, -1).astype(jnp.int32)
    d3, _ = M.decode_step(params, cfg8, c2, t2)
    assert not bool(jnp.isnan(d3).any())


def test_sharded_decode_multidevice():
    """shard_map split-KV flash-decode == plain decode on an 8-dev mesh
    (subprocess: needs its own XLA device-count flag)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, jax.numpy as jnp
        from repro import compat
        from repro.configs import get_config
        from repro.models import model as M
        from repro.models.model import MeshContext
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        mi = MeshContext(mesh, ("data",), "model", 4, 2)
        cfg = get_config("musicgen-medium").reduced()
        params = M.init_params(jax.random.key(0), cfg)
        emb = jax.random.normal(jax.random.key(2), (2, 12, cfg.d_model), jnp.bfloat16) * 0.02
        lg, cache = M.prefill(params, cfg, {"embeds": emb}, max_len=32)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        lg_plain, _ = M.decode_step(params, cfg, cache, tok)
        cfg_sh = dataclasses.replace(cfg, sharded_decode_attn=True)
        lg_shard, _ = M.decode_step(params, cfg_sh, cache, tok, mesh_info=mi)
        err = float(jnp.abs(jax.nn.log_softmax(lg_plain) - jax.nn.log_softmax(lg_shard)).max())
        assert err < 2e-2, err
        # int8 + sharded
        cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
        cfg8s = dataclasses.replace(cfg8, sharded_decode_attn=True)
        lg8, cache8 = M.prefill(params, cfg8, {"embeds": emb}, max_len=32)
        d2, _ = M.decode_step(params, cfg8, cache8, tok)
        d3, _ = M.decode_step(params, cfg8s, cache8, tok, mesh_info=mi)
        err2 = float(jnp.abs(jax.nn.log_softmax(d2) - jax.nn.log_softmax(d3)).max())
        assert err2 < 2e-2, err2
        print("SHARDED_DECODE_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=420,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert "SHARDED_DECODE_OK" in r.stdout, r.stderr[-2000:]


def test_fsdp_specs_cover_all_params():
    """Every FSDP spec shards at most one dim and only divisible dims."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro import compat
        from repro.configs import get_config
        from repro.launch import shardings as sh
        from repro.launch.input_specs import param_structs
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("granite-8b").reduced()
        specs = sh.fsdp_param_specs(cfg, mesh)
        structs = param_structs(cfg)
        from jax.sharding import PartitionSpec as P
        def check(st, sp):
            shards = [a for a in sp if a is not None]
            assert len(shards) <= 1
            for i, a in enumerate(sp):
                if a is not None:
                    assert st.shape[i] % 8 == 0, (st.shape, sp)
        jax.tree.map(check, structs, specs, is_leaf=lambda x: isinstance(x, P))
        print("FSDP_SPECS_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert "FSDP_SPECS_OK" in r.stdout, r.stderr[-2000:]
