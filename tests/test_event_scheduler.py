"""ISSUE 6: event-driven scheduler core — equivalence + invariants.

Three layers under test:

* Simulator event queue: lazy heap invalidation across preempt / cancel /
  resume, incremental demand counters vs brute-force re-sums (property
  test), bounded ``slow_samples`` ring that skips zero-demand timers,
  ``record_log=False``.
* Runtime dirty-set phases: the ``scheduler="event"`` tick loop must be
  bit-identical (full metrics summary, decisions included by implication)
  to the dense re-scan on the pinned serving configs.
* Observability: GanttRecorder rows + ASCII rendering, sched_ticks.

The property-testing package ``hypothesis`` (requirements-dev.txt) shares
a name with ``repro.core.hypothesis`` but not an import path; when absent
the property tests skip instead of failing collection (see test_core.py).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:                     # pragma: no cover
    HYPOTHESIS_SKIP = "hypothesis not installed (pip install -r requirements-dev.txt)"

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            def shim():                          # zero-arg: strategies never run
                pytest.skip(HYPOTHESIS_SKIP)
            shim.__name__ = f.__name__
            shim.__doc__ = f.__doc__
            return shim
        return deco

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.core.events import RESOURCE_DIMS
from repro.core.interference import Machine, ResourceVector
from repro.core.patterns import PatternEngine
from repro.core.runtime import BPasteRuntime, RuntimeConfig
from repro.core.simulator import SLOW_SAMPLE_CAP, Simulator
from repro.core.trace import GanttRecorder, render_ascii
from repro.core.workload import (
    WorkloadConfig, episodes_to_traces, make_episodes,
)

# wall-time-derived summary keys: everything else must match exactly
TIMING_KEYS = {"sched_us_per_admit", "sched_us_per_tick"}


def _sim(**kw):
    return Simulator(Machine(), lambda s: None, **kw)


def _d(cpu=1.0, mem=0.0, io=0.0, accel=0.0):
    return np.array([cpu, mem, io, accel])


# ======================================================================
# Simulator: heap invalidation + lazy settlement
# ======================================================================
class TestEventQueue:
    def test_preempt_resume_keeps_progress(self):
        sim = _sim()
        a = sim.new_job("a", _d(), 10.0, speculative=True)
        b = sim.new_job("b", _d(), 4.0, speculative=False)
        sim.start(a)
        sim.start(b)
        sim.step()                      # b finishes at t=4 (no contention)
        assert sim.now == pytest.approx(4.0)
        got = sim.preempt(a.jid)
        assert got is a and a.preempt_count == 1
        # lazy settlement: preemption must bring remaining forward to now
        assert a.remaining == pytest.approx(6.0)
        assert a.jid not in sim.running
        # resume: the stale heap entry from the first start() must not fire
        sim.start(a)
        assert sim.step()
        assert sim.now == pytest.approx(10.0)
        assert a.finished_at == pytest.approx(10.0)

    def test_cancel_invalidates_heap_entry(self):
        fired = []
        sim = _sim()
        t = sim.new_job("timer", np.zeros(RESOURCE_DIMS), 5.0,
                        speculative=False,
                        on_complete=lambda s, j: fired.append(j.name))
        w = sim.new_job("work", _d(), 9.0, speculative=False)
        sim.start(t)
        sim.start(w)
        sim.cancel(t.jid)
        assert t.preempt_count == 0     # cancel is not a scheduling decision
        sim.run()
        # the cancelled timer's queue entry went stale: never completes
        assert fired == []
        assert sim.now == pytest.approx(9.0)

    def test_rate_change_reprojects_completion(self):
        """Oversubscription stretches in-flight work: the old projected
        completion entry goes stale and the re-priced one wins."""
        cap = Machine().cap_array()
        sim = _sim()
        a = sim.new_job("a", _d(cpu=cap[0]), 10.0, speculative=False)
        sim.start(a)
        # drive cpu to 2x capacity at t=0: both jobs run at rate 1/2
        b = sim.new_job("b", _d(cpu=cap[0]), 10.0, speculative=False)
        sim.start(b)
        sim.run()
        assert sim.now == pytest.approx(20.0)
        assert a.finished_at == pytest.approx(20.0)
        assert b.finished_at == pytest.approx(20.0)

    def test_slack_matches_bruteforce_after_churn(self):
        sim = _sim()
        jobs = [sim.new_job(f"j{i}", _d(cpu=0.5 + 0.25 * (i % 3), io=float(i % 2)),
                            5.0 + i, speculative=bool(i % 2)) for i in range(8)]
        for j in jobs:
            sim.start(j)
        sim.preempt(jobs[2].jid)
        sim.cancel(jobs[5].jid)
        sim.start(jobs[2])              # resume
        brute = np.zeros(RESOURCE_DIMS)
        for j in sim.running.values():
            brute += j.demand
        assert np.allclose(sim.running_demand(), brute)
        assert np.allclose(sim.slack(), np.maximum(sim.cap - brute, 0.0))
        spec = sum((j.demand for j in sim.running.values() if j.speculative),
                   np.zeros(RESOURCE_DIMS))
        assert np.allclose(sim.running_demand(speculative=True), spec)


@settings(max_examples=60, deadline=None)
@given(st.lists(
    st.tuples(
        st.sampled_from(["start", "preempt", "cancel", "step", "promote"]),
        st.integers(min_value=0, max_value=7),
        st.floats(min_value=0.0, max_value=3.0),
        st.booleans(),
    ),
    min_size=1, max_size=40,
))
def test_incremental_demand_is_exact_under_random_churn(ops):
    """Property: after ANY interleaving of start/preempt/cancel/step/
    promote, the O(#groups) counter-based running_demand equals the O(n)
    brute-force re-sum EXACTLY (counters scale the group vector — no
    accumulated float drift), for both speculative classes."""
    sim = _sim(record_log=False)
    jobs = {}
    for op, slot, dem, spec in ops:
        if op == "start":
            j = jobs.get(slot)
            if j is None or j.finished_at is not None:
                j = jobs[slot] = sim.new_job(
                    f"s{slot}", _d(cpu=dem, io=dem * 0.5), 1.0 + dem,
                    speculative=spec)
            if j.jid not in sim.running and j.finished_at is None:
                sim.start(j)
        elif op == "preempt":
            j = jobs.get(slot)
            if j is not None:
                sim.preempt(j.jid)
        elif op == "cancel":
            j = jobs.get(slot)
            if j is not None:
                sim.cancel(j.jid)
                jobs.pop(slot)          # cancelled jobs never resume
        elif op == "promote":
            j = jobs.get(slot)
            if j is not None:
                sim.set_speculative(j, spec)
        else:
            sim.step()
        for flag in (None, True, False):
            brute = sum(
                (j.demand for j in sim.running.values()
                 if flag is None or j.speculative == flag),
                np.zeros(RESOURCE_DIMS))
            got = sim.running_demand(speculative=flag)
            assert np.array_equal(got, brute), (op, flag, got, brute)


# ======================================================================
# Simulator: observability knobs
# ======================================================================
class TestObservability:
    def test_record_log_off_keeps_log_empty(self):
        sim = _sim(record_log=False)
        j = sim.new_job("j", _d(), 1.0, speculative=False)
        sim.start(j)
        sim.preempt(j.jid)
        sim.start(j)
        sim.run()
        assert sim.log == []

    def test_slow_samples_bounded_and_skip_timers(self):
        sim = _sim()
        t = sim.new_job("timer", np.zeros(RESOURCE_DIMS), 2.0, speculative=False)
        sim.start(t)
        assert len(sim.slow_samples) == 0   # zero-demand: never sampled
        w = sim.new_job("w", _d(), 1.0, speculative=False)
        sim.start(w)
        assert len(sim.slow_samples) == 1
        assert sim.slow_samples.maxlen == SLOW_SAMPLE_CAP

    def test_gantt_recorder_rows_and_ascii(self):
        rec = GanttRecorder()
        sim = _sim(recorder=rec)
        t = sim.new_job("timer", np.zeros(RESOURCE_DIMS), 9.0, speculative=False,
                        meta={"timer": True})
        a = sim.new_job("spec", _d(), 2.0, speculative=True, meta={"eid": 0})
        b = sim.new_job("auth", _d(), 3.0, speculative=False, meta={"eid": 1})
        sim.start(t)
        sim.start(a)
        sim.start(b)
        sim.run()
        rec.close(sim.now)
        # timer skipped; both real jobs closed with exact extents
        assert sorted(r["job"] for r in rec.rows) == ["auth", "spec"]
        spec_row = next(r for r in rec.rows if r["job"] == "spec")
        assert spec_row["speculative"] and spec_row["outcome"] == "finish"
        assert spec_row["t_end"] == pytest.approx(2.0)
        art = render_ascii(rec.rows)
        assert "~" in art and "=" in art    # spec vs authoritative glyphs


# ======================================================================
# Runtime: event scheduler == dense scheduler, bit for bit
# ======================================================================
SERVE_BOX = Machine(ResourceVector(cpu=12, mem_bw=100, io=500, accel=4))


@pytest.fixture(scope="module")
def engine():
    train = make_episodes(WorkloadConfig(seed=1, n_episodes=20))
    return PatternEngine(context_len=2, min_support=3).fit(
        episodes_to_traces(train))


def _summary(engine, mode, memo, conc, box, scheduler):
    eps = make_episodes(WorkloadConfig(seed=42, n_episodes=8,
                                       arrival_stagger=2.0,
                                       shared_frac=0.5, shared_pool=2))
    rt = BPasteRuntime(eps, engine, box, rcfg=RuntimeConfig(
        mode=mode, seed=7, max_concurrent_episodes=conc, memo=memo,
        model_max_batch=4, scheduler=scheduler))
    return rt.run().summary()


@pytest.mark.parametrize("mode,memo,conc,thor", [
    ("bpaste", True, 8, False),
    ("bpaste", False, 8, False),
    ("bpaste", True, 4, True),
    ("serial", True, 8, False),
])
def test_event_equals_dense_summary(engine, mode, memo, conc, thor):
    """The dirty-set event loop and the dense O(c) re-scan must agree on
    EVERY summary metric except the two wall-time-derived keys — decisions,
    promotions, memo traffic, occupancy samples, latencies, all of it."""
    box = Machine() if thor else SERVE_BOX
    a = _summary(engine, mode, memo, conc, box, "event")
    b = _summary(engine, mode, memo, conc, box, "dense")
    keys = (set(a) | set(b)) - TIMING_KEYS
    diffs = {k: (a.get(k), b.get(k)) for k in keys if a.get(k) != b.get(k)}
    assert not diffs, diffs


def test_sched_ticks_counted(engine):
    s = _summary(engine, "bpaste", True, 8, SERVE_BOX, "event")
    assert s["sched_ticks"] > 0
    assert s["sched_us_per_tick"] >= 0.0


def test_bad_scheduler_rejected(engine):
    eps = make_episodes(WorkloadConfig(seed=42, n_episodes=2))
    with pytest.raises(ValueError, match="scheduler"):
        BPasteRuntime(eps, engine, Machine(),
                      rcfg=RuntimeConfig(scheduler="quantum"))
